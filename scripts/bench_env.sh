#!/usr/bin/env bash
# Benchmark environment hygiene: source this before timing runs so bench
# numbers measure the algorithm, not the allocator or logging noise.
#
#   source scripts/bench_env.sh
#   PYTHONPATH=src python benchmarks/bench_amih_vs_scan.py --batch 64
#
# scripts/verify.sh sources it automatically for the REPRO_BENCH_CHECK=1
# gate. Everything here is optional and degrades gracefully: a host
# without tcmalloc just keeps glibc malloc, and caller-set XLA_FLAGS are
# preserved. Knobs (see docs/tuning.md):
#
#   - tcmalloc via LD_PRELOAD: thread-caching malloc is measurably
#     faster for the bench's churn of short-lived NumPy buffers
#     (extraction scratch, per-batch pads), and keeps its speed once
#     the posmap donation pool removes the large steady-state
#     allocations.
#   - TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD: silence tcmalloc's
#     large-alloc warnings (device CSR uploads and big sims scratch trip
#     the default threshold and pollute timing output).
#   - TF_CPP_MIN_LOG_LEVEL=4: mute XLA/TSL C++ chatter on stderr.
#   - XLA_FLAGS --xla_force_host_platform_device_count: pin the host
#     platform's fake-device count to 1 unless the caller already chose
#     a layout — a surprise multi-device host would silently change the
#     sharded cells' placement (and bench_check would skip them as
#     config drift).

_TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [[ -f "${_TCMALLOC}" ]]; then
  export LD_PRELOAD="${_TCMALLOC}${LD_PRELOAD:+:$LD_PRELOAD}"
  export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
fi
unset _TCMALLOC

export TF_CPP_MIN_LOG_LEVEL=4

if [[ "${XLA_FLAGS:-}" != *xla_force_host_platform_device_count* ]]; then
  export XLA_FLAGS="--xla_force_host_platform_device_count=1${XLA_FLAGS:+ $XLA_FLAGS}"
fi
