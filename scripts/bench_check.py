#!/usr/bin/env python
"""Perf-regression gate: re-run the engine benchmark and diff it against
the committed BENCH_engine.json.

A fresh ``bench_amih_vs_scan`` sweep (same workload parameters as the
committed baseline, restricted to the requested batch sizes) is compared
cell-by-cell: for every amih / sharded_amih / sharded_scan
(backend, p, n, K, batch, shards) cell present in both runs, fail if
fresh throughput regressed by more than ``--threshold`` (default 25% on
ms_per_query). Cells carrying ``launches_per_batch`` (device probe rows
of a post-fusion bench) additionally gate the LAUNCH ECONOMY: walk
launches per knn_batch call are deterministic, so any increase over the
committed baseline fails outright — a config change that silently
un-fuses the batch walk can't hide behind timing noise. Baselines
written before the field existed skip that gate per cell. When the committed baseline carries a ``"serving"``
section (benchmarks/bench_serving.py: pipelined vs sequential serving
cells with p50/p99 latency, persistent-pool and placement fields),
those cells are gated the same way; older baselines without the section
still parse and skip that gate. Cells whose recorded execution config
(placement-device count, probe-pool flavor) differs between baseline
and fresh run are excluded with a note instead of gated —
apples-to-oranges timing is worse than no gate — and baselines written
before those fields existed compare against anything. Host timing is noisy, so single-cell blips on a
loaded machine are possible — the gate is opt-in (wired into
scripts/verify.sh behind REPRO_BENCH_CHECK=1), not part of tier-1.

Usage:
  PYTHONPATH=src python scripts/bench_check.py             # batch=64 gate
  PYTHONPATH=src python scripts/bench_check.py --max-n 10000   # smoke
  REPRO_BENCH_CHECK=1 scripts/verify.sh                    # tests + gate
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "benchmarks"))
sys.path.insert(0, os.path.join(_ROOT, "src"))

BASELINE_JSON = os.path.join(_ROOT, "BENCH_engine.json")


_GATED_BACKENDS = ("amih", "sharded_amih", "sharded_scan")


def _cells(payload, batches, max_n, shards):
    """(backend, p, n, K, batch, shards, probe_backend) ->
    (ms_per_query, config, launches_per_batch) for every gated cell.
    Sharded rows ride the max batch size regardless of --batch;
    pre-shard baselines carry shards=1 implicitly, and rows written
    before the probe_backend axis existed gate as "host" (the only walk
    back then). ``config`` is the cell's placement fingerprint (distinct
    devices the shards landed on) — rows written before placement
    existed carry None and compare against anything, as do rows written
    before ``launches_per_batch`` for the launch-economy gate."""
    out = {}
    for row in payload["rows"]:
        if row["backend"] not in _GATED_BACKENDS:
            continue
        n_shards = row.get("shards", 1)
        sharded = row["backend"] != "amih"
        if sharded:
            if n_shards not in shards or row["n"] > max_n:
                continue
        elif row["batch"] not in batches or row["n"] > max_n:
            continue
        key = (row["backend"], row["p"], row["n"], row["K"],
               row["batch"], n_shards, row.get("probe_backend", "host"))
        out[key] = (float(row["ms_per_query"]), row.get("devices"),
                    row.get("launches_per_batch"))
    return out


def _serving_cells(section, max_n):
    """(backend, mode, p, n, K, batch, shards, probe_backend, hosts) ->
    (ms_per_query, config) for the serving-bench cells (see
    benchmarks/bench_serving.py); pre-device-walk rows gate as "host"
    and pre-cluster rows as hosts=1 (the only shape back then).
    ``config`` fingerprints the cell's execution shape — probe-pool
    flavor and placement-device count — so a persistent-pool cell is
    never gated against a per-call-fork or differently-placed baseline;
    pre-pool baselines carry None and compare against anything."""
    out = {}
    for row in section.get("rows", []):
        if row["n"] > max_n:
            continue
        key = (row["backend"], row["mode"], row["p"], row["n"],
               row["K"], row["batch"], row["shards"],
               row.get("probe_backend", "host"), row.get("hosts", 1))
        cfg = (
            (row.get("pool", ""), row.get("devices"))
            if ("pool" in row or "devices" in row) else None
        )
        out[key] = (float(row["ms_per_query"]), cfg)
    return out


def _comparable(base_cells, fresh_cells):
    """Cells present in both runs whose configs agree (a None config —
    an older baseline without the fields — matches anything). Returns
    (sorted comparable keys, keys skipped for config drift)."""
    shared = set(base_cells) & set(fresh_cells)
    skipped = {
        c for c in shared
        if base_cells[c][1] is not None and fresh_cells[c][1] is not None
        and base_cells[c][1] != fresh_cells[c][1]
    }
    return sorted(shared - skipped), sorted(skipped)


def check_serving(baseline, max_n, threshold) -> int:
    """Gate the serving cells when the baseline carries them. Baselines
    written before bench_serving existed simply lack the section — they
    still parse and the gate passes them through."""
    section = baseline.get("serving")
    if not section:
        print("bench_check: baseline has no serving section; skipping "
              "the serving gate (run benchmarks/bench_serving.py)")
        return 0
    wl = section["workload"]
    serving_max_n = min(max_n, max(wl["sizes"]))

    import bench_serving

    def fresh(ps, sizes, batches, shards, hosts):
        with tempfile.NamedTemporaryFile(
            mode="r", suffix=".json", prefix="bench_serving_check_",
            delete=False,
        ) as tmp:
            path = tmp.name
        try:
            bench_serving.run(
                max_n=serving_max_n, nq=wl["queries"],
                ps=tuple(ps), k=wl["k"], sizes=sorted(sizes),
                batches=tuple(batches), shards=tuple(shards),
                out_json=path, csv_name="serving_check.csv",
                probe_backends=tuple(
                    wl.get("probe_backends", ["host"])
                ),
                hosts=tuple(sorted(hosts)),
            )
            with open(path) as f:
                return _serving_cells(json.load(f), serving_max_n)
        finally:
            os.unlink(path)

    base_cells = _serving_cells(section, serving_max_n)
    fresh_cells = fresh(wl["ps"], wl["sizes"], wl["batches"],
                        wl["shards"], wl.get("hosts", [1]))
    shared, skipped = _comparable(base_cells, fresh_cells)
    for cell in skipped:
        print(f"bench_check: serving cell {cell} skipped — pool/placement "
              f"config changed ({base_cells[cell][1]} -> "
              f"{fresh_cells[cell][1]}); re-run bench_serving to "
              f"re-baseline it")
    if not shared:
        print("bench_check: no comparable serving cells")
        return 2
    base_ms = {c: base_cells[c][0] for c in shared}
    fresh_ms = {c: fresh_cells[c][0] for c in shared}

    def regressed():
        return [c for c in shared
                if fresh_ms[c] / max(base_ms[c], 1e-9)
                > 1.0 + threshold]

    failures = regressed()
    if failures:
        # one retry narrowed to the failing cells' workload (the engine
        # gate's shape) — a single noisy cell must not re-run the sweep
        print(f"bench_check: {len(failures)} serving cell(s) over "
              f"threshold; re-measuring once to rule out host noise...")
        retry = fresh(
            {c[2] for c in failures}, {c[3] for c in failures},
            {c[5] for c in failures}, {c[6] for c in failures},
            {c[8] for c in failures},
        )
        for cell, (ms, _) in retry.items():
            if cell in fresh_ms:
                fresh_ms[cell] = min(fresh_ms[cell], ms)
        failures = regressed()
    for cell in shared:
        backend, mode, p, n, K, batch, n_shards, pb, n_hosts = cell
        ratio = fresh_ms[cell] / max(base_ms[cell], 1e-9)
        status = "FAIL" if cell in failures else "ok"
        print(f"  [{status}] {backend:>13}[{pb}]/{mode:<10} p={p} "
              f"n={n:>9} K={K:>3} B={batch:>3} S={n_shards:>2} "
              f"H={n_hosts} baseline={base_ms[cell]:.3f} "
              f"fresh={fresh_ms[cell]:.3f} ms/q ({ratio:.2f}x)")
    if failures:
        print(f"bench_check: {len(failures)}/{len(shared)} serving cells "
              f"regressed beyond {threshold:.0%}")
        return 1
    print(f"bench_check: all {len(shared)} serving cells within "
          f"{threshold:.0%} of the committed baseline")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--batch", type=int, nargs="+", default=[64],
                    help="batch sizes to re-run and gate on")
    ap.add_argument("--shards", type=int, nargs="+", default=None,
                    help="shard counts to gate the sharded backends on "
                         "(default: every count in the baseline workload)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated ms_per_query regression (0.25=25%%)")
    ap.add_argument("--max-n", type=int, default=None,
                    help="cap DB sizes (smoke mode); default: every size "
                         "in the committed baseline")
    ap.add_argument("--baseline", type=str, default=BASELINE_JSON)
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"bench_check: no baseline at {args.baseline}; nothing to "
              f"gate against (run benchmarks/bench_amih_vs_scan.py first)")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    wl = baseline["workload"]
    max_n = args.max_n or max(wl["sizes"])
    shards = set(args.shards or wl.get("shards", [1]))
    # Sharded rows always ride a sweep's max batch size, so the fresh
    # sweep must include the baseline's max batch or the sharded cell
    # keys would never intersect (the amih gate still honors --batch).
    sweep_batches = tuple(sorted(set(args.batch) | {max(wl["batches"])}))

    import bench_amih_vs_scan as bench

    def fresh_sweep(ps, ks, sweep_max_n, sizes=None):
        """One bench sweep into a throwaway JSON/CSV (the committed
        BENCH_engine.json and full-sweep CSV stay untouched)."""
        with tempfile.NamedTemporaryFile(
            mode="r", suffix=".json", prefix="bench_check_", delete=False
        ) as tmp:
            fresh_path = tmp.name
        try:
            bench.run(
                max_n=sweep_max_n,
                nq=wl["queries"],
                batches=sweep_batches,
                ps=tuple(ps),
                ks=tuple(ks),
                out_json=fresh_path,
                sizes=sizes,
                csv_name="amih_vs_scan_check.csv",
                shards=tuple(sorted(shards)),
                probe_backends=tuple(
                    wl.get("probe_backends", ["host"])
                ),
            )
            with open(fresh_path) as f:
                return _cells(
                    json.load(f), set(args.batch), sweep_max_n, shards
                )
        finally:
            os.unlink(fresh_path)

    base_cells = _cells(baseline, set(args.batch), max_n, shards)
    fresh_cells = fresh_sweep(wl["ps"], wl["ks"], max_n)
    shared, skipped = _comparable(base_cells, fresh_cells)
    for cell in skipped:
        print(f"bench_check: cell {cell} skipped — placement config "
              f"changed ({base_cells[cell][1]} -> {fresh_cells[cell][1]}); "
              f"re-run the bench to re-baseline it")
    if not shared:
        print("bench_check: no comparable AMIH cells between baseline and "
              "fresh run (workloads disjoint?)")
        return 2
    base_ms = {c: base_cells[c][0] for c in shared}
    fresh_ms = {c: fresh_cells[c][0] for c in shared}

    def regressed(cells):
        return [
            c for c in cells
            if fresh_ms[c] / max(base_ms[c], 1e-9)
            > 1.0 + args.threshold
        ]

    failures = regressed(shared)
    if failures:
        # one retry of just the failing cells: a single scheduler/GC
        # transient on a loaded host shouldn't fail the gate. Keep the
        # per-cell best of both sweeps.
        print(f"bench_check: {len(failures)} cell(s) over threshold; "
              f"re-measuring once to rule out host noise...")
        retry = fresh_sweep(
            sorted({c[1] for c in failures}),
            sorted({c[3] for c in failures}),
            max(c[2] for c in failures),
            sizes=sorted({c[2] for c in failures}),
        )
        for cell, (ms, _cfg, _lpb) in retry.items():
            if cell in fresh_ms:
                fresh_ms[cell] = min(fresh_ms[cell], ms)
        failures = regressed(shared)

    # Launch economy: walk launches per knn_batch call are deterministic
    # (no retry needed) — any increase over the baseline means probing
    # stopped fusing and fails outright. Cells where either side predates
    # the field skip this gate.
    launch_failures = [
        c for c in shared
        if base_cells[c][2] is not None and fresh_cells[c][2] is not None
        and float(fresh_cells[c][2]) > float(base_cells[c][2])
    ]

    for cell in shared:
        ratio = fresh_ms[cell] / max(base_ms[cell], 1e-9)
        status = "FAIL" if cell in failures or cell in launch_failures \
            else "ok"
        backend, p, n, K, batch, n_shards, pb = cell
        lpb = fresh_cells[cell][2]
        launch_note = "" if lpb is None else f" launches/batch={lpb}"
        print(f"  [{status}] {backend:>13}[{pb}] p={p} n={n:>9} "
              f"K={K:>3} B={batch:>3} S={n_shards:>2} "
              f"baseline={base_ms[cell]:.3f} fresh={fresh_ms[cell]:.3f} "
              f"ms/q ({ratio:.2f}x){launch_note}")
    for cell in launch_failures:
        print(f"bench_check: LAUNCH ECONOMY regression in {cell}: "
              f"{base_cells[cell][2]} -> {fresh_cells[cell][2]} walk "
              f"launches per batch")
    if failures or launch_failures:
        if failures:
            print(f"bench_check: {len(failures)}/{len(shared)} engine "
                  f"cells regressed beyond {args.threshold:.0%}")
        if launch_failures:
            print(f"bench_check: {len(launch_failures)}/{len(shared)} "
                  f"engine cells regressed launches-per-batch")
        return 1
    print(f"bench_check: all {len(shared)} engine cells within "
          f"{args.threshold:.0%} of the committed baseline")
    return check_serving(baseline, max_n, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
