#!/usr/bin/env python
"""Docs-rot gate: every repo path and code identifier cited in the docs
must actually exist.

Scans README.md, ROADMAP.md, and docs/*.md for three kinds of
references and fails if any is dangling:

  1. repo paths — tokens like ``src/repro/shard/plan.py`` or
     ``benchmarks/bench_serving.py`` (any ``src/ scripts/ benchmarks/
     examples/ tests/ docs/`` prefix) must exist on disk;
  2. dotted ``repro.*`` identifiers in backticks — e.g.
     ``repro.core.engine.make_engine`` — must import/resolve;
  3. backticked ``ClassName.attr`` chains — e.g.
     ``AMIHIndex.knn_batch_bounded`` — must resolve against the public
     namespace of the core modules (dataclass fields count).

Wired into scripts/verify.sh so refactors that move or rename anything
the docs point at fail tier-1 verification until the docs follow.

Usage:  PYTHONPATH=src python scripts/check_docs.py [-v]
"""

from __future__ import annotations

import argparse
import dataclasses
import importlib
import os
import re
import sys
import warnings

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "src"))

# files under the gate (CHANGES.md is an append-only log, PAPER*/SNIPPETS
# are retrieval artifacts — neither is a promise about the current tree)
DOC_FILES = ("README.md", "ROADMAP.md")
DOCS_DIR = "docs"

_PATH_RE = re.compile(
    r"(?<![\w/.])((?:src|scripts|benchmarks|examples|tests|docs)/"
    r"[A-Za-z0-9_./\-]+)"
)
_BACKTICK_RE = re.compile(r"`([^`\n]+)`")
_REPRO_RE = re.compile(r"^repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+$")
_CLASS_ATTR_RE = re.compile(
    r"^_?[A-Z][A-Za-z0-9_]*(?:\.[A-Za-z_][A-Za-z0-9_]*)+$"
)

# modules whose public names make up the ClassName.attr namespace
_NAMESPACE_MODULES = (
    "repro.core",
    "repro.core.amih",
    "repro.core.engine",
    "repro.shard",
    "repro.shard.plan",
    "repro.pipeline",
    "repro.pipeline.shardpool",
    "repro.kernels.ops",
    "repro.serve.retrieval",
    "repro.cluster",
    "repro.cluster.worker",
    "repro.cluster.transport",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.export",
)


def _doc_paths():
    out = [os.path.join(_ROOT, f) for f in DOC_FILES]
    docs = os.path.join(_ROOT, DOCS_DIR)
    if os.path.isdir(docs):
        out.extend(
            os.path.join(docs, f)
            for f in sorted(os.listdir(docs))
            if f.endswith(".md")
        )
    return out


def _namespace():
    ns = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        for modname in _NAMESPACE_MODULES:
            mod = importlib.import_module(modname)
            for name, obj in vars(mod).items():
                ns.setdefault(name, obj)
    return ns


def _has_attr(obj, attr: str) -> bool:
    if hasattr(obj, attr):
        return True
    # dataclass fields with default_factory never become class attributes
    if dataclasses.is_dataclass(obj):
        return attr in {f.name for f in dataclasses.fields(obj)}
    return False


def _resolve_repro(token: str) -> bool:
    parts = token.split(".")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # core.distributed shim etc.
        for i in range(len(parts), 0, -1):
            try:
                obj = importlib.import_module(".".join(parts[:i]))
            except ImportError:
                continue
            for j, attr in enumerate(parts[i:]):
                last = i + j == len(parts) - 1
                if last and _has_attr(obj, attr):
                    return True
                try:
                    obj = getattr(obj, attr)
                except AttributeError:
                    return False
            return True
    return False


def _check_file(path: str, ns, verbose: bool):
    failures, checked = [], 0
    rel = os.path.relpath(path, _ROOT)
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            for m in _PATH_RE.finditer(line):
                token = m.group(1).rstrip(".,:;")
                checked += 1
                if not os.path.exists(os.path.join(_ROOT, token)):
                    failures.append(
                        f"{rel}:{lineno}: missing path {token!r}"
                    )
                elif verbose:
                    print(f"  ok path       {token}")
            for m in _BACKTICK_RE.finditer(line):
                token = m.group(1).strip()
                if token.endswith("()"):
                    token = token[:-2]
                if _REPRO_RE.match(token):
                    checked += 1
                    if not _resolve_repro(token):
                        failures.append(
                            f"{rel}:{lineno}: unresolvable identifier "
                            f"{token!r}"
                        )
                    elif verbose:
                        print(f"  ok identifier {token}")
                elif _CLASS_ATTR_RE.match(token):
                    head, *tail = token.split(".")
                    obj = ns.get(head)
                    if obj is None:
                        continue   # not one of ours (e.g. numpy classes)
                    checked += 1
                    ok = True
                    for j, attr in enumerate(tail):
                        if j == len(tail) - 1 and _has_attr(obj, attr):
                            break
                        try:
                            obj = getattr(obj, attr)
                        except AttributeError:
                            ok = False
                            break
                    if not ok:
                        failures.append(
                            f"{rel}:{lineno}: {head!r} has no "
                            f"{'.'.join(tail)!r} ({token})"
                        )
                    elif verbose:
                        print(f"  ok attr       {token}")
    return failures, checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print every reference checked")
    args = ap.parse_args(argv)

    ns = _namespace()
    failures, checked = [], 0
    for path in _doc_paths():
        if args.verbose:
            print(os.path.relpath(path, _ROOT))
        f, c = _check_file(path, ns, args.verbose)
        failures.extend(f)
        checked += c
    for f in failures:
        print(f"check_docs: {f}")
    if failures:
        print(f"check_docs: {len(failures)} dangling reference(s) out of "
              f"{checked} checked")
        return 1
    print(f"check_docs: {checked} doc references OK "
          f"({len(_doc_paths())} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
