#!/usr/bin/env bash
# Tier-1 verification: the one command run locally and in CI.
# Usage: scripts/verify.sh [extra pytest args...]
# Opt-in perf gate: REPRO_BENCH_CHECK=1 scripts/verify.sh
#   (smoke-diffs a fresh bench_amih_vs_scan run against the committed
#    BENCH_engine.json via scripts/bench_check.py after the tests pass)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
# Fast pipelined-serving smoke: every pipelined path (AMIH verify/probe
# overlap, shard-parallel probing, streaming loop) answers bit-identical
# to its sequential counterpart on a small workload (~10 s).
python -m repro.pipeline.smoke
# Cross-host serving smoke: coordinator + 2 spawned localhost workers
# answer a mixed batch over the full wire protocol (build frames,
# fan-out, bound broadcast, merge) bit-identical to linear_scan_knn.
python -m repro.cluster.smoke
# Trace smoke: a 2-localhost-worker cluster search with tracing on must
# stay exact and export one Chrome trace whose report shows spans from
# >= 2 worker hosts across >= 4 distinct stages (see docs/observability.md).
OBS_TRACE="$(mktemp -t obs_smoke_XXXXXX.json)"
trap 'rm -f "$OBS_TRACE"' EXIT
python -m repro.obs.smoke --out "$OBS_TRACE"
python -m repro.obs.report "$OBS_TRACE" --min-hosts 2 --min-stages 4
# Docs-rot gate: every repo path / repro.* identifier cited in
# README/docs/ROADMAP must still exist (see scripts/check_docs.py).
python scripts/check_docs.py
if [[ "${REPRO_BENCH_CHECK:-0}" == "1" ]]; then
  # bench hygiene (tcmalloc, quiet XLA logs, pinned host device count):
  # timing noise is the gate's enemy — see scripts/bench_env.sh
  source scripts/bench_env.sh
  python scripts/bench_check.py --max-n "${REPRO_BENCH_CHECK_MAX_N:-10000}"
fi
