#!/usr/bin/env bash
# Tier-1 verification: the one command run locally and in CI.
# Usage: scripts/verify.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
