"""Quickstart: the paper's algorithm end to end in two minutes on CPU.

1. build a synthetic binary dataset (AQBC-like clustered codes),
2. build a search engine by backend name (the unified SearchEngine API),
3. run exact angular KNN as ONE batched query call and verify against the
   linear-scan backend,
4. print the paper-style cost accounting (probes / verifications /
   grouped-verify launches).

Compute knobs (PR 2):

  - AMIH verifies candidates in one grouped call per (z-group, tuple
    step): ``verify_backend="numpy"`` is a single vectorized host
    popcount over all same-z queries; ``verify_backend="pallas"`` gathers
    the blocks into a padded (B_g, C_max, W) device layout and issues one
    ``verify_tuples_grouped`` kernel launch per step against the
    device-resident DB (uploaded once at build).
    ``engine.index.verify_launches`` counts dispatches.
  - The exhaustive baseline takes ``compute_backend="pallas"``: scoring
    runs through the streaming device top-K (kernels/ops.scan_topk) and
    the preselected candidates are re-ranked on host in float64, so
    results stay bit-identical to the numpy path.

Run:  PYTHONPATH=src python examples/quickstart.py
(REPRO_EXAMPLE_N overrides the DB size — the examples smoke test runs
this headless on a small n)
"""

import os
import time

import numpy as np

from repro.core import make_engine, pack_bits
from repro.data import synthetic_binary_codes, synthetic_queries


def main():
    p, n, k, B = 64, int(os.environ.get("REPRO_EXAMPLE_N", 200_000)), 10, 5
    print(f"dataset: n={n:,} codes x {p} bits, {B} queries in one batch")
    db_bits = synthetic_binary_codes(n, p, seed=0)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=1))

    t0 = time.perf_counter()
    # verify_backend="pallas" puts grouped candidate verification on
    # device (one kernel launch per z-group and tuple step); "numpy"
    # (the default) does one vectorized host popcount per step instead —
    # the right choice off-TPU, where Pallas runs in interpret mode.
    amih = make_engine("amih", db, p)
    print(f"indexed in {time.perf_counter() - t0:.2f}s "
          f"(m={amih.index.m} tables, paper's m = p/log2 n; "
          f"enumeration_cap={amih.enumeration_cap:,} = max(8n, 16384))")
    scan = make_engine("linear_scan", db, p)

    t0 = time.perf_counter()
    ids, sims, stats = amih.knn_batch(qs, k)
    t_amih = time.perf_counter() - t0

    t0 = time.perf_counter()
    ids_l, sims_l, _ = scan.knn_batch(qs, k)
    t_scan = time.perf_counter() - t0

    assert np.array_equal(sims, sims_l), "exactness violated!"
    agg = stats.aggregate()
    for i, s in enumerate(stats.per_query):
        print(f"q{i}: top-{k} sims {np.round(sims[i, :3], 4)}..., "
              f"probes={s.probes} verified={s.verified} "
              f"({s.verified / n:.2%} of db)")
    print(f"batch of {B}: AMIH {1e3 * t_amih:6.2f}ms vs scan "
          f"{1e3 * t_scan:7.2f}ms ({t_scan / max(t_amih, 1e-9):6.1f}x) | "
          f"total probes={agg['probes']} verified={agg['verified']} in "
          f"{amih.index.verify_launches} grouped verify calls")

    # the kernel-backed exhaustive baseline: device top-K preselect
    # (scan_topk; DB uploaded once, resident thereafter) + exact float64
    # host rerank — bit-identical sims, device does the heavy scan.
    scan_dev = make_engine("linear_scan", db, p, compute_backend="pallas")
    scan_dev.knn_batch(qs[:1], k)   # warm: jit compile + DB upload
    t0 = time.perf_counter()
    _, sims_d, _ = scan_dev.knn_batch(qs, k)
    t_dev = time.perf_counter() - t0
    assert np.array_equal(sims_d, sims_l), "device path exactness violated!"
    print(f"kernel-backed scan (compute_backend='pallas'): "
          f"{1e3 * t_dev:7.2f}ms, sims bit-identical")

    # pod-scale sharded backends (repro.shard): the DB row-partitioned by
    # a ShardPlan — per-shard global-id offsets, balanced remainder — and
    # served through the SAME knn_batch API. Here the plan is host-mode
    # (num_shards); on a multi-device host pass a mesh instead:
    #   from repro.launch.mesh import make_search_mesh
    #   make_engine("sharded_amih", db, p, mesh=make_search_mesh())
    from repro.shard import ShardPlan

    plan = ShardPlan.balanced(n, 8)
    print(f"shard plan: {plan.summary()}")
    sharded = make_engine("sharded_amih", db, p, plan=plan)
    t0 = time.perf_counter()
    _, sims_s, st_s = sharded.knn_batch(qs, k)
    t_sh = time.perf_counter() - t0
    assert np.array_equal(sims_s, sims_l), "sharded exactness violated!"
    early = sum(d["early_stopped"] for d in st_s.per_shard)
    print(f"sharded_amih over {st_s.shards} shards: {1e3 * t_sh:6.2f}ms, "
          f"sims bit-identical; {early} per-shard searches stopped early "
          f"(global k-th cosine bound)")
    print("all queries exact — engine('amih') == engine('linear_scan') == "
          "engine('sharded_amih'), orders faster.")


if __name__ == "__main__":
    main()
