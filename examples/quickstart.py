"""Quickstart: the paper's algorithm end to end in two minutes on CPU.

1. build a synthetic binary dataset (AQBC-like clustered codes),
2. build the AMIH index,
3. run exact angular KNN queries and verify against linear scan,
4. print the paper-style cost accounting (probes / verifications).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import AMIHIndex, AMIHStats, linear_scan_knn, pack_bits
from repro.data import synthetic_binary_codes, synthetic_queries


def main():
    p, n, k = 64, 200_000, 10
    print(f"dataset: n={n:,} codes x {p} bits")
    db_bits = synthetic_binary_codes(n, p, seed=0)
    db = pack_bits(db_bits)
    q_bits = synthetic_queries(db_bits, 5, seed=1)
    qs = pack_bits(q_bits)

    t0 = time.perf_counter()
    index = AMIHIndex.build(db, p)
    print(f"indexed in {time.perf_counter() - t0:.2f}s "
          f"(m={index.m} tables, paper's m = p/log2 n)")

    for i, q in enumerate(qs):
        stats = AMIHStats()
        t0 = time.perf_counter()
        ids, sims = index.knn(q, k, stats=stats)
        t_amih = time.perf_counter() - t0

        t0 = time.perf_counter()
        ids_l, sims_l = linear_scan_knn(q, db, k)
        t_scan = time.perf_counter() - t0

        assert np.allclose(sims, sims_l, atol=1e-9), "exactness violated!"
        print(
            f"q{i}: top-{k} sims {np.round(sims[:3], 4)}..., "
            f"AMIH {1e3 * t_amih:6.2f}ms vs scan {1e3 * t_scan:7.2f}ms "
            f"({t_scan / max(t_amih, 1e-9):6.1f}x) | probes={stats.probes} "
            f"verified={stats.verified} ({stats.verified / n:.2%} of db)"
        )
    print("all queries exact — AMIH == linear scan, orders faster.")


if __name__ == "__main__":
    main()
