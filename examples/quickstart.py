"""Quickstart: the paper's algorithm end to end in two minutes on CPU.

1. build a synthetic binary dataset (AQBC-like clustered codes),
2. build a search engine by backend name (the unified SearchEngine API),
3. run exact angular KNN as ONE batched query call and verify against the
   linear-scan backend,
4. print the paper-style cost accounting (probes / verifications).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import make_engine, pack_bits
from repro.data import synthetic_binary_codes, synthetic_queries


def main():
    p, n, k, B = 64, 200_000, 10, 5
    print(f"dataset: n={n:,} codes x {p} bits, {B} queries in one batch")
    db_bits = synthetic_binary_codes(n, p, seed=0)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=1))

    t0 = time.perf_counter()
    amih = make_engine("amih", db, p)
    print(f"indexed in {time.perf_counter() - t0:.2f}s "
          f"(m={amih.index.m} tables, paper's m = p/log2 n)")
    scan = make_engine("linear_scan", db, p)

    t0 = time.perf_counter()
    ids, sims, stats = amih.knn_batch(qs, k)
    t_amih = time.perf_counter() - t0

    t0 = time.perf_counter()
    ids_l, sims_l, _ = scan.knn_batch(qs, k)
    t_scan = time.perf_counter() - t0

    assert np.array_equal(sims, sims_l), "exactness violated!"
    agg = stats.aggregate()
    for i, s in enumerate(stats.per_query):
        print(f"q{i}: top-{k} sims {np.round(sims[i, :3], 4)}..., "
              f"probes={s.probes} verified={s.verified} "
              f"({s.verified / n:.2%} of db)")
    print(f"batch of {B}: AMIH {1e3 * t_amih:6.2f}ms vs scan "
          f"{1e3 * t_scan:7.2f}ms ({t_scan / max(t_amih, 1e-9):6.1f}x) | "
          f"total probes={agg['probes']} verified={agg['verified']}")
    print("all queries exact — engine('amih') == engine('linear_scan'), "
          "orders faster.")


if __name__ == "__main__":
    main()
