"""Retrieval serving (the paper as a production feature): an LM encodes
documents, AQBC binarizes the embeddings, AMIH serves exact angular KNN
through the STREAMING serving loop (repro.pipeline) — submit returns a
ticket whose future resolves per batch step, run_queued(stream=True)
yields results as each step completes while the next batch encodes, and
every step carries queue-depth + p50/p99 latency counters; plus the
token-serving engine answering generation requests on the same model —
encoder + generator sharing weights, as a real deployment would.

Run:  PYTHONPATH=src python examples/retrieval_serving.py
"""

import time

import jax
import numpy as np

from repro.configs import get_tiny
from repro.models import Model
from repro.serve import (
    RetrievalConfig,
    RetrievalService,
    ServeConfig,
    ServeEngine,
)


def main():
    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)

    # ---- corpus: token "documents" (deterministic synthetic) ----
    n_docs, doc_len = 400, 24
    docs = rng.integers(1, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)

    # ---- index: encode -> AQBC(64 bits) -> AMIH (pipelined serving) ----
    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=64, aqbc_iters=8, search_batch_size=2,
                        pipelined=True),
    )
    t0 = time.perf_counter()
    info = svc.build_index(docs)
    print(f"indexed {n_docs} docs in {time.perf_counter() - t0:.2f}s "
          f"(AQBC objective {info['aqbc_objective']:.3f}, "
          f"m={int(info['m_tables'])} tables)")

    # ---- exact angular search, STREAMED: submit -> tickets; results
    # ---- arrive per batch step while the next batch is still encoding
    queries = (11, 222, 7, 333)
    tickets = {qi: svc.submit(docs[qi]) for qi in queries}
    for step in svc.run_queued(k=5, stream=True):
        lat = step.stats.latency_ms
        print(f"step {step.step}: {len(step.results)} queries answered "
              f"in {step.latency_ms:.0f} ms (queue depth "
              f"{step.stats.queue_depth}, p50 {lat['p50']:.0f} ms, "
              f"p99 {lat['p99']:.0f} ms)")
    for qi, ticket in tickets.items():
        ids, sims = ticket.result()          # already resolved
        ids_l, sims_l = svc.search_linear(docs[qi], k=5)
        assert np.allclose(sims, sims_l, atol=1e-9)
        print(f"query=doc[{qi}]: hits {ids[:5].tolist()} "
              f"sims {np.round(sims[:5], 3).tolist()} (exact, streamed)")

    # single-query convenience path still returns per-query counters
    ids, sims, stats = svc.search(docs[11], k=5)
    print(f"doc[11] solo: probes={stats.probes} verified={stats.verified}")

    # ---- generation on the same weights: batched serving engine ----
    eng = ServeEngine(
        cfg, params, ServeConfig(max_batch=4, max_seq=64, max_new_tokens=8)
    )
    rids = [
        eng.submit(rng.integers(1, cfg.vocab_size, int(rng.integers(5, 15))))
        for _ in range(6)
    ]
    t0 = time.perf_counter()
    results = eng.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"generated {sum(len(v) for v in results.values())} tokens for "
          f"{len(results)} requests in {dt:.2f}s "
          f"({eng.stats['decode_steps']} batched decode steps)")
    for rid in rids[:3]:
        print(f"  request {rid}: {results[rid]}")


if __name__ == "__main__":
    main()
