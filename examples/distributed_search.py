"""Pod-scale retrieval: the DB sharded across devices (8 fake CPU devices
here; the production meshes in launch/mesh.py on TPU), queries broadcast,
local streaming top-K per shard, global merge via all_gather(K).

This is the >HBM-capacity regime of the paper's SIFT-1B experiment — the
layer AMIH hands off to when one host's index cannot hold the corpus.

Run:  PYTHONPATH=src python examples/distributed_search.py
(sets the fake-device flag itself; run as a script, not an import.
REPRO_EXAMPLE_N overrides the DB size — the examples smoke test runs
this headless on a small n)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import linear_scan_knn, pack_bits
from repro.shard import sharded_scan_topk
from repro.data import synthetic_binary_codes, synthetic_queries
from repro.launch.mesh import make_mesh


def main():
    print(f"devices: {len(jax.devices())}")
    p, n, B, k = 128, int(os.environ.get("REPRO_EXAMPLE_N", 1 << 18)), 8, 10
    db_bits = synthetic_binary_codes(n, p, seed=0)
    q_bits = synthetic_queries(db_bits, B, seed=1)
    db = jnp.asarray(pack_bits(db_bits))
    qs = jnp.asarray(pack_bits(q_bits))

    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} — "
          f"DB rows sharded over 'data' (4 shards x {n // 4:,} codes)")

    t0 = time.perf_counter()
    sims, ids = sharded_scan_topk(mesh, qs, db, k, chunk=1 << 14)
    sims.block_until_ready()
    print(f"first query batch (incl. compile): "
          f"{time.perf_counter() - t0:.2f}s")
    t0 = time.perf_counter()
    sims, ids = sharded_scan_topk(mesh, qs, db, k, chunk=1 << 14)
    sims.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"steady-state: {1e3 * dt:.1f}ms for {B} queries x {n:,} codes "
          f"({B * n / dt / 1e9:.2f} Gcomparisons/s)")

    # exactness: the sharded merge equals the single-host linear scan
    sims_h, ids_h = np.asarray(sims), np.asarray(ids)
    for b in range(B):
        ids_l, sims_l = linear_scan_knn(
            pack_bits(q_bits[b]), pack_bits(db_bits), k
        )
        np.testing.assert_allclose(
            np.sort(sims_h[b])[::-1], sims_l, atol=1e-6
        )
    print("sharded top-K == single-host linear scan for every query (exact)")


if __name__ == "__main__":
    main()
