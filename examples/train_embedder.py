"""End-to-end driver (deliverable b): train a ~100M-param LM for a few
hundred steps with the full production stack — sharded data pipeline,
AdamW, checkpointing, crash recovery, straggler watchdog — then reuse the
trained model as the retrieval encoder.

Default config is a ~100M llama-family model; --tiny shrinks it for CI.

Run:  PYTHONPATH=src python examples/train_embedder.py [--tiny] [--steps N]
"""

import argparse
import os
import tempfile

from repro.configs import get_tiny
from repro.data import DataConfig
from repro.models.common import ArchConfig
from repro.optim import OptimConfig
from repro.train import TrainConfig, Trainer, TrainerConfig


def model_100m() -> ArchConfig:
    return ArchConfig(
        name="repro-100m",
        family="dense",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_ff=2048,
        vocab_size=32_000,
        compute_dtype="float32",
        remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="CI-sized model")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_tiny("llama3_8b").replace(compute_dtype="float32") if args.tiny \
        else model_100m()
    if args.tiny:
        args.steps, args.seq_len, args.batch = 30, 64, 8
    n_params = cfg.param_count()
    print(f"model: {cfg.name} ({n_params / 1e6:.1f}M params)")

    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.gettempdir(), "repro_train_embedder"
    )
    trainer = Trainer(
        cfg=cfg,
        ocfg=OptimConfig(
            peak_lr=3e-4, warmup_steps=min(50, args.steps // 5),
            decay_steps=args.steps,
        ),
        tcfg=TrainConfig(microbatches=2),
        rcfg=TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=max(10, args.steps // 5),
            checkpoint_dir=ckpt_dir,
            log_every=10,
        ),
        data_cfg=DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.batch,
        ),
    )
    out = trainer.run()
    losses = out["losses"]
    print(f"steps: {out['final_step']}  restarts: {out['restarts']}")
    head = sum(losses[:10]) / min(10, len(losses))
    tail = sum(losses[-10:]) / min(10, len(losses))
    print(f"loss: first10 {head:.4f} -> last10 {tail:.4f}")
    assert tail < head, "training must reduce loss"
    print(f"checkpoints in {ckpt_dir} "
          f"(restart this script — it resumes bit-exactly)")


if __name__ == "__main__":
    main()
