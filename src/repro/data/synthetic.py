"""Synthetic datasets for the retrieval experiments (paper §6).

The paper evaluates on SIFT (10^6–10^9 128-D descriptors) and TRC2
(word-count vectors). Neither raw dataset ships with this container, so the
benchmarks use deterministic synthetic stand-ins with matched statistics:

- ``clustered_features``: non-negative, heavy-tailed, cluster-structured
  vectors (SIFT-like: gradients histograms are non-negative and clumpy;
  TRC2-like: word counts are non-negative and sparse). Cluster structure is
  what gives hashing/LSH methods non-trivial recall curves — i.i.d. data
  would make every method look artificially bad.
- ``synthetic_binary_codes``: codes drawn either uniformly or by planting
  near-duplicate clusters, for exercising AMIH directly in binary space.

All generation is seeded and reproducible.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "clustered_features",
    "synthetic_binary_codes",
    "synthetic_queries",
]


def clustered_features(
    n: int,
    dim: int = 128,
    n_clusters: int = 64,
    seed: int = 0,
    noise: float = 0.25,
) -> np.ndarray:
    """Non-negative cluster-structured feature vectors, (n, dim) float32."""
    rng = np.random.default_rng(seed)
    centers = rng.gamma(shape=2.0, scale=1.0, size=(n_clusters, dim))
    assign = rng.integers(0, n_clusters, n)
    x = centers[assign] + noise * rng.gamma(2.0, 1.0, size=(n, dim))
    return np.maximum(x, 0.0).astype(np.float32)


def synthetic_binary_codes(
    n: int,
    p: int,
    seed: int = 0,
    mode: str = "clustered",
    n_clusters: int = 256,
    flip_prob: float = 0.08,
) -> np.ndarray:
    """(n, p) uint8 binary dataset.

    mode='uniform':   i.i.d. Bernoulli(1/2) bits (worst case for hashing).
    mode='clustered': cluster centers with per-bit flip noise — matches the
                      hashed-descriptor regime the paper targets (AQBC codes
                      of natural data are highly clustered).
    """
    rng = np.random.default_rng(seed)
    if mode == "uniform":
        return (rng.random((n, p)) < 0.5).astype(np.uint8)
    centers = (rng.random((n_clusters, p)) < 0.5).astype(np.uint8)
    assign = rng.integers(0, n_clusters, n)
    flips = (rng.random((n, p)) < flip_prob).astype(np.uint8)
    return centers[assign] ^ flips


def synthetic_queries(
    db_bits: np.ndarray,
    n_queries: int,
    seed: int = 1,
    flip_prob: float = 0.05,
) -> np.ndarray:
    """Queries near dataset items (realistic ANN workload): perturb random
    db rows by i.i.d. bit flips."""
    rng = np.random.default_rng(seed)
    n, p = db_bits.shape
    rows = rng.integers(0, n, n_queries)
    flips = (rng.random((n_queries, p)) < flip_prob).astype(np.uint8)
    return db_bits[rows] ^ flips
