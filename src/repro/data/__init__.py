"""Data substrate: deterministic synthetic pipelines with checkpointable,
shard-aware iterators (token streams for LM training; feature/code streams
for the retrieval experiments)."""

from .pipeline import DataConfig, TokenPipeline
from .synthetic import (
    clustered_features,
    synthetic_binary_codes,
    synthetic_queries,
)

__all__ = [
    "DataConfig",
    "TokenPipeline",
    "clustered_features",
    "synthetic_binary_codes",
    "synthetic_queries",
]
