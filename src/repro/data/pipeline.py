"""Deterministic, shard-aware, checkpointable token pipeline.

Production posture: each host process reads only the examples assigned to
its data shard (``shard_id`` of ``num_shards``); the stream is a pure
function of (seed, step) via counter-based hashing, so

  - restarts are bit-exact: restoring ``state_dict()`` resumes mid-epoch
    without replay,
  - elastic re-sharding is exact: a host joining with a different
    (shard_id, num_shards) still sees a disjoint, complete partition,
  - no host ever materializes the global batch.

The "dataset" is a deterministic synthetic LM corpus: a fixed mixture of
Zipfian unigram draws and repeated-motif spans (so models have learnable
structure and losses visibly fall — used by the train examples/tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "TokenPipeline"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2          # unigram skew
    motif_len: int = 16          # repeated-span length (learnable structure)
    motif_prob: float = 0.5      # fraction of rows carrying a motif


def _philox(counters: np.ndarray, seed: int) -> np.ndarray:
    """Counter-based pseudo-random uint64 stream (stateless, vectorized).

    splitmix64 over (counter ^ seed) — deterministic across hosts and
    restores without carrying RNG state.
    """
    x = (counters.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)) ^ np.uint64(seed)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


class TokenPipeline:
    """Iterator of per-shard batches: dict(tokens=(B_local, S) int32).

    B_local = global_batch // num_shards. The stream position is one
    integer (``step``); ``state_dict``/``load_state_dict`` checkpoint it.
    """

    def __init__(
        self,
        cfg: DataConfig,
        shard_id: int = 0,
        num_shards: int = 1,
        start_step: int = 0,
    ):
        if cfg.global_batch % num_shards:
            raise ValueError(
                f"global_batch={cfg.global_batch} not divisible by "
                f"num_shards={num_shards}"
            )
        self.cfg = cfg
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.step = start_step
        self._local_batch = cfg.global_batch // num_shards
        # Zipfian unigram table (shared, deterministic)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._cdf = np.cumsum(probs / probs.sum())

    # ------------------------------------------------------------- state
    def state_dict(self) -> Dict[str, int]:
        return {
            "step": self.step,
            "seed": self.cfg.seed,
            "shard_id": self.shard_id,
            "num_shards": self.num_shards,
        }

    def load_state_dict(self, state: Dict[str, int]) -> None:
        if state["seed"] != self.cfg.seed:
            raise ValueError("checkpoint seed mismatch")
        # shard geometry may legally change on elastic resize; only the
        # global step must carry over.
        self.step = int(state["step"])

    # ------------------------------------------------------------ batches
    def _row_tokens(self, row_counters: np.ndarray) -> np.ndarray:
        """(R,) uint64 row ids -> (R, S) int32 tokens, fully vectorized."""
        cfg = self.cfg
        R, S = row_counters.shape[0], cfg.seq_len
        # one u64 per (row, position)
        pos = np.arange(S, dtype=np.uint64)[None, :]
        ctr = row_counters[:, None] * np.uint64(1_000_003) + pos
        u = _philox(ctr, cfg.seed)
        uni = (u >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        tokens = np.searchsorted(self._cdf, uni).astype(np.int32)
        tokens = np.clip(tokens, 0, cfg.vocab_size - 1)
        # motif rows: overwrite a span with a periodic repetition
        hrow = _philox(row_counters, cfg.seed ^ 0xABCDEF)
        has_motif = (hrow % np.uint64(1000)) < np.uint64(
            int(cfg.motif_prob * 1000)
        )
        if cfg.motif_len > 0 and S >= 2 * cfg.motif_len:
            start = (hrow % np.uint64(max(1, S - 2 * cfg.motif_len))).astype(
                np.int64
            )
            motif_tok = (hrow % np.uint64(cfg.vocab_size)).astype(np.int32)
            for r in np.flatnonzero(has_motif):
                s0 = int(start[r])
                motif = (
                    motif_tok[r]
                    + np.arange(cfg.motif_len, dtype=np.int32)
                ) % cfg.vocab_size
                tokens[r, s0 : s0 + 2 * cfg.motif_len] = np.concatenate(
                    [motif, motif]
                )
        return tokens

    def next_batch(self) -> Dict[str, np.ndarray]:
        """The shard's slice of global batch ``self.step`` (advances step)."""
        cfg = self.cfg
        base = np.uint64(self.step) * np.uint64(cfg.global_batch)
        rows = base + np.uint64(self.shard_id * self._local_batch) + np.arange(
            self._local_batch, dtype=np.uint64
        )
        tokens = self._row_tokens(rows)
        self.step += 1
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -------------------------------------------------- global batch view
    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The FULL batch of one step (tests / single-host training)."""
        cfg = self.cfg
        base = np.uint64(step) * np.uint64(cfg.global_batch)
        rows = base + np.arange(cfg.global_batch, dtype=np.uint64)
        return {"tokens": self._row_tokens(rows)}
