"""Version-guarded aliases for jax APIs that moved between releases.

The repo targets current jax but must run on whatever the container
pins. Import moved/renamed symbols from here instead of guarding at each
call site. (jax.sharding.AxisType has its own guard in launch/mesh.py.)
"""

from __future__ import annotations

import jax

try:  # jax >= 0.4.38 exposes the with-path helpers on jax.tree
    tree_flatten_with_path = jax.tree.flatten_with_path
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.tree_util import tree_flatten_with_path  # noqa: F401

try:  # newer jax promotes shard_map out of experimental
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import (
        shard_map as _shard_map_experimental,
    )

    def shard_map(f, **kwargs):
        """experimental.shard_map, accepting the modern kwarg spelling
        (check_vma was named check_rep before the promotion)."""
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_experimental(f, **kwargs)

def axis_size(axis_name):
    """jax.lax.axis_size, or the psum(1) identity on jax without it."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis_dict(compiled):
    """compiled.cost_analysis() returned [dict] before jax 0.5, dict after."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        return ca[0] if ca else {}
    return ca or {}


__all__ = [
    "axis_size",
    "cost_analysis_dict",
    "shard_map",
    "tree_flatten_with_path",
]
