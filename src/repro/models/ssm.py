"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training path: chunked SSD. Within a chunk the recurrence is materialized
as a decay-masked attention-like quadratic form (matmul-friendly, MXU
work); across chunks a short sequential scan carries the (H, P, N) state.
Chunk length trades VMEM/HBM working set (the (B, nc, H, Q, Q) decay mask
is the largest intermediate) against scan length — a hillclimb lever.

Decode path: O(1) recurrent state update per token — this is what makes
long_500k feasible for the ssm/hybrid architectures.

Group count G=1 (B/C shared across heads), matching the mamba2-1.3b config.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import rmsnorm


class SSMState(NamedTuple):
    conv: jax.Array   # (B, conv_width-1, conv_dim)
    ssm: jax.Array    # (B, H, P, N) float32


def ssm_dims(cfg):
    H = cfg.ssm_heads_
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    d_inner = H * P
    conv_dim = d_inner + 2 * N            # x, B, C are convolved
    d_in_proj = 2 * d_inner + 2 * N + H   # z, xBC, dt
    return H, P, N, d_inner, conv_dim, d_in_proj


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array):
    """x (B,S,C), w (K,C), b (C,): causal depthwise conv along S."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K is 4: static unroll
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def _segsum(alpha: jax.Array) -> jax.Array:
    """alpha (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j<t<=i} alpha_t,
    -inf above the diagonal (exclusive-of-j, inclusive-of-i segment sums)."""
    Q = alpha.shape[-1]
    cs = jnp.cumsum(alpha, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssm_forward(
    x: jax.Array, params, cfg, chunk: int = 128, return_state: bool = False
):
    """Full-sequence SSD: (B, S, D) -> (B, S, D) [, final SSMState].

    ``return_state`` also returns the recurrent state after the last real
    token, so decode can continue exactly where prefill stopped.
    """
    with jax.named_scope("ssd"):
        return _ssm_forward_impl(x, params, cfg, chunk, return_state)


def _ssm_forward_impl(x, params, cfg, chunk=128, return_state=False):
    H, P, N, d_inner, conv_dim, _ = ssm_dims(cfg)
    B, S, D = x.shape
    cdt = x.dtype

    proj = x @ params["in_proj"].astype(cdt)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner : d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim :]

    xBC = jax.nn.silu(
        _causal_depthwise_conv(
            xBC, params["conv_w"].astype(cdt), params["conv_b"].astype(cdt)
        )
    )
    xs = xBC[..., :d_inner]
    B_ = xBC[..., d_inner : d_inner + N].astype(jnp.float32)
    C_ = xBC[..., d_inner + N :].astype(jnp.float32)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    alpha = dt * A[None, None, :]                     # (B,S,H) (<0)

    # ---- chunking ----
    Q = min(chunk, S)
    nc = -(-S // Q)
    Sp = nc * Q
    pad = ((0, 0), (0, Sp - S))
    xs_c = jnp.pad(xs, pad + ((0, 0),)).reshape(B, nc, Q, H, P)
    B_c = jnp.pad(B_, pad + ((0, 0),)).reshape(B, nc, Q, N)
    C_c = jnp.pad(C_, pad + ((0, 0),)).reshape(B, nc, Q, N)
    dt_c = jnp.pad(dt, pad + ((0, 0),)).reshape(B, nc, Q, H)
    al_c = jnp.pad(alpha, pad + ((0, 0),)).reshape(B, nc, Q, H)

    xdt = (xs_c.astype(jnp.float32)) * dt_c[..., None]   # dt-discretized input

    # intra-chunk (quadratic, decay-masked)
    L = jnp.exp(_segsum(jnp.moveaxis(al_c, -1, 2)))       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)      # (B,nc,Q,Q)
    y_diag = jnp.einsum(
        "bcij,bchij,bcjhp->bcihp", scores, L, xdt,
        preferred_element_type=jnp.float32,
    )

    # chunk states: decay from step j to end of chunk
    cum = jnp.cumsum(al_c, axis=2)                        # (B,nc,Q,H)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,Q,H)
    states = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchpn", B_c, decay_to_end, xdt,
        preferred_element_type=jnp.float32,
    )                                                     # (B,nc,H,P,N)

    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def step(h, inp):
        st, dec = inp                                     # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h                                   # emit state *before* chunk

    h0 = jnp.zeros((B, H, P, N), dtype=jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # (B,nc,H,P,N)

    y_off = jnp.einsum(
        "bcin,bchpn,bcih->bcihp", C_c, h_prev, jnp.exp(cum),
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(B, Sp, H, P)[:, :S]
    y = y + xs.reshape(B, S, H, P).astype(jnp.float32) * params["D_skip"].astype(
        jnp.float32
    )[None, None, :, None]
    y = y.reshape(B, S, d_inner)

    # gated RMSNorm + out projection (mamba2's NormGated)
    y = y.astype(cdt) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_scale"])
    out = y @ params["out_proj"].astype(cdt)
    if not return_state:
        return out
    # conv tail: last (K-1) pre-activation conv inputs, zero-padded on the
    # left for sequences shorter than the window
    K = cfg.conv_width
    pre_conv = proj[..., d_inner : d_inner + conv_dim]
    tail = jnp.pad(pre_conv, ((0, 0), (K - 1, 0), (0, 0)))[:, S : S + K - 1, :]
    # NOTE: pad-region chunks contribute zero to states (xdt=0 there), but
    # their decay still multiplies h; recompute the true last-token state:
    # padded steps have xs=0 yet alpha<0, so h_last is h(S_p) = h(S) scaled
    # by the pad decay. Undo it exactly:
    pad_steps = Sp - S
    if pad_steps:
        pad_alpha = al_c.reshape(B, Sp, H)[:, S:, :].sum(axis=1)  # (B,H)
        h_last = h_last / jnp.exp(pad_alpha)[:, :, None, None]
    return out, SSMState(conv=tail.astype(cdt), ssm=h_last)


def ssm_init_state(cfg, batch: int, dtype=jnp.float32) -> SSMState:
    H, P, N, d_inner, conv_dim, _ = ssm_dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype=dtype),
        ssm=jnp.zeros((batch, H, P, N), dtype=jnp.float32),
    )


def ssm_decode_step(
    x: jax.Array, state: SSMState, params, cfg
) -> Tuple[jax.Array, SSMState]:
    """One-token recurrent update: x (B, 1, D) -> (B, 1, D)."""
    H, P, N, d_inner, conv_dim, _ = ssm_dims(cfg)
    B = x.shape[0]
    cdt = x.dtype
    xt = x[:, 0, :]

    proj = xt @ params["in_proj"].astype(cdt)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner : d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim :]

    window = jnp.concatenate(
        [state.conv.astype(cdt), xBC[:, None, :]], axis=1
    )                                                  # (B, K, conv_dim)
    conv_out = (
        jnp.einsum("bkc,kc->bc", window, params["conv_w"].astype(cdt))
        + params["conv_b"].astype(cdt)[None, :]
    )
    new_conv = window[:, 1:, :]
    xBC = jax.nn.silu(conv_out)
    xs = xBC[..., :d_inner].reshape(B, H, P).astype(jnp.float32)
    B_ = xBC[..., d_inner : d_inner + N].astype(jnp.float32)
    C_ = xBC[..., d_inner + N :].astype(jnp.float32)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])                       # (B,H)

    xdt = xs * dt[..., None]                           # (B,H,P)
    h = state.ssm * a[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", xdt, B_)
    y = jnp.einsum("bhpn,bn->bhp", h, C_)
    y = y + xs * params["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, d_inner).astype(cdt) * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_scale"])
    out = (y @ params["out_proj"].astype(cdt))[:, None, :]
    return out, SSMState(conv=new_conv.astype(state.conv.dtype), ssm=h)


def ssm_init_params(cfg, key, dtype):
    H, P, N, d_inner, conv_dim, d_in_proj = ssm_dims(cfg)
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    std = D ** -0.5
    dt_min, dt_max = 1e-3, 1e-1
    u = jax.random.uniform(k3, (H,), minval=jnp.log(dt_min), maxval=jnp.log(dt_max))
    dt_init = jnp.exp(u)
    # inverse softplus so softplus(dt_bias) ~= dt_init
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": (jax.random.normal(k1, (D, d_in_proj)) * std).astype(dtype),
        "conv_w": (jax.random.normal(k2, (cfg.conv_width, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H)
        ).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": (
            jax.random.normal(k2, (d_inner, D)) * (d_inner ** -0.5)
        ).astype(dtype),
    }


def ssm_param_shapes(cfg):
    """(shape, logical_axes, dtype_kind) per parameter; dtype_kind 'p'=param
    dtype, 'f'=float32 (small numerically-sensitive vectors)."""
    H, P, N, d_inner, conv_dim, d_in_proj = ssm_dims(cfg)
    D = cfg.d_model
    return {
        "in_proj": ((D, d_in_proj), ("embed", "ssm_inner"), "p"),
        "conv_w": ((cfg.conv_width, conv_dim), ("conv_width", "ssm_inner"), "p"),
        "conv_b": ((conv_dim,), ("ssm_inner",), "p"),
        "dt_bias": ((H,), ("ssm_heads",), "f"),
        "A_log": ((H,), ("ssm_heads",), "f"),
        "D_skip": ((H,), ("ssm_heads",), "f"),
        "norm_scale": ((d_inner,), ("ssm_inner",), "p"),
        "out_proj": ((d_inner, D), ("ssm_inner", "embed"), "p"),
    }
