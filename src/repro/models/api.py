"""Family-dispatching model API: one call surface for all architectures.

    model = Model(cfg)
    params = model.init_params(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, cache, tokens, pos)

``input_specs(cfg, shape)`` builds the allocation-free ShapeDtypeStruct
inputs for every (arch x shape) dry-run cell, including the stubbed
modality frontends (vlm patch embeddings, whisper mel frames).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import encdec, lm
from .common import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------ params
    def init_params(self, key):
        return lm.init_params(self.cfg, key)

    def param_specs(self):
        return lm.param_specs(self.cfg)

    def logical_axes(self):
        return lm.logical_axes(self.cfg)

    # ------------------------------------------------------------- train
    def loss(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.loss_fn(self.cfg, params, batch)
        return lm.loss_fn(self.cfg, params, batch)

    def forward(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.forward(self.cfg, params, batch)
        return lm.forward(self.cfg, params, batch)

    # ------------------------------------------------------------- serve
    def prefill(self, params, batch):
        if self.cfg.family == "encdec":
            return encdec.prefill(self.cfg, params, batch)
        return lm.prefill(self.cfg, params, batch)

    def decode_step(self, params, cache, tokens, pos):
        if self.cfg.family == "encdec":
            return encdec.decode_step(self.cfg, params, cache, tokens, pos)
        return lm.decode_step(self.cfg, params, cache, tokens, pos)

    def cache_template(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            return encdec.cache_template(self.cfg, batch, max_seq)
        return lm.cache_template(self.cfg, batch, max_seq)

    def init_cache(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            return encdec.init_cache(self.cfg, batch, max_seq)
        return lm.init_cache(self.cfg, batch, max_seq)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct inputs for one dry-run cell (no allocation).

    train:   full-sequence batch for train_step
    prefill: full-sequence batch for prefill
    decode:  one-token batch + positions for serve_step (cache comes from
             Model.cache_template at seq_len)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            n_vis = cfg.vision_tokens
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - n_vis), i32),
                "vision_embeds": jax.ShapeDtypeStruct(
                    (B, n_vis, cfg.d_model), cfg.cdtype()
                ),
            }
        if cfg.family == "encdec":
            return {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "enc_frames": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), cfg.cdtype()
                ),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a seq_len-sized cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


INPUT_LOGICAL_AXES = {
    "tokens": ("batch", "seq"),
    "vision_embeds": ("batch", "vision_seq", "embed"),
    "enc_frames": ("batch", "enc_seq", "embed"),
}
