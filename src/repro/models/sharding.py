"""Logical-axis sharding: rules table + activation hint mechanism.

Parameters and key activations are tagged with *logical* axis names. A rules
table maps logical names to mesh axes. ``resolve_spec`` drops any mesh axis
that does not evenly divide the corresponding dim — so every architecture in
the pool lowers on every mesh without padding hacks; each drop is recorded
for the dry-run report.

Models call ``shard_hint(x, "batch", "seq", "embed")``; outside an active
mesh context this is the identity, so smoke tests on one device never touch
device state.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Default production rules: DP over pod+data, TP/EP over model.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    # dispatch buffer (E, C, D): E over model (EP), C over the data axes —
    # without this the per-device buffer at kimi-k2 train scale is ~9 TB
    "expert_capacity": ("pod", "data"),
    "vocab": "model",
    "layers": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_width": None,
    "kv_seq": None,
    "enc_seq": None,
    "vision_seq": None,
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None
    log: Optional[list] = None


_CTX = _Ctx()


@contextmanager
def sharding_context(mesh: Mesh, rules: Optional[Rules] = None, log: Optional[list] = None):
    """Activate a mesh + rules table for shard_hint / make_sharding."""
    prev = (_CTX.mesh, _CTX.rules, _CTX.log)
    _CTX.mesh, _CTX.rules, _CTX.log = mesh, dict(rules or DEFAULT_RULES), log
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.log = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def resolve_spec(
    mesh: Mesh,
    rules: Rules,
    dims: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    log: Optional[list] = None,
    what: str = "",
) -> P:
    """Logical axes -> PartitionSpec, dropping non-dividing mesh axes."""
    assert len(dims) == len(logical_axes), (dims, logical_axes)
    used: set = set()
    out = []
    for dim, name in zip(dims, logical_axes):
        target = rules.get(name) if name is not None else None
        if target is None:
            out.append(None)
            continue
        axes = (target,) if isinstance(target, str) else tuple(target)
        kept = []
        size = 1
        for ax in axes:
            if ax not in mesh.axis_names or ax in used:
                continue
            ax_size = mesh.shape[ax]
            if dim % (size * ax_size) == 0:
                kept.append(ax)
                size *= ax_size
            elif log is not None:
                log.append(
                    f"drop {ax} from {what}:{name} (dim {dim} % {size * ax_size} != 0)"
                )
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def make_sharding(dims, logical_axes, what: str = "") -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    spec = resolve_spec(_CTX.mesh, _CTX.rules, dims, logical_axes, _CTX.log, what)
    return NamedSharding(_CTX.mesh, spec)


def shard_hint(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint driven by the active rules; identity if none."""
    if _CTX.mesh is None:
        return x
    sh = make_sharding(x.shape, logical_axes, what="act")
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
