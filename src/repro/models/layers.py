"""Core layers: norms, RoPE, blocked (flash-style) attention, MLPs.

All attention here is pure-JAX blockwise online-softmax: memory is
O(q_chunk * kv_chunk) per (batch, head) instead of O(S^2), which is what
lets prefill_32k lower without materializing a 32k x 32k score matrix.
Sharding is induced from the operands (heads sharded on `model`, batch on
`data`/`pod`); XLA/GSPMD propagates through the scans.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------ norms
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def layernorm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) -> cos/sin (..., head_dim/2), float32."""
    half = head_dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ------------------------------------------------- sinusoidal (whisper enc)
def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------------- MLPs
def mlp(x: jax.Array, params, activation: str) -> jax.Array:
    """Gated/ungated feed-forward. Weights: wi[, wi_gate], wo."""
    cdt = x.dtype
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        h = act(x @ params["wi_gate"].astype(cdt)) * (
            x @ params["wi"].astype(cdt)
        )
    else:  # gelu
        h = jax.nn.gelu(x @ params["wi"].astype(cdt), approximate=True)
    return h @ params["wo"].astype(cdt)


# ------------------------------------------------------- blocked attention
def _chunk_mask(
    q_pos: jax.Array, k_pos: jax.Array, causal: bool, window: int
) -> jax.Array:
    """(Sq, Sk) additive mask in float32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def blocked_attention(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX.

    Returns (B, Sq, Hq, D). GQA is handled by reshaping Hq = Hkv * G.
    ``q_offset`` shifts query positions (prefill continuation / decode).

    The whole computation runs under ``named_scope("flash_attn")`` so the
    roofline HLO parser can attribute its HBM traffic (and model the fused
    Pallas kernel replacing it on TPU — see kernels/flash_attention.py).

    On TPU (``use_kernel=None`` -> auto) the forward runs the fused Pallas
    kernel; backward recomputes through this pure-JAX path (custom_vjp).
    Elsewhere the pure-JAX path runs both ways — it is also the kernel's
    correctness oracle.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    with jax.named_scope("flash_attn"):
        if use_kernel and q_offset == 0:
            return _flash_fwd_oracle_bwd(
                q, k, v, causal, window, q_chunk, kv_chunk
            )
        return _blocked_attention_impl(
            q, k, v, causal=causal, q_offset=q_offset, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_fwd_oracle_bwd(q, k, v, causal, window, q_chunk, kv_chunk):
    from ..kernels.flash_attention import flash_attention

    return flash_attention(
        q, k, v, causal=causal, window=window,
        interpret=jax.default_backend() != "tpu",
    )


def _ffob_fwd(q, k, v, causal, window, q_chunk, kv_chunk):
    return (
        _flash_fwd_oracle_bwd(q, k, v, causal, window, q_chunk, kv_chunk),
        (q, k, v),
    )


def _ffob_bwd(causal, window, q_chunk, kv_chunk, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _blocked_attention_impl(
            q_, k_, v_, causal=causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        ),
        q, k, v,
    )
    return vjp(g)


_flash_fwd_oracle_bwd.defvjp(_ffob_fwd, _ffob_bwd)


def _blocked_attention_impl(
    q, k, v, *, causal, q_offset=0, window=0, q_chunk=1024, kv_chunk=1024
):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    Sq_p, Sk_p = nq * q_chunk, nk * kv_chunk

    qf = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))

    # (B, nq, qc, Hkv, G, D)
    qf = qf.reshape(B, nq, q_chunk, Hkv, G, D)
    kf = kf.reshape(B, nk, kv_chunk, Hkv, D)
    vf = vf.reshape(B, nk, kv_chunk, Hkv, D)

    q_pos_all = jnp.arange(Sq_p) + q_offset
    k_pos_all = jnp.arange(Sk_p)
    k_valid_all = k_pos_all < Sk

    def per_q_chunk(qi, q_blk):
        q_pos = jax.lax.dynamic_slice_in_dim(q_pos_all, qi * q_chunk, q_chunk)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, ki = inp
            k_pos = jax.lax.dynamic_slice_in_dim(k_pos_all, ki * kv_chunk, kv_chunk)
            k_val = jax.lax.dynamic_slice_in_dim(k_valid_all, ki * kv_chunk, kv_chunk)
            # scores: (B, qc, Hkv, G, kc)
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = _chunk_mask(q_pos, k_pos, causal, window)
            mask = jnp.where(k_val[None, :], mask, NEG_INF)
            s = s + mask[None, :, None, None, :]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, q_chunk, Hkv, G), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hkv, G), dtype=jnp.float32)
        a0 = jnp.zeros((B, q_chunk, Hkv, G, D), dtype=jnp.float32)
        ks = (kf, vf, jnp.arange(nk))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B, qc, Hkv, G, D)

    outs = jax.lax.map(
        lambda i: per_q_chunk(i, jax.lax.dynamic_index_in_dim(jnp.moveaxis(qf, 1, 0), i, 0, keepdims=False)),
        jnp.arange(nq),
    )  # (nq, B, qc, Hkv, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, Hq, D)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,             # (B, 1, Hq, D)
    k_cache: jax.Array,       # (B, S, Hkv, D)
    v_cache: jax.Array,       # (B, S, Hkv, D)
    cache_len: jax.Array,     # scalar int32: #tokens written so far
    *,
    window: int = 0,
    ring: bool = False,
    use_kernel: bool | None = None,
) -> jax.Array:
    """Single-token attention over a linear or ring-buffer KV cache.

    Linear cache: slots [0, cache_len) are valid; optional sliding-window
    mask keeps the last ``window`` positions. Ring cache (slot = pos % S):
    slots [0, min(cache_len, S)) are valid and are by construction exactly
    the last <= S == window positions, so no extra mask is needed.

    Runs under ``named_scope("decode_attn")`` for roofline attribution.
    On TPU the linear-cache path uses the fused flash-decode kernel
    (kernels/flash_attention.py, ``valid_len``): one pass over the cache,
    scores never leave VMEM.
    """
    with jax.named_scope("decode_attn"):
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        if use_kernel and not ring:
            from ..kernels.flash_attention import flash_attention

            return flash_attention(
                q, k_cache, v_cache,
                causal=False,
                window=window,
                valid_len=cache_len,
                interpret=jax.default_backend() != "tpu",
            )
        return _decode_attention_impl(
            q, k_cache, v_cache, cache_len, window=window, ring=ring
        )


def _decode_attention_impl(
    q, k_cache, v_cache, cache_len, *, window=0, ring=False
):
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qh = q.reshape(B, Hkv, G, D)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qh, k_cache, preferred_element_type=jnp.float32
    ) * (D ** -0.5)
    k_pos = jnp.arange(S)
    ok = k_pos < cache_len
    if window > 0 and not ring:
        ok &= k_pos >= cache_len - window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
