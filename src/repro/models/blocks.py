"""Transformer block assembly for every architecture family.

One ``block_forward`` handles the full-sequence path (train / prefill) and
``block_decode`` the single-token path, switching on the family:

  dense   x += attn(norm(x));  x += mlp(norm(x))
  moe     x += attn(norm(x));  x += moe(norm(x)) [+ dense-residual mlp]
  ssm     x += ssd(norm(x))                         (no MLP when d_ff == 0)
  hybrid  x += g_a*attn(norm(x)) + g_m*ssd(norm(x)); x += mlp(norm(x))

Caches are NamedTuples so layer-stacked pytrees scan cleanly.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (
    apply_norm,
    apply_rope,
    blocked_attention,
    decode_attention,
    mlp,
    rope_angles,
)
from .sharding import shard_hint


class AttnCache(NamedTuple):
    k: jax.Array    # (B, S_max, Hkv, Dh)
    v: jax.Array


class LayerCache(NamedTuple):
    attn: Optional[AttnCache]
    ssm: Optional[ssm_lib.SSMState]


# ------------------------------------------------------------- attention
def _attn_proj(x, p, cfg):
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cdt))
    return q, k, v


def attention_full(
    x, p, cfg, positions, *, causal=True, window=0, kv_override=None
):
    """Full-sequence attention; returns (out, (k, v)) for cache building.

    ``p`` is the attention subdict {wq, wk, wv, wo}."""
    q, k, v = _attn_proj(x, p, cfg)
    if kv_override is not None:          # cross-attention: kv from encoder
        k, v = kv_override
        kv_pos = jnp.arange(k.shape[1])
    else:
        kv_pos = positions
    if cfg.rope_theta > 0 and kv_override is None:
        cos_q, sin_q = rope_angles(positions, cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_q, sin_q)
    q = shard_hint(q, "batch", "seq", "q_heads", "head_dim")
    k = shard_hint(k, "batch", "seq", "kv_heads", "head_dim")
    out = blocked_attention(
        q, k, v,
        causal=causal,
        window=window,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, (k, v)


def attention_decode(x, p, cfg, cache: AttnCache, pos, *, window=0):
    """Single-token attention with cache update at position ``pos``.

    If the cache is a ring buffer (its length equals the sliding window,
    shorter than the sequence), writes go to slot ``pos % len`` and keys
    carry RoPE at their absolute positions, so relative phases stay exact.
    """
    q, k, v = _attn_proj(x, p, cfg)      # (B,1,H,Dh)
    if cfg.rope_theta > 0:
        posv = jnp.full((1,), pos)
        cos, sin = rope_angles(posv, cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    s_cache = cache.k.shape[1]
    ring = bool(window) and s_cache == min(s_cache, window)
    slot = pos % s_cache if ring else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k.astype(cache.k.dtype), slot, axis=1
    )
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v.astype(cache.v.dtype), slot, axis=1
    )
    out = decode_attention(q, new_k, new_v, pos + 1, window=window, ring=ring)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, AttnCache(k=new_k, v=new_v)


def cross_attention_decode(x, p, cfg, cross_k, cross_v):
    """Decoder-side cross-attention against precomputed encoder KV."""
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    out = decode_attention(q, cross_k, cross_v, cross_k.shape[1])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))


# ------------------------------------------------------------ ffn variants
def _ffn(x, p, cfg):
    """Dense MLP / MoE / MoE+dense-residual, on (B, S, D)."""
    if not cfg.is_moe:
        with jax.named_scope("mlp"):
            return mlp(x, p["mlp"], cfg.activation), {}
    B, S, D = x.shape
    with jax.named_scope("moe"):
        out, aux = moe_lib.moe_block(
            x.reshape(B * S, D),
            p["moe"],
            top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            activation=cfg.activation,
        )
    out = out.reshape(B, S, D)
    if cfg.moe_dense_residual_ff:
        out = out + mlp(x, p["moe_dense"], cfg.activation)
    return out, aux


def _ffn_params_subset(p):
    return p  # moe/mlp weights live flat in the layer dict


# --------------------------------------------------------------- full pass
def block_forward(
    cfg, p, x, positions, *, window=0, build_cache=False, moe_layer=True,
    causal=True,
):
    """One layer, full-sequence. Returns (x, aux, cache_or_None)."""
    aux = {}
    cache = None
    h = apply_norm(x, p["ln1"], cfg.norm)
    h = shard_hint(h, "batch", "seq", "embed")

    if cfg.family == "ssm":
        if build_cache:
            out, ssm_state = ssm_lib.ssm_forward(h, p["ssm"], cfg, return_state=True)
            cache = LayerCache(attn=None, ssm=ssm_state)
        else:
            out = ssm_lib.ssm_forward(h, p["ssm"], cfg)
        x = x + out
        return x, aux, cache

    if cfg.family == "hybrid":
        attn_out, kv = attention_full(
            h, p["attn"], cfg, positions, causal=causal, window=window
        )
        if build_cache:
            ssm_out, ssm_state = ssm_lib.ssm_forward(
                h, p["ssm"], cfg, return_state=True
            )
        else:
            ssm_out = ssm_lib.ssm_forward(h, p["ssm"], cfg)
            ssm_state = None
        x = x + p["fuse_attn"].astype(x.dtype) * attn_out \
              + p["fuse_ssm"].astype(x.dtype) * ssm_out
        if build_cache:
            cache = LayerCache(
                attn=AttnCache(k=kv[0], v=kv[1]), ssm=ssm_state
            )
    else:
        attn_out, kv = attention_full(
            h, p["attn"], cfg, positions, causal=causal, window=window
        )
        x = x + attn_out
        if build_cache:
            cache = LayerCache(attn=AttnCache(k=kv[0], v=kv[1]), ssm=None)

    if cfg.d_ff > 0 or cfg.is_moe:
        h2 = apply_norm(x, p["ln2"], cfg.norm)
        h2 = shard_hint(h2, "batch", "seq", "embed")
        ffn_out, aux = (
            _ffn(h2, p, cfg)
            if moe_layer
            else (mlp(h2, p["mlp"], cfg.activation), {})
        )
        x = x + ffn_out
    x = shard_hint(x, "batch", "seq", "embed")
    return x, aux, cache


# -------------------------------------------------------------- decode pass
def block_decode(cfg, p, x, cache: LayerCache, pos, *, window=0):
    """One layer, one token. Returns (x, new_cache)."""
    h = apply_norm(x, p["ln1"], cfg.norm)

    if cfg.family == "ssm":
        out, new_ssm = ssm_lib.ssm_decode_step(h, cache.ssm, p["ssm"], cfg)
        x = x + out
        return x, LayerCache(attn=None, ssm=new_ssm)

    if cfg.family == "hybrid":
        attn_out, new_attn = attention_decode(
            h, p["attn"], cfg, cache.attn, pos, window=window
        )
        ssm_out, new_ssm = ssm_lib.ssm_decode_step(h, cache.ssm, p["ssm"], cfg)
        x = x + p["fuse_attn"].astype(x.dtype) * attn_out \
              + p["fuse_ssm"].astype(x.dtype) * ssm_out
        new_cache = LayerCache(attn=new_attn, ssm=new_ssm)
    else:
        attn_out, new_attn = attention_decode(
            h, p["attn"], cfg, cache.attn, pos, window=window
        )
        x = x + attn_out
        new_cache = LayerCache(attn=new_attn, ssm=None)

    if cfg.d_ff > 0 or cfg.is_moe:
        h2 = apply_norm(x, p["ln2"], cfg.norm)
        ffn_out, _ = _ffn(h2, p, cfg)
        x = x + ffn_out
    return x, new_cache
