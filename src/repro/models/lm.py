"""Unified LM: parameter templates, forward/loss, prefill, decode.

One module serves all 10 assigned architectures:
  dense / moe           decoder-only transformer (GQA/MQA, SwiGLU/GeGLU)
  ssm                   Mamba2 (SSD) stack, attention-free
  hybrid                Hymba-style parallel attn+SSM heads
  vlm                   decoder LM with stubbed patch-embedding inputs
  encdec                Whisper-style (see encdec.py; shares templates)

Parameters are described by a *template* pytree of ``PSpec`` records
(shape, logical axes, dtype kind, init kind). ``init_params`` materializes
it; ``param_specs`` turns it into ShapeDtypeStructs for the allocation-free
dry-run; ``logical_axes`` feeds the sharding rules. Per-layer parameters are
stacked on a leading "layers" axis and executed with lax.scan (+remat).
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import jax_compat
from . import ssm as ssm_lib
from .blocks import (
    AttnCache,
    LayerCache,
    block_decode,
    block_forward,
)
from .common import ArchConfig
from .sharding import shard_hint


class PSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    kind: str = "p"        # p = param dtype, f = float32
    init: str = "normal"   # normal | out | zeros | ones | ssm_special


# ------------------------------------------------------------- templates
def _norm_t(cfg) -> Dict[str, PSpec]:
    d = cfg.d_model
    t = {"scale": PSpec((d,), ("embed",), "p", "ones")}
    if cfg.norm == "layernorm":
        t["bias"] = PSpec((d,), ("embed",), "p", "zeros")
    return t


def _attn_t(cfg) -> Dict[str, PSpec]:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads_padded, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": PSpec((d, hq, dh), ("embed", "q_heads", "head_dim")),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((hq, dh, d), ("q_heads", "head_dim", "embed"), "p", "out"),
    }


def _mlp_t(cfg, d_ff: int) -> Dict[str, PSpec]:
    d = cfg.d_model
    t = {
        "wi": PSpec((d, d_ff), ("embed", "mlp")),
        "wo": PSpec((d_ff, d), ("mlp", "embed"), "p", "out"),
    }
    if cfg.activation in ("swiglu", "geglu"):
        t["wi_gate"] = PSpec((d, d_ff), ("embed", "mlp"))
    return t


def _moe_t(cfg) -> Dict[str, PSpec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    t = {
        "router": PSpec((d, e), ("embed", "experts"), "f"),
        "w_up": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "w_down": PSpec((e, f, d), ("experts", "mlp", "embed"), "p", "out"),
    }
    if cfg.activation in ("swiglu", "geglu"):
        t["w_gate"] = PSpec((e, d, f), ("experts", "embed", "mlp"))
    return t


def _ssm_t(cfg) -> Dict[str, PSpec]:
    out = {}
    for name, (shape, axes, kind) in ssm_lib.ssm_param_shapes(cfg).items():
        init = "ssm_special" if name in ("A_log", "dt_bias", "D_skip") else (
            "out" if name == "out_proj" else
            "ones" if name == "norm_scale" else
            "zeros" if name == "conv_b" else "normal"
        )
        out[name] = PSpec(shape, axes, kind, init)
    return out


def layer_template(cfg: ArchConfig, moe: bool, cross_attn: bool = False):
    """Template for one layer (unstacked). Nested subdicts per sublayer."""
    t: Dict[str, Any] = {"ln1": _norm_t(cfg)}
    if cfg.family != "ssm":
        t["attn"] = _attn_t(cfg)
    if cfg.family in ("ssm", "hybrid"):
        t["ssm"] = _ssm_t(cfg)
    if cfg.family == "hybrid":
        d = cfg.d_model
        t["fuse_attn"] = PSpec((d,), ("embed",), "p", "ones")
        t["fuse_ssm"] = PSpec((d,), ("embed",), "p", "ones")
    if cross_attn:
        t["lnx"] = _norm_t(cfg)
        t["xattn"] = _attn_t(cfg)
    if moe and cfg.is_moe:
        t["ln2"] = _norm_t(cfg)
        t["moe"] = _moe_t(cfg)
        if cfg.moe_dense_residual_ff:
            t["moe_dense"] = _mlp_t(cfg, cfg.moe_dense_residual_ff)
    elif cfg.d_ff > 0:
        t["ln2"] = _norm_t(cfg)
        t["mlp"] = _mlp_t(cfg, cfg.d_ff)
    return t


def _stack(template, n: int):
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, ("layers",) + s.axes, s.kind, s.init),
        template,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def model_template(cfg: ArchConfig):
    d, v = cfg.d_model, cfg.vocab_size
    t: Dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "embed")),
        "final_norm": _norm_t(cfg),
    }
    if not cfg.tie_embeddings:
        t["unembed"] = PSpec((d, v), ("embed", "vocab"), "p", "out")
    n_main = cfg.n_layers - cfg.first_k_dense
    if cfg.first_k_dense:
        dense_cfg_t = layer_template(cfg.replace(n_experts=0), moe=False)
        t["front_layers"] = _stack(dense_cfg_t, cfg.first_k_dense)
    t["layers"] = _stack(layer_template(cfg, moe=True), n_main)
    if cfg.family == "vlm":
        t["vision_adapter"] = PSpec((d, d), ("embed", None))
    if cfg.family == "encdec":
        t["enc_layers"] = _stack(
            layer_template(cfg, moe=False), cfg.n_encoder_layers
        )
        t["enc_norm"] = _norm_t(cfg)
        # decoder layers get cross-attention
        t["layers"] = _stack(
            layer_template(cfg, moe=True, cross_attn=True), cfg.n_layers
        )
    return t


# -------------------------------------------------------- materialization
def _is_pspec(x):
    return isinstance(x, PSpec)


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = 0
    for path, spec in jax_compat.tree_flatten_with_path(
        model_template(cfg), is_leaf=_is_pspec
    )[0]:
        n = math.prod(spec.shape)
        if active_only and cfg.is_moe:
            name = getattr(path[-1], "key", str(path[-1]))
            if name in ("w_up", "w_down", "w_gate"):  # routed experts
                n = n * cfg.experts_per_token // cfg.n_experts
        total += n
    return total


def param_specs(cfg: ArchConfig):
    """Pytree of ShapeDtypeStruct mirroring init_params (no allocation)."""
    pdt = cfg.pdtype()

    def to_sds(s: PSpec):
        return jax.ShapeDtypeStruct(
            s.shape, jnp.float32 if s.kind == "f" else pdt
        )

    return jax.tree.map(to_sds, model_template(cfg), is_leaf=_is_pspec)


def logical_axes(cfg: ArchConfig):
    return jax.tree.map(
        lambda s: s.axes, model_template(cfg), is_leaf=_is_pspec
    )


def init_params(cfg: ArchConfig, key: jax.Array):
    """Materialize real parameters (smoke tests / examples / training)."""
    pdt = cfg.pdtype()
    flat, treedef = jax_compat.tree_flatten_with_path(
        model_template(cfg), is_leaf=_is_pspec
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, spec), k in zip(flat, keys):
        dt = jnp.float32 if spec.kind == "f" else pdt
        name = str(path[-1])
        if spec.init == "zeros":
            leaves.append(jnp.zeros(spec.shape, dt))
        elif spec.init == "ones":
            leaves.append(jnp.ones(spec.shape, dt))
        elif spec.init == "ssm_special":
            h = spec.shape[-1]
            if "A_log" in name:
                base = jnp.log(jnp.linspace(1.0, 16.0, h))
                leaves.append(jnp.broadcast_to(base, spec.shape).astype(dt))
            elif "dt_bias" in name:
                dt0 = jnp.exp(
                    jnp.linspace(jnp.log(1e-3), jnp.log(1e-1), h)
                )
                base = dt0 + jnp.log(-jnp.expm1(-dt0))
                leaves.append(jnp.broadcast_to(base, spec.shape).astype(dt))
            else:  # D_skip
                leaves.append(jnp.ones(spec.shape, dt))
        else:
            std = 0.02
            if spec.init == "out":
                std = 0.02 / math.sqrt(2 * cfg.n_layers)
            leaves.append(
                (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)
            )
    return jax.tree.unflatten(treedef, leaves)


# ----------------------------------------------------------- embed / head
def embed_tokens(cfg, params, tokens):
    h = params["embed"][tokens].astype(cfg.cdtype())
    if cfg.tie_embeddings:  # gemma-style scaled embedding
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return h


def lm_head(cfg, params, h):
    if cfg.tie_embeddings:
        w = params["embed"].astype(cfg.cdtype()).T
    else:
        w = params["unembed"].astype(cfg.cdtype())
    logits = (h @ w).astype(jnp.float32)
    return shard_hint(logits, "batch", "seq", "vocab")


# -------------------------------------------------------------- the stack
def _remat_policy(cfg):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def _apply_stack(cfg, stack_params, h, positions, *, window, moe):
    """lax.scan over stacked layers with remat. Returns (h, aux_sums)."""

    def body(carry, lp):
        x, aux, _ = block_forward(
            cfg, lp, carry, positions, window=window, moe_layer=moe
        )
        return x, aux

    policy = _remat_policy(cfg)
    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=policy, prevent_cse=True)
    h, auxes = jax.lax.scan(body, h, stack_params)
    aux = jax.tree.map(jnp.sum, auxes) if auxes else {}
    return h, aux


def forward_hidden(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Dict]:
    """Forward up to (and including) the final norm.

    Returns (h (B, S_text, d) with vision positions already stripped, aux).
    """
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(h.dtype) @ params[
            "vision_adapter"
        ].astype(h.dtype)
        h = jnp.concatenate([vis, h], axis=1)
    h = shard_hint(h, "batch", "seq", "embed")
    S = h.shape[1]
    positions = jnp.arange(S)
    window = cfg.sliding_window if cfg.family == "hybrid" else 0

    aux = {}
    if cfg.first_k_dense:
        h, aux0 = _apply_stack(
            cfg.replace(n_experts=0), params["front_layers"], h, positions,
            window=window, moe=False,
        )
    h, aux = _apply_stack(
        cfg, params["layers"], h, positions, window=window, moe=True
    )
    from .layers import apply_norm

    h = apply_norm(h, params["final_norm"], cfg.norm)
    if cfg.family == "vlm":  # score text positions only
        h = h[:, batch["vision_embeds"].shape[1]:]
    return h, aux


def forward(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Dict]:
    """Training/scoring forward: returns (logits (B,S,V) f32, aux)."""
    h, aux = forward_hidden(cfg, params, batch)
    return lm_head(cfg, params, h), aux


def _unembed_weights(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].astype(cfg.cdtype()).T
    return params["unembed"].astype(cfg.cdtype())


def _chunked_ce(cfg, params, h, targets):
    """Blocked cross-entropy (+z-loss): the (tokens, vocab) logits tensor
    only ever exists at (ce_chunk, vocab) and is rematerialized in the
    backward pass — §Perf iteration K4. Returns (ce_sum, z_sum, count)."""
    B, S, d = h.shape
    w = _unembed_weights(cfg, params)
    T = B * S
    hc = h.reshape(T, d)
    yc = targets.reshape(T)
    Tc = min(cfg.ce_chunk, T)
    n = -(-T // Tc)
    pad = n * Tc - T
    if pad:
        hc = jnp.pad(hc, ((0, pad), (0, 0)))
        yc = jnp.pad(yc, (0, pad), constant_values=-1)
    hc = hc.reshape(n, Tc, d)
    yc = yc.reshape(n, Tc)

    def body(carry, inp):
        ce_sum, z_sum, cnt = carry
        h_i, y_i = inp
        lg = (h_i @ w).astype(jnp.float32)        # (Tc, V) — the only copy
        lg = shard_hint(lg, "batch", "vocab")
        lz = jax.scipy.special.logsumexp(lg, axis=-1)
        y_safe = jnp.maximum(y_i, 0)
        ll = jnp.take_along_axis(lg, y_safe[:, None], axis=-1)[:, 0]
        m = (y_i >= 0).astype(jnp.float32)
        return (
            ce_sum + ((lz - ll) * m).sum(),
            z_sum + ((lz**2) * m).sum(),
            cnt + m.sum(),
        ), None

    body = jax.checkpoint(body, prevent_cse=True)
    (ce_sum, z_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (hc, yc)
    )
    return ce_sum, z_sum, cnt


def loss_fn(cfg: ArchConfig, params, batch):
    """Next-token CE (+ MoE aux, z-loss). Returns (loss, metrics).

    ce_chunk > 0 uses the blocked-CE path (identical math, bounded logits
    residency); ce_chunk == 0 materializes full logits (legacy/oracle)."""
    with jax.named_scope("ce_loss"):
        targets = batch["tokens"][:, 1:]
        if cfg.ce_chunk:
            h, aux = forward_hidden(cfg, params, batch)
            ce_sum, z_sum, cnt = _chunked_ce(cfg, params, h[:, :-1], targets)
            denom = jnp.maximum(cnt, 1.0)
            ce = ce_sum / denom
            zloss = 1e-4 * z_sum / denom
        else:
            logits, aux = forward(cfg, params, batch)
            lg = logits[:, :-1]
            logz = jax.scipy.special.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
            mask = (targets >= 0).astype(jnp.float32)
            denom = jnp.maximum(mask.sum(), 1.0)
            ce = ((logz - ll) * mask).sum() / denom
            zloss = 1e-4 * ((logz**2) * mask).sum() / denom
    total = ce + zloss
    metrics = {"ce": ce, "zloss": zloss}
    if "load_balance_loss" in aux:
        lb = 0.01 * aux["load_balance_loss"] / cfg.n_layers
        rz = 1e-3 * aux["router_z_loss"] / cfg.n_layers
        total = total + lb + rz
        metrics.update(
            moe_lb=lb, moe_rz=rz,
            dropped_fraction=aux["dropped_fraction"] / cfg.n_layers,
        )
    metrics["loss"] = total
    return total, metrics


# ------------------------------------------------------------------ cache
def cache_template(cfg: ArchConfig, batch: int, max_seq: int):
    """Pytree of ShapeDtypeStruct for the decode cache (+logical axes).

    Sliding-window attention (hybrid family) gets a *ring buffer* of
    ``sliding_window`` slots instead of a max_seq-sized cache: O(window)
    memory makes long_500k decode feasible (21.5 GB -> 84 MB for hymba).
    """
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    cdt = cfg.cdtype()
    n_main = cfg.n_layers - cfg.first_k_dense
    kv_len = max_seq
    if cfg.family == "hybrid" and cfg.sliding_window:
        kv_len = min(max_seq, cfg.sliding_window)

    def attn_cache(n):
        return AttnCache(
            k=jax.ShapeDtypeStruct((n, batch, kv_len, hkv, dh), cdt),
            v=jax.ShapeDtypeStruct((n, batch, kv_len, hkv, dh), cdt),
        )

    def ssm_cache(n):
        H, P, N, d_inner, conv_dim, _ = ssm_lib.ssm_dims(cfg)
        return ssm_lib.SSMState(
            conv=jax.ShapeDtypeStruct(
                (n, batch, cfg.conv_width - 1, conv_dim), cdt
            ),
            ssm=jax.ShapeDtypeStruct((n, batch, H, P, N), jnp.float32),
        )

    if cfg.family == "ssm":
        layers = LayerCache(attn=None, ssm=ssm_cache(n_main))
    elif cfg.family == "hybrid":
        layers = LayerCache(attn=attn_cache(n_main), ssm=ssm_cache(n_main))
    else:
        layers = LayerCache(attn=attn_cache(n_main), ssm=None)
    cache = {"layers": layers}
    if cfg.first_k_dense:
        cache["front_layers"] = LayerCache(
            attn=attn_cache(cfg.first_k_dense), ssm=None
        )
    return cache


CACHE_AXES = {
    "attn_k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "attn_v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "ssm_conv": ("layers", "batch", "conv_width", "ssm_inner"),
    "ssm_ssm": ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_template(cfg, batch, max_seq)
    )


# ---------------------------------------------------------------- decode
def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """One decode step. tokens (B, 1) int32, pos scalar int32.

    Returns (logits (B, V) f32, new_cache).
    """
    h = embed_tokens(cfg, params, tokens)
    window = cfg.sliding_window if cfg.family == "hybrid" else 0

    def scan_decode(stack_params, stack_cache, h, sub_cfg):
        def body(x, inp):
            lp, lc = inp
            x, new_lc = block_decode(sub_cfg, lp, x, lc, pos, window=window)
            return x, new_lc

        h, new_cache = jax.lax.scan(body, h, (stack_params, stack_cache))
        return h, new_cache

    new_cache = dict(cache)
    if cfg.first_k_dense:
        h, nc = scan_decode(
            params["front_layers"], cache["front_layers"], h,
            cfg.replace(n_experts=0),
        )
        new_cache["front_layers"] = nc
    h, nc = scan_decode(params["layers"], cache["layers"], h, cfg)
    new_cache["layers"] = nc

    from .layers import apply_norm

    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = lm_head(cfg, params, h)[:, 0]
    return logits, new_cache


# --------------------------------------------------------------- prefill
def prefill(cfg: ArchConfig, params, batch, max_seq: Optional[int] = None):
    """Full-prompt pass that also builds the decode cache.

    Returns (logits at last position (B, V), cache at prompt length).
    Cache buffers sized to the prompt; serve/engine pads to max_seq.
    """
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(h.dtype) @ params[
            "vision_adapter"
        ].astype(h.dtype)
        h = jnp.concatenate([vis, h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)
    window = cfg.sliding_window if cfg.family == "hybrid" else 0

    def scan_prefill(stack_params, h, sub_cfg, moe):
        def body(x, lp):
            x, aux, lc = block_forward(
                sub_cfg, lp, x, positions, window=window,
                build_cache=True, moe_layer=moe,
            )
            return x, lc

        return jax.lax.scan(body, h, stack_params)

    cache = {}
    if cfg.first_k_dense:
        h, lc = scan_prefill(
            params["front_layers"], h, cfg.replace(n_experts=0), False
        )
        cache["front_layers"] = lc
    h, lc = scan_prefill(params["layers"], h, cfg, True)
    cache["layers"] = lc

    from .layers import apply_norm

    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = lm_head(cfg, params, h[:, -1:, :])[:, 0]
    return logits, cache
