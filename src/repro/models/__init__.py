"""Model zoo: unified transformer/SSM stack for the 10 assigned archs."""

from .api import Model, input_specs
from .common import SHAPES, ArchConfig, ShapeConfig, shape_applicable

__all__ = [
    "ArchConfig",
    "Model",
    "SHAPES",
    "ShapeConfig",
    "input_specs",
    "shape_applicable",
]
