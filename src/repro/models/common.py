"""Architecture config schema + logical-axis sharding vocabulary.

Every assigned architecture is described by one frozen ``ArchConfig``. Shapes
of all parameters/caches derive from it, so the dry-run can build
ShapeDtypeStructs without allocating anything.

Logical axes (MaxText-style): every parameter/activation dim is tagged with a
logical name; ``repro.launch.sharding`` maps logical names -> mesh axes via a
rules table (the hillclimbing lever), dropping mesh axes that do not divide
the dim (decision logged, never fatal).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp

# ------------------------------------------------------------------ config


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual_ff: int = 0   # arctic: parallel dense MLP width
    first_k_dense: int = 0           # kimi: leading dense layers
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 0             # stubbed frontend frames (whisper: 1500)
    # --- vlm (llava) ---
    vision_tokens: int = 0           # stubbed patch embeds per sequence
    # --- attention windowing (hybrid long-context) ---
    sliding_window: int = 0          # 0 = full causal
    # --- numerics / execution ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"              # full | dots | none
    # attention blocking (pure-JAX flash-style); hillclimb levers
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # blocked cross-entropy: tokens per chunk (0 = unchunked legacy path).
    # Bounds the live (tokens, vocab) logits tensor to (ce_chunk, vocab),
    # rematerializing it in backward — §Perf iteration K4 (big-vocab archs).
    ce_chunk: int = 0
    # structural head padding for tensor parallelism (§Perf iteration L3):
    # round n_heads up to this multiple so the q-head dim divides the
    # model axis (llava/arctic: 56 -> 64 on a 16-way axis). Extra heads are
    # extra capacity, not a semantic change; 0 = exact published count.
    pad_heads_to_multiple: int = 0

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_heads_padded(self) -> int:
        """Attention-projection head count after TP padding (L3)."""
        m = self.pad_heads_to_multiple
        if not m:
            return self.n_heads
        h = ((self.n_heads + m - 1) // m) * m
        # GQA requires an integer group size
        while h % self.n_kv_heads:
            h += 1
        return h

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        if self.ssm_heads:
            return self.ssm_heads * self.ssm_head_dim
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads_(self) -> int:
        return self.ssm_heads or self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Total parameters N (embedding included)."""
        from . import lm  # deferred; avoids import cycle

        return lm.count_params(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        from . import lm

        return lm.count_params(self, active_only=True)


# ----------------------------------------------------- shapes (assignment)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid run it
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (skip recorded in DESIGN.md)"
        )
    return True, ""
