"""Mixture-of-Experts block: top-k routing with sort-based static-capacity
dispatch (TPU/XLA-friendly: all shapes static, grouped GEMMs over a stacked
expert weight tensor, EP sharding over the `experts` logical axis).

Why sort-based: the one-hot (T, E, C) dispatch tensor of the classic
implementation is O(T*E*C) and infeasible at kimi-k2 scale
(T = 1M tokens, E = 384). Sorting token-assignments by expert id gives the
same drop-on-overflow semantics with O(T*k) memory; the dispatch/return
movement is two static scatters/gathers which GSPMD turns into all-to-all
style collectives when experts are sharded.

Aux outputs follow Switch-Transformer: load-balance loss + router z-loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .sharding import shard_hint


def expert_capacity(
    n_tokens: int,
    n_experts: int,
    top_k: int,
    factor: float,
    multiple: int = 512,
) -> int:
    """Static per-expert capacity, rounded UP to ``multiple`` so the
    (E, C, D) dispatch buffer's capacity dim stays shardable over the data
    axes of the production meshes (16 and 32 both divide 512); capped at
    n_tokens (an expert can never receive more than every token). The cap
    keeps tiny smoke configs drop-free and exact."""
    c = max(1, math.ceil(n_tokens * top_k * factor / n_experts))
    c = ((c + multiple - 1) // multiple) * multiple
    return min(c, n_tokens)


def moe_block(
    x: jax.Array,            # (T, D) tokens (caller flattens batch*seq)
    params,                  # router (D,E) f32; w_gate/w_up (E,D,F); w_down (E,F,D)
    *,
    top_k: int,
    capacity_factor: float,
    activation: str = "swiglu",
) -> Tuple[jax.Array, dict]:
    T, D = x.shape
    E = params["router"].shape[1]
    C = expert_capacity(T, E, top_k, capacity_factor)

    # --- routing (f32 for numerics) ---
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate, expert_idx = jax.lax.top_k(probs, top_k)             # (T, k)
    gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

    # --- flatten assignments and rank within expert ---
    flat_expert = expert_idx.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    flat_gate = gate.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    seg_start = jnp.searchsorted(sorted_expert, sorted_expert, side="left")
    within = jnp.arange(T * top_k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    keep = within < C
    dest = sorted_expert.astype(jnp.int32) * C + within         # (T*k,) in [0, E*C)
    dest = jnp.where(keep, dest, E * C)                         # OOB -> dropped

    # --- inverse maps: buffer row -> (token, gate) -------------------------
    # Dispatch/combine are phrased so that NO (T*k, D) tensor is ever
    # materialized: under GSPMD that tensor lowers to a fully-replicated
    # gather + all-reduce across the model axis (measured: 240 GB/op/layer
    # at kimi-k2 train_4k — see EXPERIMENTS.md §Perf iteration K1). The
    # inverse-permutation maps are integer (E*C,) vectors (megabytes), and
    # the row-data movement happens on (E, C, D) — sharded on BOTH mesh
    # axes — via one gather (dispatch) and one scatter-add (combine).
    src_tok = flat_token[order]
    cdt = x.dtype
    row_token = jnp.full((E * C,), T, dtype=jnp.int32).at[dest].set(
        src_tok, mode="drop"
    )                                          # T = "no token" sentinel
    row_gate = jnp.zeros((E * C,), jnp.float32).at[dest].set(
        flat_gate[order] * keep, mode="drop"
    )

    # --- dispatch: gather token rows into the (E, C, D) buffer ---
    row_valid = (row_token < T)[:, None].astype(cdt)
    src_safe = jnp.minimum(row_token, T - 1)
    buf = x[src_safe] * row_valid              # (E*C, D), no scatter
    buf = buf.reshape(E, C, D)
    buf = shard_hint(buf, "experts", "expert_capacity", "embed")

    # --- grouped expert GEMMs ---
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True)
        )
        h = act(
            jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(cdt))
        ) * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(cdt))
    else:
        h = jax.nn.gelu(
            jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(cdt)),
            approximate=True,
        )
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cdt))
    out_buf = shard_hint(out_buf, "experts", "expert_capacity", "embed")
    out_buf = out_buf.reshape(E * C, D)

    # --- combine: weighted scatter-add of buffer rows back to tokens ---
    # (one scatter from the sharded (E*C, D) rows; rows with the sentinel
    # token index T fall off the end and are dropped)
    weighted = out_buf * row_gate.astype(cdt)[:, None]
    out = jnp.zeros((T, D), dtype=cdt).at[row_token].add(
        weighted, mode="drop"
    )

    # --- aux losses (Switch-style) ---
    frac_tokens = jnp.zeros(E, jnp.float32).at[flat_expert].add(1.0) / (T * top_k)
    mean_prob = probs.mean(axis=0)
    aux = {
        "load_balance_loss": E * jnp.sum(frac_tokens * mean_prob),
        "router_z_loss": jnp.mean(
            jax.scipy.special.logsumexp(logits, axis=-1) ** 2
        ),
        "dropped_fraction": 1.0 - keep.mean(),
    }
    return out, aux
