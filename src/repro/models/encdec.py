"""Whisper-style encoder-decoder (audio family, conv frontend stubbed).

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed mel-frame embeddings (B, encoder_seq, d_model). Positions are
sinusoidal (whisper uses sinusoidal for the encoder and learned for the
decoder; we use sinusoidal for both — recorded in DESIGN.md). Decoder layers
are self-attn (causal) -> cross-attn (encoder KV) -> MLP, all pre-norm.

Decode caches: per-layer self-attn KV ring... linear buffers + per-layer
cross-attn KV computed once at prefill from the encoder output.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .blocks import AttnCache, attention_full, attention_decode
from .layers import apply_norm, decode_attention, mlp, sinusoidal_positions
from .sharding import shard_hint


class EncDecCache(NamedTuple):
    self_kv: AttnCache     # (L, B, S_max, Hkv, Dh)
    cross_kv: AttnCache    # (L, B, S_enc, Hkv, Dh)


# ------------------------------------------------------------- encoder
def encode(cfg, params, enc_frames):
    """(B, S_enc, D) stub frames -> encoder hidden states."""
    cdt = cfg.cdtype()
    h = enc_frames.astype(cdt)
    h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(cdt)[None]
    h = shard_hint(h, "batch", "enc_seq", "embed")
    positions = jnp.arange(h.shape[1])

    def body(x, lp):
        hh = apply_norm(x, lp["ln1"], cfg.norm)
        attn_out, _ = attention_full(hh, lp["attn"], cfg, positions, causal=False)
        x = x + attn_out
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + mlp(h2, lp["mlp"], cfg.activation)
        return x, None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(h, params["enc_norm"], cfg.norm)


# ------------------------------------------------- decoder (full sequence)
def _cross_attention_full(x, xp, cfg, enc_h):
    cdt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, xp["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", enc_h, xp["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", enc_h, xp["wv"].astype(cdt))
    from .layers import blocked_attention

    out = blocked_attention(
        q, k, v, causal=False, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
    )
    return jnp.einsum("bshk,hkd->bsd", out, xp["wo"].astype(cdt)), (k, v)


def _decoder_layer_full(cfg, lp, x, positions, enc_h, build_cache):
    h = apply_norm(x, lp["ln1"], cfg.norm)
    attn_out, kv = attention_full(h, lp["attn"], cfg, positions, causal=True)
    x = x + attn_out
    hx = apply_norm(x, lp["lnx"], cfg.norm)
    cross_out, cross_kv = _cross_attention_full(hx, lp["xattn"], cfg, enc_h)
    x = x + cross_out
    h2 = apply_norm(x, lp["ln2"], cfg.norm)
    x = x + mlp(h2, lp["mlp"], cfg.activation)
    cache = None
    if build_cache:
        cache = EncDecCache(
            self_kv=AttnCache(k=kv[0], v=kv[1]),
            cross_kv=AttnCache(k=cross_kv[0], v=cross_kv[1]),
        )
    return x, cache


def _decode_tokens_embed(cfg, params, tokens, pos0):
    cdt = cfg.cdtype()
    h = params["embed"][tokens].astype(cdt)
    S = tokens.shape[1]
    pos = pos0 + jnp.arange(S)
    half = cfg.d_model // 2
    freq = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = pos.astype(jnp.float32)[:, None] * freq[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return h + pe.astype(cdt)[None]


def forward(cfg, params, batch) -> Tuple[jax.Array, Dict]:
    """Training forward: returns (decoder logits (B, S, V) f32, aux)."""
    enc_h = encode(cfg, params, batch["enc_frames"])
    tokens = batch["tokens"]
    h = _decode_tokens_embed(cfg, params, tokens, 0)
    h = shard_hint(h, "batch", "seq", "embed")
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        x, _ = _decoder_layer_full(cfg, lp, x, positions, enc_h, False)
        return x, None

    import functools

    from .lm import _remat_policy

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(
            body, policy=_remat_policy(cfg), prevent_cse=True
        )
    h, _ = jax.lax.scan(body_fn, h, params["layers"])
    h = apply_norm(h, params["final_norm"], cfg.norm)
    w = params["unembed"].astype(h.dtype)
    logits = (h @ w).astype(jnp.float32)
    return logits, {}


def loss_fn(cfg, params, batch):
    logits, _ = forward(cfg, params, batch)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = ((logz - ll) * mask).sum() / denom
    return ce, {"ce": ce, "loss": ce}


# ----------------------------------------------------------------- decode
def cache_template(cfg, batch: int, max_seq: int):
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    cdt = cfg.cdtype()
    L = cfg.n_layers
    return EncDecCache(
        self_kv=AttnCache(
            k=jax.ShapeDtypeStruct((L, batch, max_seq, hkv, dh), cdt),
            v=jax.ShapeDtypeStruct((L, batch, max_seq, hkv, dh), cdt),
        ),
        cross_kv=AttnCache(
            k=jax.ShapeDtypeStruct((L, batch, cfg.encoder_seq, hkv, dh), cdt),
            v=jax.ShapeDtypeStruct((L, batch, cfg.encoder_seq, hkv, dh), cdt),
        ),
    )


def init_cache(cfg, batch: int, max_seq: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_template(cfg, batch, max_seq)
    )


def decode_step(cfg, params, cache: EncDecCache, tokens, pos):
    """One decoder token. tokens (B, 1); returns (logits (B, V), cache)."""
    h = _decode_tokens_embed(cfg, params, tokens, pos)

    def body(x, inp):
        lp, self_kv, cross_kv = inp
        hh = apply_norm(x, lp["ln1"], cfg.norm)
        attn_out, new_kv = attention_decode(hh, lp["attn"], cfg, self_kv, pos)
        x = x + attn_out
        hx = apply_norm(x, lp["lnx"], cfg.norm)
        cdt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", hx, lp["xattn"]["wq"].astype(cdt))
        cross_out = decode_attention(
            q, cross_kv.k, cross_kv.v, cross_kv.k.shape[1]
        )
        x = x + jnp.einsum(
            "bshk,hkd->bsd", cross_out, lp["xattn"]["wo"].astype(cdt)
        )
        h2 = apply_norm(x, lp["ln2"], cfg.norm)
        x = x + mlp(h2, lp["mlp"], cfg.activation)
        return x, new_kv

    h, new_self = jax.lax.scan(
        body, h, (params["layers"], cache.self_kv, cache.cross_kv)
    )
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = (h @ params["unembed"].astype(h.dtype))[:, 0].astype(jnp.float32)
    return logits, EncDecCache(self_kv=new_self, cross_kv=cache.cross_kv)


def prefill(cfg, params, batch):
    """Encoder pass + decoder prompt pass; builds both cache halves."""
    enc_h = encode(cfg, params, batch["enc_frames"])
    tokens = batch["tokens"]
    h = _decode_tokens_embed(cfg, params, tokens, 0)
    positions = jnp.arange(tokens.shape[1])

    def body(x, lp):
        x, cache = _decoder_layer_full(cfg, lp, x, positions, enc_h, True)
        return x, cache

    h, caches = jax.lax.scan(body, h, params["layers"])
    h = apply_norm(h, params["final_norm"], cfg.norm)
    logits = (h[:, -1] @ params["unembed"].astype(h.dtype)).astype(jnp.float32)
    return logits, caches
