"""StagedExecutor: a small software pipeline over thread pools.

Serving a search query is a chain of stages that alternate between the
device and the host — encode (device) -> probe (host) -> verify (device)
-> merge/emit (host). Run sequentially, each resource idles while the
other works; run as a pipeline, stage ``s`` of item ``i`` overlaps stage
``s+1`` of item ``i-1``. Threads are the right vehicle here because every
stage either releases the GIL (NumPy popcounts, jax dispatch/transfer) or
is a device call, and all shared structures (tables, packed DBs) are
read-only.

Each stage owns ONE worker thread, so a stage processes items strictly in
submission order (per-stage FIFO — results come back in order, no
reordering logic needed) while different stages run different items
concurrently: classic double buffering when ``window=2``. An item's
stage-``s`` task blocks on its stage-``s-1`` future; since the worker
could not run anything else anyway (FIFO), that wait costs nothing.

``map`` keeps at most ``window`` items in flight (default
``2 * n_stages``): the producer is throttled by yielding finished items,
so an unbounded input stream never piles up unbounded intermediate
buffers. A stage exception propagates to the consumer at the failed
item's position in the output order; later items may still run their
early stages (their results are discarded by the raised iteration).
"""

from __future__ import annotations

from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator, List, Optional, Sequence

from ..obs import trace as _obs

__all__ = ["Stage", "StagedExecutor"]


@dataclass(frozen=True)
class Stage:
    """One pipeline stage: a name (thread/debug label) and a callable
    ``fn(x) -> y`` mapping the previous stage's output to this one's."""

    name: str
    fn: Callable[[Any], Any]


class StagedExecutor:
    """Run items through a chain of stages with cross-stage overlap.

    >>> ex = StagedExecutor([Stage("enc", enc), Stage("search", knn)])
    >>> for out in ex.map(batches):
    ...     consume(out)          # in submission order
    >>> ex.close()

    Also usable as a context manager. ``submit`` returns the final-stage
    future for callers that want to drive completion themselves.
    """

    def __init__(
        self,
        stages: Sequence[Stage],
        window: Optional[int] = None,
        name: str = "pipeline",
    ):
        if not stages:
            raise ValueError("StagedExecutor needs at least one stage")
        self.stages: List[Stage] = list(stages)
        self.window = max(1, window or 2 * len(self.stages))
        self._pools = [
            ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=f"{name}-{s.name}"
            )
            for s in self.stages
        ]
        self._closed = False

    # ------------------------------------------------------------ plumbing
    @staticmethod
    def _run_stage(name, fn, prev: Optional[Future], item):
        """Stage body: wait for the upstream result (FIFO worker — nothing
        else could run meanwhile), then apply this stage. The span covers
        only this stage's own work, not the upstream wait — queueing time
        would otherwise inflate every downstream stage's cost."""
        x = item if prev is None else prev.result()
        with _obs.current().span(f"stage.{name}", cat="pipeline"):
            return fn(x)

    def submit(self, item) -> Future:
        """Push one item through every stage; returns the LAST stage's
        future (exceptions from any stage surface on it)."""
        if self._closed:
            raise RuntimeError("StagedExecutor is closed")
        fut: Optional[Future] = None
        for stage, pool in zip(self.stages, self._pools):
            fut = pool.submit(self._run_stage, stage.name, stage.fn, fut,
                              item)
            item = None   # only the first stage sees the raw item
        assert fut is not None
        return fut

    def map(self, items: Iterable) -> Iterator:
        """Pipeline ``items`` through the stages, yielding final-stage
        results in submission order, at most ``window`` items in flight."""
        inflight: deque = deque()
        for item in items:
            inflight.append(self.submit(item))
            while len(inflight) >= self.window:
                yield inflight.popleft().result()
        while inflight:
            yield inflight.popleft().result()

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            for pool in self._pools:
                pool.shutdown(wait=True)

    def __enter__(self) -> "StagedExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
