"""AMIH tuple-step overlap: verify step *t* while probing step *t+1*.

The sequential ``AMIHIndex`` group loop alternates strictly:

    probe(t)  ->  verify(t)  ->  bucket(t)  ->  emit(t)  ->  probe(t+1) ...

``verify`` is a device call (or one big vectorized host popcount) and
``probe`` is host-side table walking — each leaves the other resource
idle. ``VerifyOverlap`` software-pipelines the loop one step deep:

    probe(t)          | verify(t-1)  [worker thread / device]
    bucket+emit(t-1)  |
    submit verify(t)  |
    probe(t+1)        | verify(t)    ...

Exactness is preserved because bucketing is order-independent *within* a
step: the candidates a tuple emits depend only on the probes performed up
to that tuple (deterministic per query) and on their exact verified
tuples, never on when the verification physically ran. Emission for step
``t`` happens only after step ``t``'s verification has been joined and
bucketed, so every code of bucket ``(r1, r2)`` discovered by any probe up
to step ``t`` is present — the same set the sequential loop emits.
Results (ids, sims) are therefore bit-identical to the sequential loop.

One visible difference is bounded over-probing: the pipelined loop probes
step ``t+1`` *before* it learns (at step ``t``'s emit) that a query just
filled its K results, so a finishing query may execute one extra probing
step. Its fresh candidates are dropped before verification (``verified``
matches the sequential count) but the probe-side counters
(``probes`` / ``tuples_processed`` / ``max_radius``) may run one step
past the sequential ones. Result rows are unaffected.
"""

from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor
from typing import List, Optional

from ..core.tuples import rhat, sim_value
from ..obs import trace as _obs

__all__ = ["VerifyOverlap"]


class _PendingStep:
    """Verification in flight (or already resolved) for one tuple step."""

    __slots__ = ("r1", "r2", "s_val", "states", "blocks", "future", "keys")

    def __init__(self, r1, r2, s_val, states, blocks, future, keys=None):
        self.r1 = r1
        self.r2 = r2
        self.s_val = s_val
        self.states = states
        self.blocks = blocks
        self.future: Optional[Future] = future
        self.keys = keys               # inline-verified small steps


class VerifyOverlap:
    """Pipelined driver for ``AMIHIndex``'s per-z-group tuple loop.

    Owns one background worker ("tables are read-only" is what makes a
    plain thread safe here: the worker only reads the index and the DB,
    and writes nothing but its returned key arrays). On the Pallas
    verify backend the worker issues the grouped device launch
    (``kernels/ops.verify_tuples_grouped_launch``) and blocks on the
    transfer; on the NumPy backend it runs the vectorized popcount —
    either way the main thread is free to probe the next tuple step.

    One instance serves one engine; calls are not re-entrant (the engine
    layer serializes ``knn_batch`` calls per engine object).

    ``min_async_candidates``: steps whose fresh-candidate total is below
    this verify INLINE at submit time instead — a sub-millisecond
    popcount costs less than a worker-thread hop, and most tail steps of
    a converged query are tiny. Only the big early steps, where
    verification is real work (and where NumPy/device verification
    releases the GIL), go through the worker.
    """

    def __init__(self, name: str = "amih-verify",
                 min_async_candidates: int = 2048):
        self._name = name
        self.min_async_candidates = min_async_candidates
        self._pool: Optional[ThreadPoolExecutor] = None

    def _submit(self, fn, *args) -> Future:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=self._name
            )
        return self._pool.submit(fn, *args)

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # ------------------------------------------------------------- driver
    def run_group(
        self,
        index,
        z: int,
        states: List,
        k: int,
        enumeration_cap: Optional[int],
        stop_below=None,
        on_done=None,
    ) -> None:
        """Pipelined replacement for ``AMIHIndex._run_group_sequential``:
        same states in, same out_ids/out_sims per state out (bit-identical
        up to in-tuple ties; see module docstring for the counter caveat).
        """
        r_hat = rhat(z)
        prev: Optional[_PendingStep] = None
        for (r1, r2) in index._probing_iter(z):
            alive = [s for s in states if not s.done]
            if not alive and prev is None:
                break
            s_val = sim_value(index.p, z, r1, r2)
            # Bound-stopped queries skip this step's probing, but their
            # `done` flag is only set AFTER the previous step's emission
            # below — the sequential loop emits step t-1 before it checks
            # the bound at step t, and so must we.
            bound_stopped, probing = [], alive
            if stop_below is not None:
                # one bound read per state: shared bounds may move between
                # reads (they only ever increase), and a state must land in
                # exactly one of the two lists.
                bound_stopped, probing = [], []
                for s in alive:
                    (bound_stopped if s_val < stop_below[s.qi]
                     else probing).append(s)
            # 1. probe step t on the host while step t-1 verifies.
            tr = _obs.current()
            t0 = _obs.now_us() if tr.enabled else 0.0
            fresh_states, fresh_blocks = [], []
            for s in probing:
                fresh = index._probe_step(s, r1, r2, r_hat, enumeration_cap)
                if fresh.size:
                    fresh_states.append(s)
                    fresh_blocks.append(fresh)
            if tr.enabled:
                tr.record("amih.probe", t0, _obs.now_us(), cat="amih",
                          z=z, r1=r1, r2=r2, queries=len(probing),
                          overlapped=True)
            # 2. flush step t-1: join its verification, bucket, emit.
            if prev is not None:
                self._flush(index, states, k, prev, on_done)
            for s in bound_stopped:
                s.done = True
            # 3. drop blocks of queries that just finished, then issue
            #    step t's verification asynchronously.
            keep = [
                (s, b)
                for s, b in zip(fresh_states, fresh_blocks)
                if not s.done
            ]
            v_states = [s for s, _ in keep]
            v_blocks = [b for _, b in keep]
            for s, b in keep:
                if s.stats is not None:
                    s.stats.verified += b.size
            future = keys = None
            if v_blocks:
                if (sum(b.size for b in v_blocks)
                        >= self.min_async_candidates):
                    future = self._submit(
                        index._verify_keys, v_states, v_blocks
                    )
                else:   # tiny step: the thread hop costs more than it hides
                    keys = index._verify_keys(v_states, v_blocks)
            prev = _PendingStep(
                r1, r2, s_val, v_states, v_blocks, future, keys
            )
            if all(s.done for s in states):
                break
        if prev is not None:
            self._flush(index, states, k, prev, on_done)

    @staticmethod
    def _flush(index, states, k, step: _PendingStep, on_done=None) -> None:
        """Join the step's verification, bucket its keys, emit its tuple."""
        keys = (
            step.future.result() if step.future is not None else step.keys
        )
        if keys is not None:
            index._bucket_keys(step.states, step.blocks, keys)
        emitted = [s for s in states if not s.done]
        tr = _obs.current()
        t0 = _obs.now_us() if tr.enabled else 0.0
        index._emit_tuple(emitted, step.r1, step.r2, step.s_val, k)
        if tr.enabled:
            tr.record("amih.emit", t0, _obs.now_us(), cat="amih",
                      overlapped=True)
        if on_done is not None:
            index._notify_done(emitted, on_done)
