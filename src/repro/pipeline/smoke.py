"""Fast pipelined-vs-sequential smoke check (wired into scripts/verify.sh).

    PYTHONPATH=src python -m repro.pipeline.smoke

Runs in seconds: a small clustered workload is answered by the pipelined
paths (AMIH verify/probe overlap, shard-parallel probing with the shared
warm-started bound, the two-stage streaming loop) and every result is
asserted bit-identical to its sequential counterpart and to the exact
linear scan. This is the cheap end-to-end canary for the subsystem — the
full property sweep lives in tests/test_pipeline.py.
"""

from __future__ import annotations

import sys
import time

import numpy as np


def main() -> int:
    from ..core import linear_scan_knn, make_engine, pack_bits
    from ..data import synthetic_binary_codes, synthetic_queries
    from .stream import stream_search

    t0 = time.perf_counter()
    p, n, B, k, S = 64, 1200, 16, 10, 8
    db_bits = synthetic_binary_codes(n, p, seed=0)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=1))
    qs[1] = 0  # zero-norm query rides along
    ref = [linear_scan_knn(qs[i], db, k)[1] for i in range(B)]

    def check(tag, engine):
        ids, sims, _ = engine.knn_batch(qs, k)
        for i in range(B):
            np.testing.assert_array_equal(sims[i], ref[i])
        print(f"  {tag}: exact")
        return engine

    seq = check("amih sequential   ", make_engine("amih", db, p))
    check("amih overlap      ",
          make_engine("amih", db, p, overlap_verify=True))
    check("sharded sequential",
          make_engine("sharded_amih", db, p, num_shards=S))
    par = make_engine("sharded_amih", db, p, num_shards=S, probe_workers=S)
    # tiny smoke DB / 2-core CI host: force the pool past its adaptive
    # stand-down gates so the smoke actually exercises it
    par.PARALLEL_MIN_SHARD_ROWS = 0
    par.PARALLEL_MIN_CPUS = 0
    par.PARALLEL_MIN_BATCH = 0
    assert par._use_parallel(B)
    check("sharded parallel  ", par)

    # streaming loop over the sequential engine: per-step results in
    # order, latency counters present, same sims
    steps = list(stream_search(seq, [qs[:8], qs[8:]], k))
    got = np.concatenate([sr.sims for sr in steps])
    for i in range(B):
        np.testing.assert_array_equal(got[i], ref[i])
    assert all("p50" in sr.stats.latency_ms for sr in steps)
    assert steps[0].stats.queue_depth == 8
    print(f"  stream_search     : exact, latency counters present")
    print(f"pipeline smoke OK in {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
