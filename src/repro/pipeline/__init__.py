"""Async pipelined serving subsystem: overlapped stages for AMIH search.

The paper's AMIH query (§5) is a fixed alternation of host and device
work; run strictly in that order, each resource idles while the other
runs. This package pipelines the alternation at every level of the
stack, without giving up exactness — every pipelined path returns
bit-identical (ids, sims) to its sequential counterpart, up to ties
inside one Hamming tuple.

Stage map (paper §5 <-> modules here):

  encode      query embedding -> AQBC code (§6.1's binarization; device)
                `stream.stream_search` overlaps it with the search of the
                previous batch step via `stages.StagedExecutor`.
  probe       substring-tuple bucket walks T_{r1,r2,m} (Prop. 4; host)
                `overlap.VerifyOverlap` probes tuple step t+1 while step
                t's verification is in flight; `shardpool` probes all
                shards of a sharded index concurrently under one shared
                monotone k-th-cosine bound (the cross-shard form of the
                paper's early-termination rule).
  verify      exact full-code tuple popcounts of fresh candidates
                (Eq. 3 / §5's candidate check; device or vectorized
                host) — issued asynchronously per tuple step
                (`kernels.ops.verify_tuples_grouped_launch`).
  merge/emit  bucket by exact tuple, emit in decreasing-sim order
                (Prop. 4's exact emission; host) — order-independent
                within a step, which is what makes the overlap legal.

Modules:
  - stages.py    — StagedExecutor: per-stage single-worker thread pools,
                   bounded in-flight window, in-order results.
  - overlap.py   — VerifyOverlap: AMIH tuple-step verify/probe overlap
                   (plugs into AMIHIndex via the ``overlap=`` knob).
  - shardpool.py — SharedBound + PersistentShardPool: shard-parallel
                   probing for "sharded_amih" with a shared, monotone,
                   warm-startable k-th-cosine bound; workers fork once
                   per engine lifetime and take tasks over pipes
                   (probe_shards_parallel is the one-shot wrapper).
  - stream.py    — Ticket / stream_search / LatencyTracker: streaming
                   ``run_queued`` results with queue-depth and p50/p99
                   latency counters on EngineStats.
  - smoke.py     — fast end-to-end pipelined==sequential check
                   (``python -m repro.pipeline.smoke``; wired into
                   scripts/verify.sh).

Engine knobs (see core.engine / shard.engines / serve.retrieval):
  make_engine("amih", db, p, overlap_verify=True)
  make_engine("sharded_amih", db, p, num_shards=8, probe_workers=8)
  RetrievalConfig(pipelined=True);  RetrievalService.run_queued(stream=True)
"""

from .overlap import VerifyOverlap
from .shardpool import (
    PersistentShardPool,
    SharedBound,
    prime_ids,
    probe_shards_parallel,
)
from .stages import Stage, StagedExecutor
from .stream import LatencyTracker, StepResult, Ticket, stream_search

__all__ = [
    "LatencyTracker",
    "PersistentShardPool",
    "SharedBound",
    "Stage",
    "StagedExecutor",
    "StepResult",
    "Ticket",
    "VerifyOverlap",
    "prime_ids",
    "probe_shards_parallel",
    "stream_search",
]
