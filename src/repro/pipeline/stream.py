"""Streaming search serving: tickets, per-step results, latency counters.

``stream_search`` drives a ``SearchEngine`` over a sequence of query
batches through a two-stage pipeline (encode -> search) and yields a
``StepResult`` per batch step AS IT COMPLETES — batch ``i+1`` encodes on
the device while batch ``i`` probes on the host, and callers consume
results while later steps are still in flight. This is the serving loop
of ``RetrievalService.run_queued(stream=True)``; the serving benchmark
drives it directly over pre-packed codes (identity encode).

The overlap compounds with the device probe path's async multi-device
dispatch: a sharded engine with ``probe_backend="device"`` issues ONE
fused walk launch per device without blocking (shard.engines
``_probe_device_fused``), so while every device probes step ``i``, the
search worker is only busy for the O(K) extraction tail and the encode
worker is already packing step ``i+1`` — three overlapping stages from
two threads plus the devices themselves.

``Ticket`` is the handle ``RetrievalService.submit`` returns: an
int-compatible query id (old callers that used the qid as a dict key
keep working unchanged) carrying a ``concurrent.futures.Future`` that
resolves to ``(ids, sims)`` when the query's batch step completes, plus
its submission timestamp for queueing-latency accounting.

Each yielded step's ``EngineStats`` carries the serving-side counters:
``queue_depth`` (queries still waiting behind this step) and
``latency_ms`` (rolling p50/p99 over answered queries).
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace as _obs
from ..obs.metrics import REGISTRY as _REG, Histogram
from .stages import Stage, StagedExecutor

__all__ = ["LatencyTracker", "StepResult", "Ticket", "stream_search"]


class Ticket:
    """Handle for one submitted query: an int-compatible qid plus a
    future resolving to ``(ids, sims)``. Hashes and compares equal to its
    qid, so dicts keyed by the old integer qids accept tickets and vice
    versa."""

    __slots__ = ("qid", "future", "submitted_at")

    def __init__(self, qid: int):
        self.qid = qid
        self.future: Future = Future()
        self.submitted_at = time.perf_counter()

    def result(self, timeout: Optional[float] = None):
        """Block until the query's batch step completes; returns
        (ids, sims)."""
        return self.future.result(timeout)

    def __int__(self) -> int:
        return self.qid

    __index__ = __int__

    def __hash__(self) -> int:
        return hash(self.qid)

    def __eq__(self, other) -> bool:
        if isinstance(other, Ticket):
            return self.qid == other.qid
        if isinstance(other, int):
            return self.qid == other
        return NotImplemented

    def __repr__(self) -> str:
        state = "done" if self.future.done() else "pending"
        return f"Ticket(qid={self.qid}, {state})"


class LatencyTracker(Histogram):
    """Rolling latency percentiles over answered queries (thread-safe).

    A ``repro.obs.metrics.Histogram`` (same bounded window, same locks)
    keeping its historical snapshot shape: interpolated np.percentile
    values rounded to 4 places with a float ``count`` — the dict that
    lands on ``EngineStats.latency_ms``. Dashboards want recent p50/p99,
    not all-time, so only the last ``window`` samples score.
    """

    def __init__(self, window: int = 4096):
        super().__init__(window)

    def record(self, ms: float, count: int = 1) -> None:
        super().record(float(ms), count)

    def snapshot(self) -> Dict[str, float]:
        """{"p50": ..., "p99": ..., "mean": ..., "count": ...} in ms over
        the current window; empty dict before the first sample."""
        with self._lock:
            if not self._samples:
                return {}
            arr = np.asarray(self._samples, dtype=np.float64)
            return {
                "p50": round(float(np.percentile(arr, 50)), 4),
                "p99": round(float(np.percentile(arr, 99)), 4),
                "mean": round(float(arr.mean()), 4),
                "count": float(self._count),
            }


@dataclass
class StepResult:
    """One completed batch step of a streaming search."""

    step: int                     # step index in submission order
    ids: np.ndarray               # (B_step, k')
    sims: np.ndarray              # (B_step, k')
    stats: Any                    # EngineStats with serving counters set
    latency_ms: float             # enqueue -> completion for this step
    # service-level view (filled by RetrievalService): qid -> (ids, sims)
    results: Dict[int, Tuple[np.ndarray, np.ndarray]] = field(
        default_factory=dict
    )


def stream_search(
    engine,
    batches: Sequence,
    k: int,
    encode: Optional[Callable[[Any], np.ndarray]] = None,
    window: Optional[int] = None,
    tracker: Optional[LatencyTracker] = None,
    stamp_latency: bool = True,
) -> Iterator[StepResult]:
    """Pipeline ``batches`` through encode -> ``engine.knn_batch`` and
    yield a ``StepResult`` per batch, in order, as each completes.

    ``batches`` is a sequence of per-step payloads; ``encode`` maps a
    payload to packed (B, W) query words (None: payloads are already
    packed). Encoding of step ``i+1`` overlaps the search of step ``i``
    (one worker thread each, see stages.StagedExecutor). Per-step
    latency is measured from pipeline enqueue to step completion and
    recorded per query into ``tracker`` (a fresh one unless provided);
    ``stamp_latency=False`` skips that and leaves ``stats.latency_ms``
    untouched for callers that stamp their own definition (the
    retrieval service uses true submit -> resolve latency).
    """
    batches = list(batches)
    tracker = tracker or LatencyTracker()
    # queries waiting strictly behind step i (queue depth when i answers)
    sizes = [len(b) for b in batches]
    behind = np.concatenate([np.cumsum(sizes[::-1])[::-1][1:], [0]]) \
        if sizes else np.zeros(0)
    enqueue_t: Dict[int, float] = {}

    def _enc(item):
        i, payload = item
        q = payload if encode is None else encode(payload)
        return i, q

    def _search(item):
        i, q = item
        ids, sims, stats = engine.knn_batch(q, k)
        return i, ids, sims, stats

    def _feed():
        for i, payload in enumerate(batches):
            enqueue_t[i] = time.perf_counter()
            yield (i, payload)

    with StagedExecutor(
        [Stage("encode", _enc), Stage("search", _search)],
        window=window, name="serve",
    ) as ex:
        tr = _obs.current()
        for i, ids, sims, stats in ex.map(_feed()):
            done_t = time.perf_counter()
            lat_ms = 1e3 * (done_t - enqueue_t[i])
            if tr.enabled:
                # enqueue_t and now_us share the perf_counter clock
                tr.record("serve.step", enqueue_t[i] * 1e6, done_t * 1e6,
                          cat="serve", step=i, B=int(ids.shape[0]))
            _REG.histogram("serve.latency_ms").record(
                lat_ms, count=max(1, ids.shape[0])
            )
            stats.queue_depth = int(behind[i])
            if stamp_latency:
                tracker.record(lat_ms, count=max(1, ids.shape[0]))
                stats.latency_ms = tracker.snapshot()
            yield StepResult(
                step=i, ids=ids, sims=sims, stats=stats,
                latency_ms=lat_ms,
            )
