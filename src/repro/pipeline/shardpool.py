"""Shard-parallel AMIH probing with a shared monotone k-th-cosine bound.

The sequential ``sharded_amih`` engine probes its shards one after
another, chaining each shard's pooled k-th cosine into the next shard's
``stop_below`` bound. That serializes the embarrassingly parallel part of
multi-index hashing — every shard owns a disjoint, read-only table set —
and gives shard 0 no bound at all.

This module replaces the chain with a shared per-query bound probed by
all shards CONCURRENTLY:

  - ``SharedBound`` owns a live float64 ``bounds`` array handed directly
    to every shard's ``AMIHIndex.knn_batch_bounded`` (which re-reads it
    at every tuple step, no copy). Entries only ever increase, and every
    value written is the k-th best exact sim of SOME subset of real DB
    rows — hence always a valid lower bound on the global k-th, which is
    all exactness needs (see the engine docstring). Monotonicity is also
    what makes lock-free reads safe: a stale read is merely a weaker,
    still-correct bound.

  - Bounds rise *while shards probe*: the ``on_done`` hook fires inside
    the bounded search the moment a query fills its local K, publishing
    that shard's local k-th immediately — peers prune mid-flight instead
    of waiting for whole-shard completion the way the sequential chain
    waits for whole-shard results.

  - ``prime()``-style warm starting: the exact sims of a small
    deterministic row sample (``prime_ids``) are offered before any
    probing, so even the first-finishing shard — which the sequential
    chain probes with no bound at all — starts pruned.

Worker modes (``mode=``):

  - "process" (default where ``fork`` exists): one forked worker per
    shard group, the per-call bounds array in a named
    ``multiprocessing.shared_memory`` segment every worker attaches to.
    Probing is a Python loop over many small NumPy calls — far too
    GIL-bound for threads to help on CPython (measured: 8 threads run
    the SAME work ~2.5-3x slower than one) — so real CPU parallelism
    needs processes. Fork is cheap here: the child inherits the built
    shard indexes copy-on-write and ships back only (B, k) results.
    Racy ``max`` writes to the shared array can lose an update, leaving
    a smaller — still valid — bound; exactness is unaffected.
  - "thread": the issue-shaped thread pool, the right choice on
    free-threaded (nogil) interpreters and for mesh-device workloads
    where probing cost is dominated by device calls that release the
    GIL (the mesh-resident pallas verify path forces this mode — a
    fork-child of a jax-initialized parent must never dispatch jax).
  - "auto": "process" when the platform has ``fork``, else "thread".

``PersistentShardPool`` is the serving-host form: workers fork ONCE per
engine lifetime (``ShardedAMIHEngine`` owns one, released by
``engine.close()``) and every ``probe()`` call ships its task over the
worker's task pipe instead of re-forking — the per-call fork cost that
erased the pool's wins on serving hosts is paid once at warm-up. The
one-shot ``probe_shards_parallel`` is a build-probe-close wrapper over
it, kept for callers without an engine lifetime to amortize over.
"""

from __future__ import annotations

import multiprocessing
import sys
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace as _obs

__all__ = [
    "PersistentShardPool",
    "SharedBound",
    "prime_ids",
    "probe_shards_parallel",
    "resolve_probe_mode",
]

_EMPTY64 = np.empty(0, dtype=np.int64)


def resolve_probe_mode(mode: str = "auto") -> str:
    if mode not in ("auto", "process", "thread"):
        raise ValueError(f"unknown probe mode {mode!r}")
    if mode != "auto":
        return mode
    can_fork = (
        sys.platform != "win32"
        and "fork" in multiprocessing.get_all_start_methods()
    )
    return "process" if can_fork else "thread"


class SharedBound:
    """Per-query monotone lower bounds on the global k-th cosine.

    ``bounds`` is a live float64 (B,) array: consumers hand it directly
    to ``AMIHIndex.knn_batch_bounded`` while producers raise it through
    ``offer`` (pooled candidates, deduplicated by global id — the same
    code offered twice must not fake a tighter k-th than the DB
    supports) or ``raise_to`` (a known-valid k-th, e.g. a shard's local
    k-th). ``bounds=<array>`` aliases an existing live array instead of
    allocating one; cross-process sharing is the pool's job —
    ``PersistentShardPool._probe_procs`` re-points ``bounds`` at a
    per-call shared-memory segment for the duration of a call.
    """

    def __init__(self, B: int, k: int,
                 bounds: Optional[np.ndarray] = None):
        self.k = k
        if bounds is not None:
            self.bounds = bounds
        else:
            self.bounds = np.full(B, -np.inf, dtype=np.float64)
        # per query: pooled (ids, sims) of the current top-<=k candidates
        self._ids: List[np.ndarray] = [_EMPTY64 for _ in range(B)]
        self._sims: List[np.ndarray] = [
            np.empty(0, dtype=np.float64) for _ in range(B)
        ]
        self._lock = threading.Lock()

    def raise_to(self, qi: int, kth: float) -> None:
        """Monotone write of a known-valid bound (lock-free)."""
        if kth > self.bounds[qi]:
            self.bounds[qi] = kth

    def offer(self, qi: int, ids: np.ndarray, sims: np.ndarray) -> None:
        """Fold candidate (global id, exact sim) pairs into query ``qi``'s
        pool and raise its bound to the pooled k-th best (once the pool
        holds k distinct ids)."""
        if ids.size == 0:
            return
        with self._lock:
            all_ids = np.concatenate([self._ids[qi], ids])
            all_sims = np.concatenate([self._sims[qi], sims])
            uniq, first = np.unique(all_ids, return_index=True)
            usims = all_sims[first]
            if uniq.size > self.k:
                keep = np.argpartition(usims, uniq.size - self.k)[
                    uniq.size - self.k:
                ]
                uniq, usims = uniq[keep], usims[keep]
            self._ids[qi], self._sims[qi] = uniq, usims
            if uniq.size >= self.k:
                self.raise_to(qi, float(usims.min()))


def prime_ids(n: int, k: int, sample: Optional[int] = None) -> np.ndarray:
    """Deterministic row sample for bound warm-starting: ``sample`` ids
    spread evenly across [0, n) (default ``min(n, max(4k, 256))``)."""
    if sample is None:
        sample = min(n, max(4 * k, 256))
    sample = max(1, min(n, sample))
    return np.unique(
        np.linspace(0, n - 1, num=sample, dtype=np.int64)
    )


def _local_kth_publisher(bounds: np.ndarray, k: int):
    """on_done hook: the moment a query fills its local K inside a
    shard's bounded search, its local k-th (emission order is
    non-increasing, so the last sim) becomes a live bound for peers."""

    def on_done(qi: int, ids: np.ndarray, sims: np.ndarray) -> None:
        if sims.size >= k:
            kth = float(sims[-1])
            if kth > bounds[qi]:
                bounds[qi] = kth

    return on_done


def _probe_group(group, q_words, k, pool: SharedBound, stats_factory,
                 enumeration_cap,
                 on_first_shard=None) -> Dict[int, Tuple[list, list, int]]:
    """One worker's shard group, probed sequentially under the live
    shared bound. Within the group the bound chains exactly like the
    sequential engine (each finished shard's results are pooled and
    offered before the next shard starts); across groups the bound
    flows through the shared array — per query, the moment it fills its
    local K (``on_done``). ``on_first_shard`` fires once the group's
    first (cold) shard completes — the staggered-start gate."""
    B = q_words.shape[0]
    on_done = _local_kth_publisher(pool.bounds, k)
    out: Dict[int, Tuple[list, list, int]] = {}
    for s, index in group:
        st = [stats_factory() for _ in range(B)]
        launches0 = index.verify_launches
        results = index.knn_batch_bounded(
            q_words, k, stop_below=pool.bounds, stats=st,
            enumeration_cap=enumeration_cap, on_done=on_done,
        )
        for qi, (r_ids, r_sims) in enumerate(results):
            pool.offer(qi, r_ids, r_sims)
        # launch delta measured where the verifies RAN: a forked worker's
        # index counters never reach the parent's index objects
        out[s] = (results, st, index.verify_launches - launches0)
        if on_first_shard is not None:
            on_first_shard()
            on_first_shard = None
    return out


def _await_warm_start(bounds: np.ndarray, floor: np.ndarray, gate,
                      fraction: float = 0.9,
                      timeout_s: float = 60.0) -> None:
    """Bound-aware staggered start: block until ``fraction`` of the
    queries have had their shared bound raised ABOVE ``floor`` (the
    pre-probe snapshot — priming counts for nothing here; only a peer's
    probing publishes tighter values), or the lead worker's cold shard
    has completed (``gate``), whichever is first. A worker that starts
    cold probes its first shard unbounded — the expensive regime the
    sequential chain pays exactly once, for shard 0; the stagger keeps
    it paid roughly once across the whole pool while everything after
    still overlaps."""
    import time as _time

    deadline = _time.perf_counter() + timeout_s
    while ((bounds > floor).mean() < fraction
           and not gate()
           and _time.perf_counter() < deadline):
        _time.sleep(0.002)


def _attach_shm(name: str):
    """Attach a named shared-memory segment without taking ownership: the
    parent owns the segment's lifetime (it unlinks after the call).
    ``track=False`` (3.13+) skips tracker registration outright; on older
    Pythons the attach re-registers the name with the resource tracker —
    harmless here because the pool forks its workers only after
    ``ensure_running`` (see ``_ensure_procs``), so parent and children
    share ONE tracker whose per-name set the re-register is a no-op on
    and the parent's unlink balances (a child-side unregister would
    instead strip the parent's registration, cpython issue 82300)."""
    from multiprocessing import shared_memory

    try:
        return shared_memory.SharedMemory(name=name, track=False)  # 3.13+
    except TypeError:
        return shared_memory.SharedMemory(name=name)


def _run_pool_task(group, lead, stats_factory, result_conn, shm,
                   task) -> None:
    """One probe task inside a persistent worker: alias the call's shared
    bounds segment and probe the group, STREAMING each finished shard's
    results back immediately — the parent folds them into the one global
    candidate pool and is the single writer of the pooled k-th bounds
    (per-worker pools would compose only through a max of partial k-ths,
    a strictly weaker bound). Touches only NumPy and the pipes — never
    jax — so running in a fork-child of a jax-initialized parent is
    safe. A separate function so every view of ``shm.buf`` (including
    the ones captured by the gate/on_done closures) is dead before the
    caller closes the segment.

    ``trace_meta`` (the task's optional 6th element) carries the
    parent's trace id when tracing is on: the child installs a matching
    tracer and ships each shard's spans back on the SAME result pipe,
    tagged with its pid (stamped at record time) and shard id — fork
    children share the parent's CLOCK_MONOTONIC base, so the spans land
    on the parent timeline without adjustment."""
    B, q_words, k, enumeration_cap, floor, trace_meta = task
    tracer = _obs.Tracer(enabled=False)
    if trace_meta:
        tracer = _obs.Tracer(
            enabled=True, host=trace_meta.get("host", "local"),
            trace_id=trace_meta.get("id"),
        )
    _obs.set_tracer(tracer)
    bounds = np.frombuffer(shm.buf, dtype=np.float64, count=B)
    gate = np.frombuffer(shm.buf, dtype=np.uint8, count=1, offset=8 * B)
    try:
        if not lead:                     # staggered worker: warm start
            _await_warm_start(bounds, floor, lambda: gate[0] != 0)
            on_first = None
        else:                            # lead worker: opens the gate
            def on_first():
                gate[0] = 1

        on_done = _local_kth_publisher(bounds, k)
        for s, index in group:
            st = [stats_factory() for _ in range(B)]
            launches0 = index.verify_launches
            results = index.knn_batch_bounded(
                q_words, k, stop_below=bounds, stats=st,
                enumeration_cap=enumeration_cap, on_done=on_done,
            )
            spans = None
            if trace_meta:
                spans = tracer.drain()
                for sp in spans:
                    sp.setdefault("args", {})["shard"] = s
            result_conn.send(("shard", s, results, st,
                              index.verify_launches - launches0, spans))
            if on_first is not None:
                on_first()
                on_first = None
        result_conn.send(("done",))
    except BaseException as e:          # surface the failure to the parent
        result_conn.send(("error", e))
    finally:
        # even on failure: staggered peers must not sit out the full
        # warm-start timeout waiting on a gate that will never open
        if lead:
            gate[0] = 1


def _pool_worker(group, lead, stats_factory, task_conn, result_conn):
    """Persistent forked-worker loop: block on the task pipe, run each
    probe task against the inherited (copy-on-write) shard indexes, exit
    on ("stop",) or when the parent's end of the pipe closes."""
    try:
        while True:
            try:
                msg = task_conn.recv()
            except EOFError:            # parent died / closed the pipe
                break
            if msg[0] == "stop":
                break
            try:
                shm = _attach_shm(msg[1])
            except (FileNotFoundError, OSError) as e:
                # the parent abandoned this call (a peer's pipe broke
                # mid-dispatch) and already unlinked its segment: report
                # and stay alive rather than dying on a stale task
                result_conn.send(("error", e))
                continue
            try:
                _run_pool_task(group, lead, stats_factory, result_conn,
                               shm, msg[2:])
            finally:
                shm.close()
    finally:
        result_conn.close()
        task_conn.close()


def _partition(entries, workers: int):
    """Round-robin shard groups of near-equal row totals (shards are
    already balanced, so round-robin by position is enough)."""
    groups = [entries[w::workers] for w in range(workers)]
    return [g for g in groups if g]


class PersistentShardPool:
    """Fork-once shard-probe worker pool: the amortized form of
    ``probe_shards_parallel`` for engines that answer many calls.

    Construction only partitions the shards; the workers (one per shard
    group, at most ``min(max_workers, len(shards), cpu_count)``) fork
    lazily on the FIRST ``probe()`` and then persist — every later call
    reuses them, shipping its task over each worker's task pipe and a
    fresh named shared-memory bounds segment (created per call, sized to
    the call's batch, unlinked after). ``forks`` counts worker processes
    ever started; for a healthy pool it never exceeds the group count,
    which is what "fork at most once per engine lifetime" means
    operationally.

    More workers than cores cannot probe faster but DOES weaken the
    bound (a shard only sees peers' bounds once their queries complete,
    so oversubscription just multiplies un-pruned starts). Within a
    group the bound chains sequentially, exactly like the sequential
    engine; across groups it flows live through the shared segment.
    Thread mode keeps one persistent ``ThreadPoolExecutor`` instead of
    processes — the right shape when probing cost is dominated by
    GIL-releasing device calls (mesh-resident pallas verification).

    ``close()`` (idempotent, also run on GC) sends every worker a stop
    message and joins it; ``ShardedAMIHEngine.close()`` forwards here so
    serving hosts can release the pool deterministically.
    """

    def __init__(self, indexes, stats_factory,
                 max_workers: Optional[int] = None, mode: str = "auto"):
        self.mode = resolve_probe_mode(mode)
        self.entries = list(indexes)
        self.stats_factory = stats_factory
        # stand-down gate: a device-probing shard answers in one fused
        # jitted launch per z-group — there is no host loop to overlap,
        # a fork-child of a jax-initialized parent must never dispatch
        # jax, and a single device serializes the launches anyway. Any
        # device-backed shard collapses the pool to the inline path.
        if any(
            getattr(ix, "probe_backend", "host") == "device"
            for _, ix in self.entries
        ):
            workers = 1
        else:
            workers = max(1, min(
                max_workers or len(self.entries),
                len(self.entries),
                multiprocessing.cpu_count(),
            ))
        self.groups = _partition(self.entries, workers)
        self.forks = 0                   # worker processes ever started
        self._procs: List[tuple] = []    # [(proc, task_conn, result_conn)]
        self._executor: Optional[ThreadPoolExecutor] = None
        self._closed = False
        self._broken = False
        # serializes probe(): the standing task/result pipes carry one
        # call at a time (the per-call-fork predecessor was isolated per
        # call; a second concurrent call here would steal the first's
        # result messages). Serving already serializes knn_batch per
        # engine — this guards direct multi-threaded engine use.
        self._probe_lock = threading.Lock()

    def worker_pids(self) -> List[int]:
        """PIDs of the live forked workers (empty in thread/inline mode)."""
        return [proc.pid for proc, _, _ in self._procs]

    # ------------------------------------------------------------ lifecycle
    def _ensure_procs(self) -> None:
        """Fork the workers, once. Children inherit the built shard
        indexes copy-on-write (fork start method: args are never
        pickled) and block on their task pipes between calls."""
        if self._procs:
            return
        try:
            # start the resource tracker BEFORE forking so parent and
            # workers share one tracker process: per-call segment
            # registrations then balance against the parent's unlink
            # (see _attach_shm)
            from multiprocessing import resource_tracker

            resource_tracker.ensure_running()
        except Exception:
            pass
        ctx = multiprocessing.get_context("fork")
        for w, group in enumerate(self.groups):
            task_parent, task_child = ctx.Pipe(duplex=False)
            res_parent, res_child = ctx.Pipe(duplex=False)
            proc = ctx.Process(
                target=_pool_worker,
                args=(group, w == 0, self.stats_factory,
                      task_parent, res_child),
                daemon=True,
            )
            with warnings.catch_warnings():
                # jax warns that a fork-child using jax may deadlock;
                # these children are numpy-only by construction
                # (_run_pool_task)
                warnings.filterwarnings(
                    "ignore", message=".*os.fork.*", category=RuntimeWarning
                )
                proc.start()
            self.forks += 1
            task_parent.close()
            res_child.close()
            self._procs.append((proc, task_child, res_parent))

    def close(self) -> None:
        """Stop and join every worker (idempotent). Takes the probe lock,
        so a close racing an in-flight ``probe()`` drains that call first
        instead of closing the pipes out from under its collector."""
        with self._probe_lock:
            if self._closed:
                return
            self._closed = True
            for _, task_conn, _ in self._procs:
                try:
                    task_conn.send(("stop",))
                except (OSError, ValueError):
                    pass
                task_conn.close()
            for proc, _, res_conn in self._procs:
                proc.join(timeout=10)
                if proc.is_alive():
                    proc.terminate()
                res_conn.close()
            self._procs = []
            if self._executor is not None:
                self._executor.shutdown(wait=True)
                self._executor = None

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass   # interpreter shutdown: pipes may already be gone

    # -------------------------------------------------------------- probing
    def probe(
        self,
        q_words: np.ndarray,
        k: int,
        shared: SharedBound,
        enumeration_cap: Optional[int] = None,
    ) -> Dict[int, Tuple[list, list, int]]:
        """Probe every shard concurrently under ``shared``'s live bound.
        Returns shard_id -> (per-query results, per-query stats,
        verify-launch delta); callers fold in shard-id order so merged
        stats stay deterministic. ``shared`` may be a plain-array
        SharedBound — process mode re-points ``shared.bounds`` at the
        call's shared segment for the duration of the call (and back to
        a plain copy after), so the parent's ``offer`` writes are the
        single pooled-bound source every worker reads."""
        with self._probe_lock:
            if self._closed:
                raise RuntimeError("probe pool is closed")
            if self._broken:
                raise RuntimeError(
                    "probe pool lost a worker; build a fresh engine/pool"
                )
            if len(self.groups) == 1:
                return _probe_group(
                    self.entries, q_words, k, shared, self.stats_factory,
                    enumeration_cap,
                )
            if self.mode == "thread":
                return self._probe_threads(
                    q_words, k, shared, enumeration_cap
                )
            return self._probe_procs(q_words, k, shared, enumeration_cap)

    def _probe_threads(self, q_words, k, shared, enumeration_cap):
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=len(self.groups),
                thread_name_prefix="shard-probe",
            )
        # pre-probe bound snapshot: later workers stagger on bounds
        # raised ABOVE this floor by the lead worker's first shard
        # (priming does not count), lead cold-shard completion fallback
        floor = shared.bounds.copy()
        gate = threading.Event()

        def probe_entry(item):
            w, group = item
            if w > 0:
                _await_warm_start(shared.bounds, floor, gate.is_set)
                return _probe_group(
                    group, q_words, k, shared, self.stats_factory,
                    enumeration_cap,
                )
            try:
                return _probe_group(
                    group, q_words, k, shared, self.stats_factory,
                    enumeration_cap, on_first_shard=gate.set,
                )
            finally:
                gate.set()   # even on failure: unblock staggered peers

        out: Dict[int, Tuple[list, list, int]] = {}
        for part in self._executor.map(probe_entry, enumerate(self.groups)):
            out.update(part)
        return out

    def _probe_procs(self, q_words, k, shared, enumeration_cap):
        from multiprocessing import shared_memory

        self._ensure_procs()
        B = q_words.shape[0]
        # per-call bounds segment: B float64 bounds + 1 gate byte (the
        # lead worker's cold-shard flag), zero-initialized by create
        shm = shared_memory.SharedMemory(create=True, size=8 * B + 1)
        seg = np.frombuffer(shm.buf, dtype=np.float64, count=B)

        def open_gate():
            # on-demand view, dropped before returning: a persistent
            # gate array handed into _collect would be pinned by an
            # error path's traceback frame and block shm.close()
            g = np.frombuffer(shm.buf, dtype=np.uint8, count=1,
                              offset=8 * B)
            g[0] = 1

        try:
            seg[:] = shared.bounds
            shared.bounds = seg          # live view for parent offers
            floor = seg.copy()
            tr = _obs.current()
            trace_meta = (
                {"id": tr.trace_id, "host": tr.host} if tr.enabled
                else None
            )
            for w, (_, task_conn, _) in enumerate(self._procs):
                try:
                    task_conn.send((
                        "probe", shm.name, B, q_words, k, enumeration_cap,
                        None if w == 0 else floor, trace_meta,
                    ))
                except OSError as e:
                    # a worker died between calls: its task pipe is
                    # broken. The pool cannot serve half-dispatched
                    # calls — mark it dead so later probes fail fast
                    # instead of stranding stale tasks.
                    self._broken = True
                    raise RuntimeError(
                        "probe pool lost a worker; build a fresh "
                        "engine/pool"
                    ) from e
            return self._collect(shared, open_gate)
        finally:
            # detach the live bound from the segment (keep final values)
            # and drop every view before closing the mapping
            shared.bounds = np.array(shared.bounds, dtype=np.float64)
            del seg
            try:
                shm.close()
            except BufferError:
                # an in-flight exception's traceback can still pin a
                # view; never let that mask the real error — the name
                # is unlinked below regardless and the mapping dies
                # with the last reference
                pass
            shm.unlink()

    def _collect(self, shared, open_gate):
        """Drain result pipes for one call. The parent is the pooling
        thread: it folds streamed per-shard results into THE global
        candidate pool and is the single writer of the pooled per-query
        k-th bounds (children still publish their local k-ths via
        on_done — aligned 8-byte stores, monotone, safe)."""
        from multiprocessing.connection import wait as mp_wait

        out: Dict[int, Tuple[list, list, int]] = {}
        failure: Optional[BaseException] = None
        live = {conn: proc for proc, _, conn in self._procs}
        while live:
            for conn in mp_wait(list(live)):
                try:
                    msg = conn.recv()
                except EOFError:        # worker died mid-call
                    open_gate()         # (hard kill skips its finally)
                    self._broken = True
                    del live[conn]
                    continue
                if msg[0] == "shard":
                    _, s, results, st, launches, spans = msg
                    if spans:
                        # same machine, shared monotonic clock: no shift
                        _obs.current().ingest(spans)
                    out[s] = (results, st, launches)
                    for qi, (r_ids, r_sims) in enumerate(results):
                        shared.offer(qi, r_ids, r_sims)
                elif msg[0] == "error":
                    failure = failure or msg[1]
                    open_gate()         # never strand staggered peers
                    del live[conn]
                else:                   # "done": task finished
                    del live[conn]
        if failure is not None:
            raise failure
        if len(out) != len(self.entries):
            missing = sorted(set(s for s, _ in self.entries) - set(out))
            self._broken = True
            raise RuntimeError(
                f"shard probe worker died without reporting shards "
                f"{missing}"
            )
        return out


def probe_shards_parallel(
    indexes,
    q_words: np.ndarray,
    k: int,
    shared: SharedBound,
    stats_factory,
    enumeration_cap: Optional[int] = None,
    max_workers: Optional[int] = None,
    mode: str = "auto",
) -> Dict[int, Tuple[list, list]]:
    """One-shot form of ``PersistentShardPool``: build the pool, probe
    once, tear the workers down. Same result contract as ``probe()``;
    use the persistent pool (as ``ShardedAMIHEngine`` does) when there
    is an engine lifetime to amortize the forks over."""
    pool = PersistentShardPool(
        indexes, stats_factory, max_workers=max_workers, mode=mode
    )
    try:
        return pool.probe(
            q_words, k, shared, enumeration_cap=enumeration_cap
        )
    finally:
        pool.close()
