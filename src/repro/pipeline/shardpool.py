"""Shard-parallel AMIH probing with a shared monotone k-th-cosine bound.

The sequential ``sharded_amih`` engine probes its shards one after
another, chaining each shard's pooled k-th cosine into the next shard's
``stop_below`` bound. That serializes the embarrassingly parallel part of
multi-index hashing — every shard owns a disjoint, read-only table set —
and gives shard 0 no bound at all.

This module replaces the chain with a shared per-query bound probed by
all shards CONCURRENTLY:

  - ``SharedBound`` owns a live float64 ``bounds`` array handed directly
    to every shard's ``AMIHIndex.knn_batch_bounded`` (which re-reads it
    at every tuple step, no copy). Entries only ever increase, and every
    value written is the k-th best exact sim of SOME subset of real DB
    rows — hence always a valid lower bound on the global k-th, which is
    all exactness needs (see the engine docstring). Monotonicity is also
    what makes lock-free reads safe: a stale read is merely a weaker,
    still-correct bound.

  - Bounds rise *while shards probe*: the ``on_done`` hook fires inside
    the bounded search the moment a query fills its local K, publishing
    that shard's local k-th immediately — peers prune mid-flight instead
    of waiting for whole-shard completion the way the sequential chain
    waits for whole-shard results.

  - ``prime()``-style warm starting: the exact sims of a small
    deterministic row sample (``prime_ids``) are offered before any
    probing, so even the first-finishing shard — which the sequential
    chain probes with no bound at all — starts pruned.

Worker modes (``mode=``):

  - "process" (default where ``fork`` exists): one forked worker per
    shard, the bounds array in ``multiprocessing.RawArray`` shared
    memory. Probing is a Python loop over many small NumPy calls — far
    too GIL-bound for threads to help on CPython (measured: 8 threads
    run the SAME work ~2.5-3x slower than one) — so real CPU parallelism
    needs processes. Fork is cheap here: the child inherits the built
    shard indexes copy-on-write and ships back only (B, k) results.
    Racy ``max`` writes to the shared array can lose an update, leaving
    a smaller — still valid — bound; exactness is unaffected.
  - "thread": the issue-shaped thread pool, the right choice on
    free-threaded (nogil) interpreters and for mesh-device workloads
    where probing cost is dominated by device calls that release the
    GIL.
  - "auto": "process" when the platform has ``fork``, else "thread".
"""

from __future__ import annotations

import multiprocessing
import sys
import threading
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "SharedBound",
    "prime_ids",
    "probe_shards_parallel",
    "resolve_probe_mode",
]

_EMPTY64 = np.empty(0, dtype=np.int64)


def resolve_probe_mode(mode: str = "auto") -> str:
    if mode not in ("auto", "process", "thread"):
        raise ValueError(f"unknown probe mode {mode!r}")
    if mode != "auto":
        return mode
    can_fork = (
        sys.platform != "win32"
        and "fork" in multiprocessing.get_all_start_methods()
    )
    return "process" if can_fork else "thread"


class SharedBound:
    """Per-query monotone lower bounds on the global k-th cosine.

    ``bounds`` is a live float64 (B,) array: consumers hand it directly
    to ``AMIHIndex.knn_batch_bounded`` while producers raise it through
    ``offer`` (pooled candidates, deduplicated by global id — the same
    code offered twice must not fake a tighter k-th than the DB
    supports) or ``raise_to`` (a known-valid k-th, e.g. a shard's local
    k-th). With ``shared_memory=True`` the array lives in a
    ``multiprocessing.RawArray`` so forked shard workers see — and
    raise — the same bounds; ``bounds=<array>`` aliases an existing live
    array instead (how a forked worker builds its own pooling view over
    the inherited shared memory).
    """

    def __init__(self, B: int, k: int, shared_memory: bool = False,
                 bounds: Optional[np.ndarray] = None):
        self.k = k
        self.raw = None
        if bounds is not None:
            self.bounds = bounds
        elif shared_memory:
            ctx = multiprocessing.get_context("fork")
            self.raw = ctx.RawArray("d", B)
            self.bounds = np.frombuffer(self.raw, dtype=np.float64)
            self.bounds[:] = -np.inf
        else:
            self.bounds = np.full(B, -np.inf, dtype=np.float64)
        # per query: pooled (ids, sims) of the current top-<=k candidates
        self._ids: List[np.ndarray] = [_EMPTY64 for _ in range(B)]
        self._sims: List[np.ndarray] = [
            np.empty(0, dtype=np.float64) for _ in range(B)
        ]
        self._lock = threading.Lock()

    def raise_to(self, qi: int, kth: float) -> None:
        """Monotone write of a known-valid bound (lock-free)."""
        if kth > self.bounds[qi]:
            self.bounds[qi] = kth

    def offer(self, qi: int, ids: np.ndarray, sims: np.ndarray) -> None:
        """Fold candidate (global id, exact sim) pairs into query ``qi``'s
        pool and raise its bound to the pooled k-th best (once the pool
        holds k distinct ids)."""
        if ids.size == 0:
            return
        with self._lock:
            all_ids = np.concatenate([self._ids[qi], ids])
            all_sims = np.concatenate([self._sims[qi], sims])
            uniq, first = np.unique(all_ids, return_index=True)
            usims = all_sims[first]
            if uniq.size > self.k:
                keep = np.argpartition(usims, uniq.size - self.k)[
                    uniq.size - self.k:
                ]
                uniq, usims = uniq[keep], usims[keep]
            self._ids[qi], self._sims[qi] = uniq, usims
            if uniq.size >= self.k:
                self.raise_to(qi, float(usims.min()))


def prime_ids(n: int, k: int, sample: Optional[int] = None) -> np.ndarray:
    """Deterministic row sample for bound warm-starting: ``sample`` ids
    spread evenly across [0, n) (default ``min(n, max(4k, 256))``)."""
    if sample is None:
        sample = min(n, max(4 * k, 256))
    sample = max(1, min(n, sample))
    return np.unique(
        np.linspace(0, n - 1, num=sample, dtype=np.int64)
    )


def _local_kth_publisher(bounds: np.ndarray, k: int):
    """on_done hook: the moment a query fills its local K inside a
    shard's bounded search, its local k-th (emission order is
    non-increasing, so the last sim) becomes a live bound for peers."""

    def on_done(qi: int, ids: np.ndarray, sims: np.ndarray) -> None:
        if sims.size >= k:
            kth = float(sims[-1])
            if kth > bounds[qi]:
                bounds[qi] = kth

    return on_done


def _probe_group(group, q_words, k, pool: SharedBound, stats_factory,
                 enumeration_cap,
                 on_first_shard=None) -> Dict[int, Tuple[list, list, int]]:
    """One worker's shard group, probed sequentially under the live
    shared bound. Within the group the bound chains exactly like the
    sequential engine (each finished shard's results are pooled and
    offered before the next shard starts); across groups the bound
    flows through the shared array — per query, the moment it fills its
    local K (``on_done``). ``on_first_shard`` fires once the group's
    first (cold) shard completes — the staggered-start gate."""
    B = q_words.shape[0]
    on_done = _local_kth_publisher(pool.bounds, k)
    out: Dict[int, Tuple[list, list, int]] = {}
    for s, index in group:
        st = [stats_factory() for _ in range(B)]
        launches0 = index.verify_launches
        results = index.knn_batch_bounded(
            q_words, k, stop_below=pool.bounds, stats=st,
            enumeration_cap=enumeration_cap, on_done=on_done,
        )
        for qi, (r_ids, r_sims) in enumerate(results):
            pool.offer(qi, r_ids, r_sims)
        # launch delta measured where the verifies RAN: a forked worker's
        # index counters never reach the parent's index objects
        out[s] = (results, st, index.verify_launches - launches0)
        if on_first_shard is not None:
            on_first_shard()
            on_first_shard = None
    return out


def _await_warm_start(bounds: np.ndarray, floor: np.ndarray, gate,
                      fraction: float = 0.9,
                      timeout_s: float = 60.0) -> None:
    """Bound-aware staggered start: block until ``fraction`` of the
    queries have had their shared bound raised ABOVE ``floor`` (the
    pre-probe snapshot — priming counts for nothing here; only a peer's
    probing publishes tighter values), or the lead worker's cold shard
    has completed (``gate``), whichever is first. A worker that starts
    cold probes its first shard unbounded — the expensive regime the
    sequential chain pays exactly once, for shard 0; the stagger keeps
    it paid roughly once across the whole pool while everything after
    still overlaps."""
    import time as _time

    deadline = _time.perf_counter() + timeout_s
    while ((bounds > floor).mean() < fraction
           and not gate()
           and _time.perf_counter() < deadline):
        _time.sleep(0.002)


def _probe_group_child(group, q_words, k, raw, gate_raw, stats_factory,
                       enumeration_cap, conn, floor) -> None:
    """Forked worker body: alias the shared bounds and probe the group,
    STREAMING each finished shard's results back immediately — the
    parent folds them into the one global candidate pool and is the
    single writer of the pooled k-th bounds (per-worker pools would
    compose only through a max of partial k-ths, a strictly weaker
    bound). Touches only NumPy and the pipe — never jax — so running in
    a fork-child of a jax-initialized parent is safe."""
    lead = floor is None
    try:
        bounds = np.frombuffer(raw, dtype=np.float64)
        if not lead:                     # staggered worker: warm start
            _await_warm_start(bounds, floor, lambda: gate_raw[0] != 0)
            on_first = None
        else:                            # lead worker: opens the gate
            def on_first():
                gate_raw[0] = 1

        B = q_words.shape[0]
        on_done = _local_kth_publisher(bounds, k)
        for s, index in group:
            st = [stats_factory() for _ in range(B)]
            launches0 = index.verify_launches
            results = index.knn_batch_bounded(
                q_words, k, stop_below=bounds, stats=st,
                enumeration_cap=enumeration_cap, on_done=on_done,
            )
            conn.send(("shard", s, results, st,
                       index.verify_launches - launches0))
            if on_first is not None:
                on_first()
                on_first = None
        conn.send(("done",))
    except BaseException as e:          # surface the failure to the parent
        conn.send(("error", e))
    finally:
        if lead:
            # even on failure: staggered peers must not sit out the full
            # warm-start timeout waiting on a gate that will never open
            gate_raw[0] = 1
        conn.close()


def _partition(entries, workers: int):
    """Round-robin shard groups of near-equal row totals (shards are
    already balanced, so round-robin by position is enough)."""
    groups = [entries[w::workers] for w in range(workers)]
    return [g for g in groups if g]


def probe_shards_parallel(
    indexes,
    q_words: np.ndarray,
    k: int,
    shared: SharedBound,
    stats_factory,
    enumeration_cap: Optional[int] = None,
    max_workers: Optional[int] = None,
    mode: str = "auto",
) -> Dict[int, Tuple[list, list]]:
    """Probe every (shard_id, AMIHIndex) concurrently under the shared
    bound. Returns shard_id -> (per-query results, per-query stats,
    verify-launch delta); callers fold in shard-id order so merged stats
    stay deterministic.

    Shards are partitioned into at most ``min(max_workers, cpu_count)``
    groups, one worker each: more workers than cores cannot probe faster
    but DOES weaken the bound (a shard only sees peers' bounds once
    their queries complete, so oversubscription just multiplies
    un-pruned starts), and in process mode each worker is one fork.
    Within a group the bound chains sequentially, exactly like the PR 3
    engine; across groups it flows live through ``shared.bounds``.
    """
    mode = resolve_probe_mode(mode)
    entries = list(indexes)
    workers = max(1, min(
        max_workers or len(entries),
        len(entries),
        multiprocessing.cpu_count(),
    ))
    groups = _partition(entries, workers)

    if len(groups) == 1:
        return _probe_group(
            entries, q_words, k, shared, stats_factory, enumeration_cap
        )

    # pre-probe bound snapshot: later workers stagger on bounds raised
    # ABOVE this floor by the lead worker's first shard (priming does
    # not count), with the lead's cold-shard completion as the fallback
    floor = shared.bounds.copy()

    if mode == "thread":
        gate = threading.Event()

        def probe_entry(item):
            w, group = item
            if w > 0:
                _await_warm_start(shared.bounds, floor, gate.is_set)
                return _probe_group(
                    group, q_words, k, shared, stats_factory,
                    enumeration_cap,
                )
            try:
                return _probe_group(
                    group, q_words, k, shared, stats_factory,
                    enumeration_cap, on_first_shard=gate.set,
                )
            finally:
                gate.set()   # even on failure: unblock staggered peers

        out: Dict[int, Tuple[list, list, int]] = {}
        with ThreadPoolExecutor(
            max_workers=len(groups), thread_name_prefix="shard-probe"
        ) as pool:
            for part in pool.map(probe_entry, enumerate(groups)):
                out.update(part)
        return out

    if shared.raw is None:
        raise ValueError(
            "process mode needs SharedBound(shared_memory=True)"
        )
    from multiprocessing.connection import wait as mp_wait

    ctx = multiprocessing.get_context("fork")
    gate_raw = ctx.RawArray("b", 1)     # lead worker's cold-shard flag
    procs = []
    for w, group in enumerate(groups):
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        # fork start method: args are inherited, never pickled — the
        # child gets the built indexes copy-on-write
        proc = ctx.Process(
            target=_probe_group_child,
            args=(group, q_words, k, shared.raw, gate_raw, stats_factory,
                  enumeration_cap, child_conn, floor if w else None),
            daemon=True,
        )
        with warnings.catch_warnings():
            # jax warns that a fork-child using jax may deadlock; these
            # children are numpy-only by construction (_probe_group_child)
            warnings.filterwarnings(
                "ignore", message=".*os.fork.*", category=RuntimeWarning
            )
            proc.start()
        child_conn.close()
        procs.append((proc, parent_conn))
    # The parent is the pooling thread: it folds streamed per-shard
    # results into THE global candidate pool and is the single writer
    # of the pooled per-query k-th bounds (children still publish their
    # local k-ths via on_done — aligned 8-byte stores, monotone, safe).
    out: Dict[int, Tuple[list, list, int]] = {}
    failure: Optional[BaseException] = None
    live = {conn: proc for proc, conn in procs}
    while live:
        for conn in mp_wait(list(live)):
            try:
                msg = conn.recv()
            except EOFError:            # worker died without reporting
                gate_raw[0] = 1         # (hard kill skips its finally)
                del live[conn]
                conn.close()
                continue
            if msg[0] == "shard":
                _, s, results, st, launches = msg
                out[s] = (results, st, launches)
                for qi, (r_ids, r_sims) in enumerate(results):
                    shared.offer(qi, r_ids, r_sims)
            elif msg[0] == "error":
                failure = failure or msg[1]
                gate_raw[0] = 1         # never strand staggered peers
                del live[conn]
                conn.close()
            else:                       # "done"
                del live[conn]
                conn.close()
    for proc, _ in procs:
        proc.join(timeout=30)
    if failure is not None:
        raise failure
    if len(out) != len(entries):
        missing = sorted(set(s for s, _ in entries) - set(out))
        raise RuntimeError(
            f"shard probe worker died without reporting shards {missing}"
        )
    return out
