"""Retrieval service: the paper's technique as a first-class serving feature.

Pipeline:  encoder LM  ->  mean-pooled hidden state  ->  AQBC binarization
           ->  exact angular KNN through the unified SearchEngine
           (core.engine; backend selected by name — including the
           pod-scale "sharded_scan"/"sharded_amih" backends of
           repro.shard, configured via the mesh/num_shards knobs, and
           the cross-host "cluster" tier of repro.cluster, selected by
           ``RetrievalConfig.cluster``/``hosts``).

This is the production shape of the paper: binary hashing exists to make
billion-item corpora searchable in RAM (paper §6.3.4); the LM zoo supplies
the embeddings; the engine supplies exact sublinear angular search over
the codes, *batched* — queued queries are answered ``search_batch_size``
at a time through one ``knn_batch`` call per step, the multi-index-hashing
serving shape (probing-sequence sharing amortizes across the batch).

``RetrievalService.build_index`` ingests documents (token arrays), encodes,
learns/applies AQBC, packs codes, builds the engine. ``search_batch``
answers a batch of queries in one engine call; ``search`` is the B=1
convenience; ``submit``/``run_queued`` expose the queued serving loop.

Queued serving is asynchronous and streamable (repro.pipeline):
``submit`` is thread-safe and returns a ``Ticket`` (int-compatible qid +
a future resolving to that query's (ids, sims));
``run_queued(stream=True)`` yields one ``StepResult`` per batch step as
it completes, encoding batch i+1 on the device while batch i searches,
with queue-depth and p50/p99 latency counters on each step's
``EngineStats``. ``RetrievalConfig.pipelined=True`` additionally turns
on the engine-level pipelining (AMIH verify/probe overlap,
shard-parallel probing) for the backends that support it.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import EngineStats, SearchEngine, linear_scan_knn, make_engine, pack_bits
from ..core import aqbc
from ..models import Model
from ..models.common import ArchConfig
from ..pipeline.stream import LatencyTracker, StepResult, Ticket, stream_search

__all__ = ["RetrievalConfig", "RetrievalService"]


@dataclass(frozen=True)
class RetrievalConfig:
    code_bits: int = 64
    aqbc_iters: int = 15
    m_tables: Optional[int] = None    # None -> paper's p/log2(n)
    batch_size: int = 32              # encode batch
    # core.engine backend name: "amih", "linear_scan", "single_table",
    # or the pod-scale "sharded_scan" / "sharded_amih" (repro.shard).
    backend: str = "amih"
    # AMIH grouped candidate verification: "numpy" (one vectorized host
    # popcount per z-group/tuple-step) or "pallas" (one
    # verify_tuples_grouped launch per step over the padded
    # (B_g, C_max, W) layout; DB stays device-resident from build).
    verify_backend: str = "numpy"
    # AMIH probing walk: "host" (the reference per-tuple Python walk) or
    # "device" (the fused probe -> bucket-lookup -> verify jitted launch;
    # see core.probe_device). Applies to "amih" and "sharded_amih";
    # probe_stream_cap bounds the precompiled probing stream per (p, z)
    # schedule before the scan fallback takes over. probe_fused (default)
    # stacks every z-group into ONE launch per batch — and, sharded, one
    # fused launch per DEVICE, dispatched to all devices without blocking
    # — False restores the per-z-group launches as a parity oracle.
    probe_backend: str = "host"
    probe_stream_cap: int = 1 << 16
    probe_fused: bool = True
    # linear_scan scoring: "numpy" (chunked host popcounts) or "pallas"
    # (streaming device top-K via kernels/ops.scan_topk + exact float64
    # host rerank).
    compute_backend: str = "numpy"
    # None -> backend default (max(8n, 16384)): bucket enumerations past
    # this degrade the query to an exact scan.
    enumeration_cap: Optional[int] = None
    search_batch_size: int = 32       # queued queries per knn_batch step
    # Sharded-backend layout knobs (repro.shard.ShardPlan): a mesh shards
    # the sharded_scan DB across devices (shard_axes selects the mesh
    # axes; None = all); num_shards is the host-side shard count when no
    # mesh is given; None -> one shard per local device.
    mesh: Optional[object] = None
    num_shards: Optional[int] = None
    shard_axes: Optional[Tuple[str, ...]] = None
    # Engine-level pipelining (repro.pipeline): "amih" gets the tuple-step
    # verify/probe overlap (overlap_verify), "sharded_amih" gets
    # shard-parallel probing under the shared warm-started bound
    # (probe_workers; None -> one worker per shard; the worker pool is
    # persistent — forked once per engine, released by service.close()).
    # Results stay bit-identical to the sequential engines.
    pipelined: bool = False
    probe_workers: Optional[int] = None
    # Worker flavor of the shard-probe pool: "process" (real CPU
    # parallelism on CPython), "thread" (free-threaded runtimes /
    # GIL-releasing device verification), or "auto" (process where fork
    # exists; the pallas verify backend forces thread either way).
    probe_mode: str = "auto"
    # Explicit per-shard placement devices for the sharded backends
    # (round-robin over shards); None derives placement from the mesh,
    # falling back to the local devices.
    devices: Optional[Tuple[object, ...]] = None
    # Cross-host serving tier (repro.cluster): cluster=True swaps the
    # engine for the "cluster" backend — a coordinator over ``hosts``
    # worker processes, each running ``backend`` (which must then be a
    # sharded backend; any other name serves via sharded_amih workers)
    # over its host-partitioned slice, with the monotone k-th-cosine
    # floor broadcast between hosts. Exact results, same knn_batch API;
    # the queued/streaming serving loop is unchanged on top.
    cluster: bool = False
    hosts: int = 2
    # End-to-end tracing (repro.obs): True installs an enabled Tracer at
    # build_index time (a float in (0, 1] additionally samples top-level
    # spans at that probability). Spans from every layer — engine,
    # AMIH probe/verify, kernel launches, and (cluster=True) the
    # cross-host worker spans — land on ``service.engine.tracer``;
    # export with repro.obs.export.write_chrome_trace. Off by default:
    # the disabled path is a single attribute check per span site.
    trace: object = False

    @property
    def engine(self) -> str:
        """Pre-shard name of ``backend``, kept for callers of the old
        field."""
        return self.backend


@dataclass
class RetrievalService:
    """End-to-end retrieval serving over one encoder LM + one engine.

    Lifecycle: construct with an encoder config/params and a
    ``RetrievalConfig``; ``build_index(doc_tokens)`` encodes the corpus,
    learns AQBC, packs codes and builds the engine; then either

      - ``search_batch(query_tokens, k)`` — one batched ``knn_batch``
        call, returns ``(ids, sims, EngineStats)``; ``search`` is the
        B=1 convenience returning the query's own stats object, or
      - ``submit(query_tokens) -> Ticket`` + ``run_queued(k[, stream])``
        — the queued/streaming serving loop (see the method docstrings).

    ``close()`` releases engine-held workers (the persistent shard-probe
    pool, the verify-overlap thread) — call it when retiring a service
    on a long-lived serving host; GC of the engine does it too.
    """

    cfg: ArchConfig
    params: object
    rcfg: RetrievalConfig = field(default_factory=RetrievalConfig)

    engine: Optional[SearchEngine] = None
    rotation: Optional[jax.Array] = None
    db_words: Optional[np.ndarray] = None
    shift: Optional[np.ndarray] = None   # non-negativity shift, fit at build
    _queue: List[Tuple[Ticket, np.ndarray]] = field(default_factory=list)
    _next_qid: int = 0
    # guards _queue/_next_qid: submit may be called from many request
    # threads while run_queued drains (the streaming serving shape)
    _lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False
    )
    # rolling submit->resolve latency over answered queries (ms)
    _latency: LatencyTracker = field(
        default_factory=LatencyTracker, repr=False
    )
    # jitted pooled-encoder forward, built once on first embed(): a fresh
    # @jax.jit closure per call would retrace+recompile on every batched
    # serving step (embed is the hot path of run_queued)
    _pooled: Optional[object] = field(default=None, repr=False)

    # ------------------------------------------------------------ encoding
    def _pooled_fn(self):
        """The jitted pooled forward (final-norm hidden states, not
        logits), built once and cached on the service."""
        if self._pooled is not None:
            return self._pooled
        from ..models import lm as lm_lib

        @jax.jit
        def pooled(tokens):
            h = lm_lib.embed_tokens(self.cfg, self.params, tokens)
            positions = jnp.arange(tokens.shape[1])
            window = (
                self.cfg.sliding_window if self.cfg.family == "hybrid" else 0
            )
            if self.cfg.first_k_dense:
                h, _ = lm_lib._apply_stack(
                    self.cfg.replace(n_experts=0),
                    self.params["front_layers"], h, positions,
                    window=window, moe=False,
                )
            h, _ = lm_lib._apply_stack(
                self.cfg, self.params["layers"], h, positions,
                window=window, moe=True,
            )
            from ..models.layers import apply_norm

            h = apply_norm(h, self.params["final_norm"], self.cfg.norm)
            return h.mean(axis=1).astype(jnp.float32)

        self._pooled = pooled
        return pooled

    def embed(self, token_batches: np.ndarray) -> np.ndarray:
        """(N, S) int32 tokens -> (N, d_model) float32 mean-pooled states."""
        pooled = self._pooled_fn()
        out = []
        B = self.rcfg.batch_size
        toks = np.asarray(token_batches, np.int32)
        for i in range(0, len(toks), B):
            chunk = toks[i : i + B]
            pad = B - len(chunk)
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            emb = np.asarray(pooled(jnp.asarray(chunk)))
            out.append(emb[: len(toks[i : i + B])])
        return np.concatenate(out, axis=0)

    def _shifted(self, x: np.ndarray, fit: bool) -> np.ndarray:
        """AQBC assumes non-negative data (SIFT/BoW regime); shift into the
        positive orthant per-dimension. The shift is FIT ON THE CORPUS and
        reused for queries — refitting per query would zero out single
        queries and break angle consistency."""
        if fit:
            self.shift = x.min(axis=0, keepdims=True)
        return np.maximum(x - self.shift, 0.0)

    # ------------------------------------------------------------ indexing
    def build_index(self, doc_tokens: np.ndarray) -> Dict[str, float]:
        x = self._shifted(self.embed(doc_tokens), fit=True)
        model = aqbc.learn(
            x, self.rcfg.code_bits, iters=self.rcfg.aqbc_iters
        )
        self.rotation = model.rotation
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        self.db_words = pack_bits(bits)
        shard_cfg: Dict[str, object] = {
            "mesh": self.rcfg.mesh,
            "num_shards": self.rcfg.num_shards,
            "shard_axes": self.rcfg.shard_axes,
            "devices": self.rcfg.devices,
        }
        cfg: Dict[str, object] = {}
        if self.rcfg.backend == "amih":
            cfg = {
                "m": self.rcfg.m_tables,
                "verify_backend": self.rcfg.verify_backend,
                "enumeration_cap": self.rcfg.enumeration_cap,
                "overlap_verify": self.rcfg.pipelined,
                "probe_backend": self.rcfg.probe_backend,
                "probe_stream_cap": self.rcfg.probe_stream_cap,
                "probe_fused": self.rcfg.probe_fused,
            }
        elif self.rcfg.backend == "linear_scan":
            cfg = {"compute_backend": self.rcfg.compute_backend}
        elif self.rcfg.backend == "single_table":
            cfg = {"enumeration_cap": self.rcfg.enumeration_cap}
        elif self.rcfg.backend == "sharded_scan":
            cfg = shard_cfg
        elif self.rcfg.backend == "sharded_amih":
            cfg = {
                **shard_cfg,
                "m": self.rcfg.m_tables,
                "verify_backend": self.rcfg.verify_backend,
                "enumeration_cap": self.rcfg.enumeration_cap,
                "probe_workers": self.rcfg.probe_workers,
                "probe_mode": self.rcfg.probe_mode,
                "probe_backend": self.rcfg.probe_backend,
                "probe_stream_cap": self.rcfg.probe_stream_cap,
                "probe_fused": self.rcfg.probe_fused,
            }
        backend = self.rcfg.backend
        if self.rcfg.cluster:
            # cross-host tier: the coordinator ships each worker its
            # host-partitioned slice; workers run the sharded flavor of
            # the configured backend (anything unsharded serves through
            # sharded_amih workers). Only JSON-serializable knobs cross
            # the wire — mesh/devices placement is re-derived per host.
            inner = backend if backend in ("sharded_amih", "sharded_scan") \
                else "sharded_amih"
            cfg = {
                "hosts": self.rcfg.hosts,
                "inner_backend": inner,
                "num_shards": self.rcfg.num_shards,
            }
            if inner == "sharded_amih":
                cfg.update(
                    m=self.rcfg.m_tables,
                    verify_backend=self.rcfg.verify_backend,
                    enumeration_cap=self.rcfg.enumeration_cap,
                    probe_backend=self.rcfg.probe_backend,
                    probe_stream_cap=self.rcfg.probe_stream_cap,
                    probe_fused=self.rcfg.probe_fused,
                )
            backend = "cluster"
        if self.rcfg.trace:
            from ..obs import trace as _obs_trace

            sample = (
                float(self.rcfg.trace)
                if isinstance(self.rcfg.trace, float) else 1.0
            )
            cfg["tracer"] = _obs_trace.Tracer(
                enabled=True, sample=sample, host="coordinator",
            )
        self.engine = make_engine(
            backend, self.db_words, self.rcfg.code_bits, **cfg
        )
        if (self.rcfg.backend == "sharded_amih" and not self.rcfg.cluster
                and self.rcfg.pipelined
                and self.rcfg.probe_workers is None):
            # pipelined default: one probe worker per (non-empty) shard
            self.engine.probe_workers = len(self.engine.indexes)
        index = getattr(self.engine, "index", None)
        return {
            "n_docs": float(len(doc_tokens)),
            "aqbc_objective": float(model.objective_trace[-1]),
            "m_tables": float(getattr(index, "m", 0)),
        }

    # -------------------------------------------------------------- search
    def encode_query(self, query_tokens: np.ndarray) -> np.ndarray:
        x = self.embed(
            query_tokens[None, :] if query_tokens.ndim == 1 else query_tokens
        )
        x = self._shifted(x, fit=False)
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        return pack_bits(bits)

    def search_batch(
        self, query_tokens: np.ndarray, k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, EngineStats]:
        """Exact angular KNN for a batch of queries through one
        ``knn_batch`` call. Returns (ids (B, k'), sims (B, k'), stats)."""
        assert self.engine is not None, "call build_index first"
        q_words = self.encode_query(query_tokens)
        return self.engine.knn_batch(q_words, k)

    def search(self, query_tokens: np.ndarray, k: int = 10):
        """Single-query convenience over ``search_batch`` (B=1).

        Returns (ids, sims, stats) where stats is the query's own counter
        object (AMIHStats / SearchStats — every backend provides one).
        """
        ids, sims, stats = self.search_batch(
            query_tokens[None, :] if query_tokens.ndim == 1 else query_tokens,
            k,
        )
        return ids[0], sims[0], stats.per_query[0]

    # ------------------------------------------------------ queued serving
    def submit(self, query_tokens: np.ndarray) -> Ticket:
        """Enqueue a query for the next batched search step (thread-safe).

        Returns a ``Ticket``: an int-compatible qid (old callers keep
        indexing result dicts with it) whose ``future`` resolves to this
        query's (ids, sims) when its batch step completes.
        """
        toks = np.asarray(query_tokens)
        with self._lock:
            ticket = Ticket(self._next_qid)
            self._next_qid += 1
            self._queue.append((ticket, toks))
        return ticket

    def queue_depth(self) -> int:
        """Queries currently waiting for a ``run_queued`` drain."""
        with self._lock:
            return len(self._queue)

    def run_queued(self, k: int = 10, stream: bool = False):
        """Drain the queue, ``search_batch_size`` queries per knn_batch
        step (the serving loop's batched shape).

        ``stream=False`` (default): blocks until the drain completes and
        returns qid -> (ids, sims), as before.

        ``stream=True``: returns an iterator of ``StepResult``s, one per
        batch step, yielded AS EACH STEP COMPLETES — step i+1 encodes on
        the device while step i searches (repro.pipeline.stream). Every
        step's ``EngineStats`` carries ``queue_depth`` and rolling
        p50/p99 ``latency_ms`` over answered queries (measured
        submit -> resolve); each answered ticket's future is resolved
        before its step is yielded.

        Queries submitted after the drain snapshot wait for the next
        ``run_queued`` call. If a step raises, unanswered queries are
        re-queued for a retry; their tickets' CURRENT futures fail with
        the step's exception (a blocked ``ticket.result()`` observes the
        dead drain instead of hanging) and are replaced with fresh ones
        that a successful retry drain resolves.
        """
        if stream:
            return self._run_queued_stream(k)
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for step in self._run_queued_stream(k):
            out.update(step.results)
        return out

    def _run_queued_stream(self, k: int) -> Iterator[StepResult]:
        assert self.engine is not None, "call build_index first"
        step_size = max(1, self.rcfg.search_batch_size)
        with self._lock:
            pending = self._queue
            self._queue = []
        steps = [
            pending[lo : lo + step_size]
            for lo in range(0, len(pending), step_size)
        ]
        done_steps = 0
        try:
            results = stream_search(
                self.engine,
                [np.stack([t for _, t in batch]) for batch in steps],
                k,
                encode=self.encode_query,
                stamp_latency=False,   # stamped below: submit -> resolve
            )
            for sr in results:
                now = time.perf_counter()
                batch = steps[sr.step]
                for row, (ticket, _) in enumerate(batch):
                    pair = (sr.ids[row], sr.sims[row])
                    sr.results[ticket.qid] = pair
                    self._latency.record(
                        1e3 * (now - ticket.submitted_at)
                    )
                    ticket.future.set_result(pair)
                # serving-level counters: true submit->resolve latency
                # and the queries still waiting behind this step
                sr.stats.latency_ms = self._latency.snapshot()
                sr.stats.queue_depth += self.queue_depth()
                done_steps += 1
                yield sr
        except GeneratorExit:
            # the CONSUMER abandoned the iterator early — nothing failed.
            # Re-queue the unanswered queries with their futures left
            # pending; the next drain resolves them.
            self._requeue(steps[done_steps:])
            raise
        except BaseException as exc:
            # a step actually died: unanswered queries go back to the
            # queue's front for a retry; their current futures FAIL (a
            # waiter blocked in ticket.result() must observe the dead
            # drain, not hang) and are replaced with fresh ones that the
            # retry drain resolves — futures are single-shot.
            requeued = self._requeue(steps[done_steps:])
            for ticket, _ in requeued:
                failed, ticket.future = ticket.future, Future()
                failed.set_exception(exc)
            raise

    def _requeue(self, unanswered_steps):
        """Push un-drained batches back onto the queue's front."""
        requeued = [item for batch in unanswered_steps for item in batch]
        with self._lock:
            self._queue[:0] = requeued
        return requeued

    def search_linear(self, query_tokens: np.ndarray, k: int = 10):
        """Exhaustive baseline over the same codes (cross-check)."""
        q_words = self.encode_query(query_tokens)[0]
        return linear_scan_knn(q_words, self.db_words, k)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Release engine-held workers (persistent shard-probe pool,
        verify-overlap thread). Idempotent; safe before build_index."""
        engine, close = self.engine, getattr(self.engine, "close", None)
        if engine is not None and callable(close):
            close()
