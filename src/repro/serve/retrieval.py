"""Retrieval service: the paper's technique as a first-class serving feature.

Pipeline:  encoder LM  ->  mean-pooled hidden state  ->  AQBC binarization
           ->  AMIH exact angular KNN  (host index)  +  device-sharded
           linear-scan reranker for pod-scale DBs (core.distributed).

This is the production shape of the paper: binary hashing exists to make
billion-item corpora searchable in RAM (paper §6.3.4); the LM zoo supplies
the embeddings; AMIH supplies exact sublinear angular search over the codes.

``RetrievalService.build_index`` ingests documents (token arrays), encodes,
learns/applies AQBC, packs codes, builds the AMIH index. ``search`` encodes
a query the same way and returns exact angular KNN (plus optionally the
device scan used as a cross-check / distributed fallback).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import AMIHIndex, AMIHStats, linear_scan_knn, pack_bits
from ..core import aqbc
from ..models import Model
from ..models.common import ArchConfig

__all__ = ["RetrievalConfig", "RetrievalService"]


@dataclass(frozen=True)
class RetrievalConfig:
    code_bits: int = 64
    aqbc_iters: int = 15
    m_tables: Optional[int] = None    # None -> paper's p/log2(n)
    batch_size: int = 32              # encode batch


@dataclass
class RetrievalService:
    cfg: ArchConfig
    params: object
    rcfg: RetrievalConfig = field(default_factory=RetrievalConfig)

    index: Optional[AMIHIndex] = None
    rotation: Optional[jax.Array] = None
    db_words: Optional[np.ndarray] = None
    shift: Optional[np.ndarray] = None   # non-negativity shift, fit at build

    # ------------------------------------------------------------ encoding
    def embed(self, token_batches: np.ndarray) -> np.ndarray:
        """(N, S) int32 tokens -> (N, d_model) float32 mean-pooled states."""
        # A dedicated pooled forward (final-norm hidden states, not logits):
        from ..models import lm as lm_lib

        @jax.jit
        def pooled(tokens):
            h = lm_lib.embed_tokens(self.cfg, self.params, tokens)
            positions = jnp.arange(tokens.shape[1])
            window = (
                self.cfg.sliding_window if self.cfg.family == "hybrid" else 0
            )
            if self.cfg.first_k_dense:
                h, _ = lm_lib._apply_stack(
                    self.cfg.replace(n_experts=0),
                    self.params["front_layers"], h, positions,
                    window=window, moe=False,
                )
            h, _ = lm_lib._apply_stack(
                self.cfg, self.params["layers"], h, positions,
                window=window, moe=True,
            )
            from ..models.layers import apply_norm

            h = apply_norm(h, self.params["final_norm"], self.cfg.norm)
            return h.mean(axis=1).astype(jnp.float32)

        out = []
        B = self.rcfg.batch_size
        toks = np.asarray(token_batches, np.int32)
        for i in range(0, len(toks), B):
            chunk = toks[i : i + B]
            pad = B - len(chunk)
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            emb = np.asarray(pooled(jnp.asarray(chunk)))
            out.append(emb[: len(toks[i : i + B])])
        return np.concatenate(out, axis=0)

    def _shifted(self, x: np.ndarray, fit: bool) -> np.ndarray:
        """AQBC assumes non-negative data (SIFT/BoW regime); shift into the
        positive orthant per-dimension. The shift is FIT ON THE CORPUS and
        reused for queries — refitting per query would zero out single
        queries and break angle consistency."""
        if fit:
            self.shift = x.min(axis=0, keepdims=True)
        return np.maximum(x - self.shift, 0.0)

    # ------------------------------------------------------------ indexing
    def build_index(self, doc_tokens: np.ndarray) -> Dict[str, float]:
        x = self._shifted(self.embed(doc_tokens), fit=True)
        model = aqbc.learn(
            x, self.rcfg.code_bits, iters=self.rcfg.aqbc_iters
        )
        self.rotation = model.rotation
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        self.db_words = pack_bits(bits)
        self.index = AMIHIndex.build(
            self.db_words, self.rcfg.code_bits, m=self.rcfg.m_tables
        )
        return {
            "n_docs": float(len(doc_tokens)),
            "aqbc_objective": float(model.objective_trace[-1]),
            "m_tables": float(self.index.m),
        }

    # -------------------------------------------------------------- search
    def encode_query(self, query_tokens: np.ndarray) -> np.ndarray:
        x = self.embed(
            query_tokens[None, :] if query_tokens.ndim == 1 else query_tokens
        )
        x = self._shifted(x, fit=False)
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        return pack_bits(bits)

    def search(
        self, query_tokens: np.ndarray, k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, AMIHStats]:
        """Exact angular KNN for one query. Returns (ids, sims, stats)."""
        assert self.index is not None, "call build_index first"
        q_words = self.encode_query(query_tokens)[0]
        stats = AMIHStats()
        ids, sims = self.index.knn(q_words, k, stats=stats)
        return ids, sims, stats

    def search_linear(self, query_tokens: np.ndarray, k: int = 10):
        """Exhaustive baseline over the same codes (cross-check)."""
        q_words = self.encode_query(query_tokens)[0]
        return linear_scan_knn(q_words, self.db_words, k)
