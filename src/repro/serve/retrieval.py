"""Retrieval service: the paper's technique as a first-class serving feature.

Pipeline:  encoder LM  ->  mean-pooled hidden state  ->  AQBC binarization
           ->  exact angular KNN through the unified SearchEngine
           (core.engine; backend selected by name — including the
           pod-scale "sharded_scan"/"sharded_amih" backends of
           repro.shard, configured via the mesh/num_shards knobs).

This is the production shape of the paper: binary hashing exists to make
billion-item corpora searchable in RAM (paper §6.3.4); the LM zoo supplies
the embeddings; the engine supplies exact sublinear angular search over
the codes, *batched* — queued queries are answered ``search_batch_size``
at a time through one ``knn_batch`` call per step, the multi-index-hashing
serving shape (probing-sequence sharing amortizes across the batch).

``RetrievalService.build_index`` ingests documents (token arrays), encodes,
learns/applies AQBC, packs codes, builds the engine. ``search_batch``
answers a batch of queries in one engine call; ``search`` is the B=1
convenience; ``submit``/``run_queued`` expose the queued serving loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import EngineStats, SearchEngine, linear_scan_knn, make_engine, pack_bits
from ..core import aqbc
from ..models import Model
from ..models.common import ArchConfig

__all__ = ["RetrievalConfig", "RetrievalService"]


@dataclass(frozen=True)
class RetrievalConfig:
    code_bits: int = 64
    aqbc_iters: int = 15
    m_tables: Optional[int] = None    # None -> paper's p/log2(n)
    batch_size: int = 32              # encode batch
    # core.engine backend name: "amih", "linear_scan", "single_table",
    # or the pod-scale "sharded_scan" / "sharded_amih" (repro.shard).
    backend: str = "amih"
    # AMIH grouped candidate verification: "numpy" (one vectorized host
    # popcount per z-group/tuple-step) or "pallas" (one
    # verify_tuples_grouped launch per step over the padded
    # (B_g, C_max, W) layout; DB stays device-resident from build).
    verify_backend: str = "numpy"
    # linear_scan scoring: "numpy" (chunked host popcounts) or "pallas"
    # (streaming device top-K via kernels/ops.scan_topk + exact float64
    # host rerank).
    compute_backend: str = "numpy"
    # None -> backend default (max(8n, 16384)): bucket enumerations past
    # this degrade the query to an exact scan.
    enumeration_cap: Optional[int] = None
    search_batch_size: int = 32       # queued queries per knn_batch step
    # Sharded-backend layout knobs (repro.shard.ShardPlan): a mesh shards
    # the sharded_scan DB across devices (shard_axes selects the mesh
    # axes; None = all); num_shards is the host-side shard count when no
    # mesh is given; None -> one shard per local device.
    mesh: Optional[object] = None
    num_shards: Optional[int] = None
    shard_axes: Optional[Tuple[str, ...]] = None

    @property
    def engine(self) -> str:
        """Pre-shard name of ``backend``, kept for callers of the old
        field."""
        return self.backend


@dataclass
class RetrievalService:
    cfg: ArchConfig
    params: object
    rcfg: RetrievalConfig = field(default_factory=RetrievalConfig)

    engine: Optional[SearchEngine] = None
    rotation: Optional[jax.Array] = None
    db_words: Optional[np.ndarray] = None
    shift: Optional[np.ndarray] = None   # non-negativity shift, fit at build
    _queue: List[Tuple[int, np.ndarray]] = field(default_factory=list)
    _next_qid: int = 0
    # jitted pooled-encoder forward, built once on first embed(): a fresh
    # @jax.jit closure per call would retrace+recompile on every batched
    # serving step (embed is the hot path of run_queued)
    _pooled: Optional[object] = field(default=None, repr=False)

    # ------------------------------------------------------------ encoding
    def _pooled_fn(self):
        """The jitted pooled forward (final-norm hidden states, not
        logits), built once and cached on the service."""
        if self._pooled is not None:
            return self._pooled
        from ..models import lm as lm_lib

        @jax.jit
        def pooled(tokens):
            h = lm_lib.embed_tokens(self.cfg, self.params, tokens)
            positions = jnp.arange(tokens.shape[1])
            window = (
                self.cfg.sliding_window if self.cfg.family == "hybrid" else 0
            )
            if self.cfg.first_k_dense:
                h, _ = lm_lib._apply_stack(
                    self.cfg.replace(n_experts=0),
                    self.params["front_layers"], h, positions,
                    window=window, moe=False,
                )
            h, _ = lm_lib._apply_stack(
                self.cfg, self.params["layers"], h, positions,
                window=window, moe=True,
            )
            from ..models.layers import apply_norm

            h = apply_norm(h, self.params["final_norm"], self.cfg.norm)
            return h.mean(axis=1).astype(jnp.float32)

        self._pooled = pooled
        return pooled

    def embed(self, token_batches: np.ndarray) -> np.ndarray:
        """(N, S) int32 tokens -> (N, d_model) float32 mean-pooled states."""
        pooled = self._pooled_fn()
        out = []
        B = self.rcfg.batch_size
        toks = np.asarray(token_batches, np.int32)
        for i in range(0, len(toks), B):
            chunk = toks[i : i + B]
            pad = B - len(chunk)
            if pad:
                chunk = np.pad(chunk, ((0, pad), (0, 0)))
            emb = np.asarray(pooled(jnp.asarray(chunk)))
            out.append(emb[: len(toks[i : i + B])])
        return np.concatenate(out, axis=0)

    def _shifted(self, x: np.ndarray, fit: bool) -> np.ndarray:
        """AQBC assumes non-negative data (SIFT/BoW regime); shift into the
        positive orthant per-dimension. The shift is FIT ON THE CORPUS and
        reused for queries — refitting per query would zero out single
        queries and break angle consistency."""
        if fit:
            self.shift = x.min(axis=0, keepdims=True)
        return np.maximum(x - self.shift, 0.0)

    # ------------------------------------------------------------ indexing
    def build_index(self, doc_tokens: np.ndarray) -> Dict[str, float]:
        x = self._shifted(self.embed(doc_tokens), fit=True)
        model = aqbc.learn(
            x, self.rcfg.code_bits, iters=self.rcfg.aqbc_iters
        )
        self.rotation = model.rotation
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        self.db_words = pack_bits(bits)
        shard_cfg: Dict[str, object] = {
            "mesh": self.rcfg.mesh,
            "num_shards": self.rcfg.num_shards,
            "shard_axes": self.rcfg.shard_axes,
        }
        cfg: Dict[str, object] = {}
        if self.rcfg.backend == "amih":
            cfg = {
                "m": self.rcfg.m_tables,
                "verify_backend": self.rcfg.verify_backend,
                "enumeration_cap": self.rcfg.enumeration_cap,
            }
        elif self.rcfg.backend == "linear_scan":
            cfg = {"compute_backend": self.rcfg.compute_backend}
        elif self.rcfg.backend == "single_table":
            cfg = {"enumeration_cap": self.rcfg.enumeration_cap}
        elif self.rcfg.backend == "sharded_scan":
            cfg = shard_cfg
        elif self.rcfg.backend == "sharded_amih":
            cfg = {
                **shard_cfg,
                "m": self.rcfg.m_tables,
                "verify_backend": self.rcfg.verify_backend,
                "enumeration_cap": self.rcfg.enumeration_cap,
            }
        self.engine = make_engine(
            self.rcfg.backend, self.db_words, self.rcfg.code_bits, **cfg
        )
        index = getattr(self.engine, "index", None)
        return {
            "n_docs": float(len(doc_tokens)),
            "aqbc_objective": float(model.objective_trace[-1]),
            "m_tables": float(getattr(index, "m", 0)),
        }

    # -------------------------------------------------------------- search
    def encode_query(self, query_tokens: np.ndarray) -> np.ndarray:
        x = self.embed(
            query_tokens[None, :] if query_tokens.ndim == 1 else query_tokens
        )
        x = self._shifted(x, fit=False)
        bits = np.asarray(aqbc.encode(jnp.asarray(x), self.rotation))
        return pack_bits(bits)

    def search_batch(
        self, query_tokens: np.ndarray, k: int = 10
    ) -> Tuple[np.ndarray, np.ndarray, EngineStats]:
        """Exact angular KNN for a batch of queries through one
        ``knn_batch`` call. Returns (ids (B, k'), sims (B, k'), stats)."""
        assert self.engine is not None, "call build_index first"
        q_words = self.encode_query(query_tokens)
        return self.engine.knn_batch(q_words, k)

    def search(self, query_tokens: np.ndarray, k: int = 10):
        """Single-query convenience over ``search_batch`` (B=1).

        Returns (ids, sims, stats) where stats is the query's own counter
        object (AMIHStats / SearchStats — every backend provides one).
        """
        ids, sims, stats = self.search_batch(
            query_tokens[None, :] if query_tokens.ndim == 1 else query_tokens,
            k,
        )
        return ids[0], sims[0], stats.per_query[0]

    # ------------------------------------------------------ queued serving
    def submit(self, query_tokens: np.ndarray) -> int:
        """Enqueue a query for the next batched search step; returns qid."""
        qid = self._next_qid
        self._next_qid += 1
        self._queue.append((qid, np.asarray(query_tokens)))
        return qid

    def run_queued(
        self, k: int = 10
    ) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Drain the queue, ``search_batch_size`` queries per knn_batch
        step (the serving loop's batched shape). Returns qid -> (ids, sims).
        """
        out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        step = max(1, self.rcfg.search_batch_size)
        while self._queue:
            batch = self._queue[:step]
            toks = np.stack([t for _, t in batch])
            ids, sims, _ = self.search_batch(toks, k)
            # pop only after the step succeeded, so a raise mid-drain
            # leaves the unanswered queries queued for a retry
            self._queue = self._queue[step:]
            for row, (qid, _) in enumerate(batch):
                out[qid] = (ids[row], sims[row])
        return out

    def search_linear(self, query_tokens: np.ndarray, k: int = 10):
        """Exhaustive baseline over the same codes (cross-check)."""
        q_words = self.encode_query(query_tokens)[0]
        return linear_scan_knn(q_words, self.db_words, k)
