"""Serving runtime: batched prefill/decode engine + the AMIH retrieval
service (the paper's technique as a first-class serving feature)."""

from .engine import ServeConfig, ServeEngine
from .retrieval import RetrievalConfig, RetrievalService

__all__ = ["RetrievalConfig", "RetrievalService", "ServeConfig", "ServeEngine"]
