"""Batched prefill/decode serving engine.

Slot-based continuous batching: a fixed device batch of ``max_batch``
slots; requests occupy slots, finished slots are refilled from the queue
without recompiling (shapes static). KV caches are preallocated at
``max_seq`` and written in place (donated through the jit'd step).

The decode step is exactly ``train.step.make_serve_step``'s function, so
the engine and the dry-run exercise the same lowered computation.

Fault tolerance: the engine snapshots (cache, slot table) on request; a
failed step replays from the last snapshot (the decode path is
deterministic).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model
from ..models.common import ArchConfig

__all__ = ["ServeConfig", "ServeEngine", "Request"]


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Single-host engine; the distributed variant shards params/cache via
    the same shardings the dry-run proves out (launch.shardings)."""

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.scfg = scfg
        self.model = Model(cfg)
        self.params = params
        B, S = scfg.max_batch, scfg.max_seq
        self.cache = self.model.init_cache(B, S)
        self._cache_tpl = self.model.cache_template(B, S)
        # slot table
        self.slot_req: List[Optional[Request]] = [None] * B
        self.slot_pos = np.zeros(B, dtype=np.int32)   # next position to write
        self.queue: List[Request] = []
        self._next_rid = 0

        # masked decode: only ``mask``-selected slots commit cache writes;
        # masking lives inside the jit so the old cache can be donated.
        def masked_decode(params, cache, tokens, pos, mask):
            logits, new_cache = self.model.decode_step(
                params, cache, tokens, pos
            )

            def select(new, old):
                shape = [1] * new.ndim
                shape[1] = new.shape[1]
                return jnp.where(mask.reshape(shape), new, old)

            merged = jax.tree.map(select, new_cache, cache)
            return logits, merged

        self._decode = jax.jit(masked_decode, donate_argnums=(1,))
        self._stats = {"prefills": 0, "decode_steps": 0, "tokens_out": 0}

    # --------------------------------------------------------------- API
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(
            Request(
                rid=rid,
                prompt=np.asarray(prompt, np.int32),
                max_new_tokens=max_new_tokens or self.scfg.max_new_tokens,
            )
        )
        return rid

    def run_until_drained(self) -> Dict[int, List[int]]:
        """Process the whole queue; returns {rid: generated tokens}."""
        results: Dict[int, List[int]] = {}
        while self.queue or any(r is not None for r in self.slot_req):
            self._fill_slots()
            self._step()
            for i, req in enumerate(self.slot_req):
                if req is not None and req.done:
                    results[req.rid] = req.generated
                    self.slot_req[i] = None
        return results

    @property
    def stats(self):
        return dict(self._stats)

    # ------------------------------------------------------------ internal
    def _fill_slots(self):
        for i in range(self.scfg.max_batch):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into_slot(i, req)

    def _prefill_into_slot(self, slot: int, req: Request):
        """Run prefill for one request; paste its KV into the engine cache.

        Single-sequence prefill (B=1) then scatter into slot. Production
        variant batches same-length prefills; correctness is identical.
        """
        S = len(req.prompt)
        assert S + req.max_new_tokens <= self.scfg.max_seq, "prompt too long"
        if self.cfg.family == "hybrid" and self.cfg.sliding_window:
            # ring-buffer KV: slot = pos % ring is the identity only while
            # the prompt fits the ring; longer prompts need chunked prefill
            assert S <= self.cfg.sliding_window, (
                "prompt longer than the attention window needs chunked "
                "prefill (not implemented in this engine)"
            )
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None, :]}
        logits, cache1 = self.model.prefill(self.params, batch)
        self._stats["prefills"] += 1

        # paste: every cache leaf has layout (L, B, ...); the prefill cache
        # has B=1 and possibly shorter trailing dims (seq = prompt length)
        def paste(full, part, tpl):
            part = part.astype(full.dtype)
            pads = [
                (0, 0) if d == 1 else (0, f - p)
                for d, (f, p) in enumerate(zip(tpl.shape, part.shape))
            ]
            part = jnp.pad(part, pads)
            return jax.lax.dynamic_update_index_in_dim(full, part[:, 0], slot, 1)

        self.cache = jax.tree.map(
            paste, self.cache, cache1, self._cache_tpl,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple),
        )
        self.slot_req[slot] = req
        self.slot_pos[slot] = S
        tok = self._select_token(np.asarray(logits), slot)
        req.generated.append(int(tok))
        self._stats["tokens_out"] += 1

    def _select_token(self, logits_row: np.ndarray, slot: int) -> int:
        if logits_row.ndim == 2:
            logits_row = logits_row[0]
        if self.scfg.greedy:
            return int(np.argmax(logits_row))
        rng = np.random.default_rng(
            self.scfg.seed + 7919 * self._stats["decode_steps"] + slot
        )
        p = np.exp(
            (logits_row - logits_row.max()) / max(self.scfg.temperature, 1e-6)
        )
        p /= p.sum()
        return int(rng.choice(len(p), p=p))

    def _step(self):
        active = [i for i, r in enumerate(self.slot_req) if r is not None and not r.done]
        if not active:
            return
        # NOTE: slots decode at a shared position; the engine groups slots
        # by position so RoPE/cache positions stay exact. Simplest correct
        # grouping: advance the *lagging* position group each step.
        pos_vals = {int(self.slot_pos[i]) for i in active}
        pos = min(pos_vals)
        group = [i for i in active if int(self.slot_pos[i]) == pos]
        tokens = np.zeros((self.scfg.max_batch, 1), np.int32)
        mask = np.zeros((self.scfg.max_batch,), bool)
        for i in group:
            tokens[i, 0] = self.slot_req[i].generated[-1]
            mask[i] = True
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.int32(pos),
            jnp.asarray(mask),
        )
        self._stats["decode_steps"] += 1
        logits = np.asarray(logits)
        for i in group:
            req = self.slot_req[i]
            tok = self._select_token(logits[i], i)
            req.generated.append(int(tok))
            self._stats["tokens_out"] += 1
            self.slot_pos[i] = pos + 1
            if (
                len(req.generated) >= req.max_new_tokens
                or int(self.slot_pos[i]) + 1 >= self.scfg.max_seq
            ):
                req.done = True
