"""jit'd train/serve step construction — the single source of truth used by
the Trainer, the serving engine, the benchmarks AND the production dry-run
(launch/dryrun.py lowers exactly these functions, so what is dry-run is
what runs).

``make_train_step``: loss -> grad -> (optional scan-microbatched
accumulation) -> (optional int8 compressed data-parallel mean) -> AdamW.
Gradients are mean-reduced over the batch axes implicitly by pjit (the
batch is sharded over pod/data; XLA inserts the reduce-scatter/all-reduce);
the explicit shard_map compression path replaces that collective with the
int8 error-feedback one.

``make_serve_step``: one decode token against a seq_len KV cache, the
function lowered for the decode_* / long_* dry-run shapes.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import jax_compat
from ..models import Model, input_specs
from ..models.common import ArchConfig, ShapeConfig
from ..models.sharding import DEFAULT_RULES, Rules, sharding_context
from ..optim import OptimConfig, apply_updates, init_state, state_specs
from ..launch import shardings as sh

__all__ = ["TrainConfig", "make_train_step", "make_serve_step"]


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1            # grad-accumulation steps (lax.scan)
    grad_compression: str = "none"   # none | int8 (error-feedback DP mean)
    compression_block: int = 256


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def make_train_step(
    cfg: ArchConfig,
    ocfg: OptimConfig,
    tcfg: TrainConfig = TrainConfig(),
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
    log: Optional[list] = None,
    opt_rules: Optional[Rules] = None,
):
    """Build (train_step, specs) for one architecture.

    Returns a dict with:
      step:            jit'd (params, opt_state, batch) -> (params, opt_state, metrics)
      param_specs:     ShapeDtypeStruct tree
      opt_specs:       ShapeDtypeStruct tree
      in_shardings:    (params, opt, batch) NamedSharding trees (mesh != None)
      out_shardings:   (params, opt, None)
      init:            (key) -> (params, opt_state) materializer
    """
    model = Model(cfg)
    rules = dict(rules or DEFAULT_RULES)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        nm = tcfg.microbatches

        def split(x):
            b = x.shape[0]
            assert b % nm == 0, (b, nm)
            return x.reshape((nm, b // nm) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            return _tree_add(acc, grads), metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        acc, metrics = jax.lax.scan(body, zero, mbs)
        grads = _tree_scale(acc, 1.0 / nm)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if mesh is not None:
            ctx = sharding_context(mesh, rules, log)
        else:
            from contextlib import nullcontext

            ctx = nullcontext()
        with ctx:
            grads, metrics = compute_grads(params, batch)
            new_params, new_opt, om = apply_updates(
                ocfg, params, grads, opt_state
            )
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_opt, metrics

    out: Dict[str, Any] = {
        "param_specs": model.param_specs(),
        "opt_specs": state_specs(ocfg, model.param_specs()),
    }

    def init(key):
        params = model.init_params(key)
        return params, init_state(ocfg, params)

    out["init"] = init

    if mesh is None:
        out["step"] = jax.jit(train_step, donate_argnums=(0, 1))
        return out

    pshard = sh.param_shardings(cfg, mesh, rules, log)
    # optimizer-only rules (ZeRO-style): moments may shard over the data
    # axes even where the live params do not — XLA inserts the
    # reduce-scatter(grads)/all-gather(updates) pair around the update
    oshard = sh.opt_shardings(ocfg, cfg, mesh, opt_rules or rules, log)
    out["in_shardings"] = (pshard, oshard)
    out["out_shardings"] = (pshard, oshard, None)

    def batch_shardings(batch_specs):
        return sh.batch_shardings(cfg, mesh, rules, batch_specs, log)

    out["batch_shardings"] = batch_shardings
    out["step"] = jax.jit(
        train_step,
        in_shardings=(pshard, oshard, None),  # batch shardings set at lower
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )

    def lower_for(shape: ShapeConfig):
        """Lower against ShapeDtypeStructs (the dry-run entry point)."""
        bspecs = input_specs(cfg, shape)
        bshard = batch_shardings(bspecs)
        specs_sharded = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
            for k, v in bspecs.items()
        }
        fn = jax.jit(
            train_step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        return fn.lower(out["param_specs"], out["opt_specs"], specs_sharded)

    out["lower_for"] = lower_for
    return out


def make_serve_step(
    cfg: ArchConfig,
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
    log: Optional[list] = None,
):
    """Build the single-token decode step (the decode-shape dry-run target).

    Returns dict with:
      step:       (params, cache, tokens, pos) -> (logits, cache)
      lower_for:  (shape) -> lowered (mesh != None); cache sized to
                  shape.seq_len, batch = shape.global_batch
    """
    model = Model(cfg)
    rules = dict(rules or DEFAULT_RULES)

    def serve_step(params, cache, tokens, pos):
        if mesh is not None:
            ctx = sharding_context(mesh, rules, log)
        else:
            from contextlib import nullcontext

            ctx = nullcontext()
        with ctx:
            logits, new_cache = model.decode_step(params, cache, tokens, pos)
        return logits, new_cache

    out: Dict[str, Any] = {"param_specs": model.param_specs()}
    if mesh is None:
        out["step"] = jax.jit(serve_step, donate_argnums=(1,))
        return out

    pshard = sh.param_shardings(cfg, mesh, rules, log)

    def lower_for(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        cache_tpl = model.cache_template(B, S)
        cshard = sh.cache_shardings(cfg, mesh, rules, cache_tpl, log)
        cache_specs = jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            cache_tpl,
            cshard,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        tok_shard = sh.batch_shardings(
            cfg, mesh, rules,
            {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}, log,
        )["tokens"]
        tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_shard)
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            serve_step,
            in_shardings=(pshard, cshard, tok_shard, None),
            out_shardings=(None, cshard),
            donate_argnums=(1,),
        )
        return fn.lower(out["param_specs"], cache_specs, tok_spec, pos_spec)

    out["lower_for"] = lower_for

    def lower_prefill(shape: ShapeConfig):
        """Lower the full-sequence prefill (prefill_* shapes)."""
        bspecs = input_specs(cfg, shape)
        bshard = sh.batch_shardings(cfg, mesh, rules, bspecs, log)
        specs_sharded = {
            k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
            for k, v in bspecs.items()
        }

        def prefill_fn(params, batch):
            with sharding_context(mesh, rules, log):
                return model.prefill(params, batch)

        fn = jax.jit(
            prefill_fn, in_shardings=(pshard, bshard), out_shardings=None
        )
        return fn.lower(out["param_specs"], specs_sharded)

    out["lower_prefill"] = lower_prefill
    return out


def make_dp_compressed_train_step(
    cfg: ArchConfig,
    ocfg: OptimConfig,
    mesh: Mesh,
    block: int = 256,
):
    """Data-parallel train step with int8 error-feedback gradient all-reduce.

    shard_map over the data axes: params replicated, batch row-sharded,
    per-shard grads compressed to int8 (+ carried residual) before the
    cross-shard mean — the explicit form of the distributed-optimization
    trick. The returned step threads ``residuals`` (f32 pytree, one per
    param) alongside the optimizer state.

    Scope: DP axes only (params replicated across them). Composing with TP
    keeps the pjit path (make_train_step), where XLA owns the collective.
    """
    model = Model(cfg)
    from ..optim.compression import compressed_psum_mean

    dp_axes = tuple(n for n in ("pod", "data") if n in mesh.axis_names)

    def local_step(params, opt_state, residuals, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params
        )
        mean_grads, new_res = compressed_psum_mean(
            grads, residuals, dp_axes, block
        )
        new_params, new_opt, om = apply_updates(
            ocfg, params, mean_grads, opt_state
        )
        metrics = dict(metrics)
        metrics.update(om)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), metrics)
        return new_params, new_opt, new_res, metrics

    rep = P()
    batch_spec = {"tokens": P(dp_axes)}
    if cfg.family == "vlm":
        batch_spec["vision_embeds"] = P(dp_axes)
    if cfg.family == "encdec":
        batch_spec["enc_frames"] = P(dp_axes)

    fn = jax_compat.shard_map(
        local_step,
        mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_vma=False,
    )
    return jax.jit(fn, donate_argnums=(0, 1, 2))
