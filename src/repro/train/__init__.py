"""Training runtime: jit step construction, fault-tolerant loop,
straggler watchdog."""

from .step import TrainConfig, make_serve_step, make_train_step
from .loop import Trainer, TrainerConfig
from .watchdog import StragglerWatchdog

__all__ = [
    "StragglerWatchdog",
    "TrainConfig",
    "Trainer",
    "TrainerConfig",
    "make_serve_step",
    "make_train_step",
]
