"""Fault-tolerant training loop.

Single-controller trainer that composes:

  - deterministic checkpointable data pipeline  (repro.data)
  - jit train step (pjit-sharded when a mesh is given)  (train.step)
  - atomic/async checkpointing with retention  (repro.checkpoint)
  - straggler watchdog driving proactive checkpoints  (train.watchdog)
  - crash recovery: a step failure restores the last checkpoint and
    replays — because the pipeline is a pure function of the step counter,
    recovery is bit-exact (tested), exactly the behaviour needed when a
    pod-scale job is pre-empted or a host dies.

Elasticity: checkpoints are logical (unsharded), so a restart may present
a different mesh/device count; ``Trainer.restore`` re-applies shardings
for whatever mesh it is given.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..data import DataConfig, TokenPipeline
from ..models.common import ArchConfig
from ..optim import OptimConfig
from .step import TrainConfig, make_train_step
from .watchdog import StragglerWatchdog

__all__ = ["Trainer", "TrainerConfig"]


@dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    max_restarts: int = 3
    log_every: int = 10
    seed: int = 0


@dataclass
class Trainer:
    cfg: ArchConfig
    ocfg: OptimConfig
    tcfg: TrainConfig
    rcfg: TrainerConfig
    data_cfg: DataConfig
    mesh: Optional[Any] = None
    rules: Optional[Dict] = None
    # test hook: fn(step) raising to simulate a mid-run failure
    failure_injector: Optional[Callable[[int], None]] = None

    history: List[Dict[str, float]] = field(default_factory=list)
    restarts: int = 0

    def __post_init__(self):
        self._built = make_train_step(
            self.cfg, self.ocfg, self.tcfg, mesh=self.mesh, rules=self.rules
        )
        self._ckpt = Checkpointer(
            self.rcfg.checkpoint_dir,
            keep=self.rcfg.keep_checkpoints,
            async_save=self.rcfg.async_checkpoint,
        )
        self._watchdog = StragglerWatchdog()
        self.pipeline = TokenPipeline(self.data_cfg)

    # ---------------------------------------------------------- state mgmt
    def _fresh_state(self):
        params, opt = self._built["init"](jax.random.key(self.rcfg.seed))
        return params, opt

    def _save(self, step: int, params, opt):
        tree = {"params": params, "opt": opt}
        meta = {"data": self.pipeline.state_dict(), "step": step}
        self._ckpt.save(step, tree, meta)

    def _restore(self):
        tmpl = {
            "params": self._built["param_specs"],
            "opt": self._built["opt_specs"],
        }
        tree, meta = self._ckpt.restore(tmpl)
        self.pipeline.load_state_dict(meta["data"])
        params, opt = tree["params"], tree["opt"]
        if self.mesh is not None:
            pshard, oshard = self._built["in_shardings"]
            params = jax.device_put(params, pshard)
            opt = jax.device_put(opt, oshard)
        else:
            params = jax.tree.map(jnp.asarray, params)
            opt = jax.tree.map(jnp.asarray, opt)
        return int(meta["step"]), params, opt

    # ------------------------------------------------------------- running
    def run(self) -> Dict[str, Any]:
        """Train to total_steps with crash recovery. Returns summary."""
        if self._ckpt.latest_step() is not None:
            step, params, opt = self._restore()
        else:
            step = 0
            params, opt = self._fresh_state()

        step_fn = self._built["step"]
        while step < self.rcfg.total_steps:
            try:
                t0 = time.perf_counter()
                if self.failure_injector is not None:
                    self.failure_injector(step)
                batch = self.pipeline.global_batch_at(step)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, metrics = step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at {step}")
                dt = time.perf_counter() - t0
                self._watchdog.observe(step, dt)
                self.history.append(
                    {"step": step, "loss": loss, "time_s": dt}
                )
                step += 1
                self.pipeline.step = step
                if (
                    step % self.rcfg.checkpoint_every == 0
                    or step == self.rcfg.total_steps
                    or self._watchdog.should_escalate
                ):
                    self._save(step, params, opt)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                self.restarts += 1
                if self.restarts > self.rcfg.max_restarts:
                    raise
                if self._ckpt.latest_step() is not None:
                    step, params, opt = self._restore()
                else:
                    step = 0
                    params, opt = self._fresh_state()
                    self.pipeline.step = 0
        self._ckpt.wait()
        return {
            "final_step": step,
            "restarts": self.restarts,
            "losses": [h["loss"] for h in self.history],
            "straggler_events": len(self._watchdog.events),
        }
