"""Straggler detection: trailing-median step-time watchdog.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, noisy
neighbors) stretch every synchronous step. The watchdog tracks a trailing
median of per-step wall times and flags any observation exceeding
``threshold x median``. In a multi-host deployment the flag handler
re-assigns the slow host's data shard and schedules the host for drain;
here the handler is a callback so tests/simulations can observe decisions.

Also used to drive *proactive checkpointing*: repeated flags raise
``should_checkpoint`` so work is persisted before a likely failure.
"""

from __future__ import annotations

import statistics
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

__all__ = ["StragglerWatchdog", "StragglerEvent"]


@dataclass
class StragglerEvent:
    step: int
    host: int
    duration_s: float
    median_s: float
    ratio: float


@dataclass
class StragglerWatchdog:
    window: int = 50              # trailing window of step times
    threshold: float = 2.0        # flag if step > threshold * median
    warmup: int = 5               # ignore the first few (compile) steps
    escalate_after: int = 3       # consecutive flags -> escalate
    on_flag: Optional[Callable[[StragglerEvent], None]] = None

    _times: Deque[float] = field(default_factory=deque, repr=False)
    _seen: int = 0
    _consecutive: int = 0
    events: List[StragglerEvent] = field(default_factory=list)

    def observe(self, step: int, duration_s: float, host: int = 0) -> bool:
        """Record one step time. Returns True if flagged as straggler."""
        self._seen += 1
        if self._seen <= self.warmup:
            return False
        flagged = False
        if len(self._times) >= max(3, self.window // 10):
            med = statistics.median(self._times)
            if med > 0 and duration_s > self.threshold * med:
                ev = StragglerEvent(
                    step=step,
                    host=host,
                    duration_s=duration_s,
                    median_s=med,
                    ratio=duration_s / med,
                )
                self.events.append(ev)
                if self.on_flag is not None:
                    self.on_flag(ev)
                self._consecutive += 1
                flagged = True
        if not flagged:
            self._consecutive = 0
            # only healthy samples update the baseline, so a degrading host
            # cannot drag the median up and mask itself
            self._times.append(duration_s)
            while len(self._times) > self.window:
                self._times.popleft()
        return flagged

    @property
    def should_escalate(self) -> bool:
        return self._consecutive >= self.escalate_after

    @property
    def median_s(self) -> float:
        return statistics.median(self._times) if self._times else 0.0
