"""Pallas TPU kernel: per-block score maxima for pruned exact scan (R2).

Phase 1 of the block-max pruned exact top-K (§Perf R2):

  maxima[b, j] = max over codes in db block j of sim(q_b, code)

The kernel computes the (B, blk) score tile in VMEM (same SWAR popcount +
Eq. 3 body as hamming_scan) but writes only its row-max — HBM traffic is
the packed codes once plus a (B, n_blocks) f32 matrix (4·B/blk bytes per
code instead of 4·B).

Phase 2 (ops.scan_topk_pruned) uses the exact bound: if mu_k is the k-th
largest block maximum for a query, every block with max < mu_k contains
only items with score < mu_k <= (true k-th best score), so it cannot hold
a top-K item (up to ties, which the >= threshold keeps). Only surviving
blocks are rescored. Exactness is property-tested against the full scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import popcount32

DEFAULT_BLK_N = 2048


def _blockmax_kernel(q_ref, z_ref, db_ref, out_ref, *, n_words: int):
    blk_q = q_ref.shape[0]
    blk_n = db_ref.shape[0]
    r10 = jnp.zeros((blk_q, blk_n), dtype=jnp.int32)
    r01 = jnp.zeros((blk_q, blk_n), dtype=jnp.int32)
    for w in range(n_words):
        qw = q_ref[:, w][:, None]
        dw = db_ref[:, w][None, :]
        r10 = r10 + popcount32(qw & ~dw)
        r01 = r01 + popcount32(~qw & dw)
    z = z_ref[:].astype(jnp.float32)[:, None]
    num = z - r10.astype(jnp.float32)
    den_sq = z * (z - r10.astype(jnp.float32) + r01.astype(jnp.float32))
    inv = jnp.where(
        den_sq > 0, jax.lax.rsqrt(jnp.where(den_sq > 0, den_sq, 1.0)), 0.0
    )
    sims = jnp.where(den_sq > 0, num * inv, 0.0)
    out_ref[...] = sims.max(axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def blockmax_scores(
    q_words: jax.Array,      # (B, W) uint32
    z_q: jax.Array,          # (B,) int32
    db_words: jax.Array,     # (N, W) uint32, N % blk_n == 0
    *,
    blk_n: int = DEFAULT_BLK_N,
    interpret: bool = True,
) -> jax.Array:
    """(B, n_blocks) per-block maxima of Eq. 3 scores."""
    B, W = q_words.shape
    N, Wd = db_words.shape
    assert W == Wd and N % blk_n == 0, (W, Wd, N, blk_n)
    n_blocks = N // blk_n
    return pl.pallas_call(
        functools.partial(_blockmax_kernel, n_words=W),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((B, W), lambda j: (0, 0)),
            pl.BlockSpec((B,), lambda j: (0,)),
            pl.BlockSpec((blk_n, W), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, n_blocks), jnp.float32),
        interpret=interpret,
    )(
        q_words.astype(jnp.uint32),
        z_q.astype(jnp.int32),
        db_words.astype(jnp.uint32),
    )
