"""Pallas TPU kernel: streaming angular scoring of packed binary codes.

The paper's linear-scan baseline and AMIH's candidate-verification hot loop
share one compute shape: XOR/ANDN + popcount between query words and a block
of code words, then the Eq. 3 cosine from the resulting tuple. On TPU this
is a VPU-integer, HBM-bandwidth-bound streaming kernel:

  grid = (N / BLK_N, B / BLK_Q)
  per step: db block (BLK_N, W) and query tile (BLK_Q, W) live in VMEM;
  the W word columns are statically unrolled so all intermediates are 2-D
  (BLK_Q, BLK_N) tiles aligned to the 8x128 VPU lanes; popcount is SWAR.

VMEM budget at defaults (BLK_Q=8, BLK_N=1024, W<=16):
  db 1024*16*4 = 64 KiB, q 8*16*4 = 0.5 KiB, acc 2 * 8*1024*4 = 64 KiB,
  out 8*1024*4 = 32 KiB  << 16 MiB VMEM.

MXU alignment: BLK_N is a multiple of 128 (lane dim), BLK_Q a multiple of 8
(sublane dim). The kernel never touches the MXU — it is bandwidth-bound by
design; its roofline is HBM bytes (16 B/code at p=128), which is why block
sizes favor large BLK_N (sequential HBM reads of the code array).

Validated on CPU via interpret mode against ref.py; on TPU the same
pallas_call lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import popcount32

DEFAULT_BLK_N = 1024
DEFAULT_BLK_Q = 8


def _scores_kernel(q_ref, z_ref, db_ref, out_ref, *, n_words: int):
    """One (BLK_Q, BLK_N) tile of Eq.3 cosine scores."""
    blk_q = q_ref.shape[0]
    blk_n = db_ref.shape[0]
    r10 = jnp.zeros((blk_q, blk_n), dtype=jnp.int32)
    r01 = jnp.zeros((blk_q, blk_n), dtype=jnp.int32)
    # Static unroll over words keeps every intermediate a 2-D VPU tile.
    for w in range(n_words):
        qw = q_ref[:, w][:, None]            # (BLK_Q, 1) uint32
        dw = db_ref[:, w][None, :]           # (1, BLK_N) uint32
        r10 = r10 + popcount32(qw & ~dw)
        r01 = r01 + popcount32(~qw & dw)
    z = z_ref[:].astype(jnp.float32)[:, None]
    num = z - r10.astype(jnp.float32)
    den_sq = z * (z - r10.astype(jnp.float32) + r01.astype(jnp.float32))
    inv = jnp.where(den_sq > 0, jax.lax.rsqrt(jnp.where(den_sq > 0, den_sq, 1.0)), 0.0)
    out_ref[...] = jnp.where(den_sq > 0, num * inv, 0.0)


@functools.partial(
    jax.jit, static_argnames=("blk_n", "blk_q", "interpret")
)
def hamming_scan_scores(
    q_words: jax.Array,
    z_q: jax.Array,
    db_words: jax.Array,
    *,
    blk_n: int = DEFAULT_BLK_N,
    blk_q: int = DEFAULT_BLK_Q,
    interpret: bool = True,
) -> jax.Array:
    """(B, W) x (N, W) -> (B, N) float32 Eq.3 cosine scores.

    B and N must be multiples of blk_q / blk_n (ops.py pads & masks).
    """
    B, W = q_words.shape
    N, Wd = db_words.shape
    assert W == Wd, (W, Wd)
    assert B % blk_q == 0 and N % blk_n == 0, (B, N, blk_q, blk_n)
    grid = (N // blk_n, B // blk_q)
    return pl.pallas_call(
        functools.partial(_scores_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_q, W), lambda i, j: (j, 0)),
            pl.BlockSpec((blk_q,), lambda i, j: (j,)),
            pl.BlockSpec((blk_n, W), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk_q, blk_n), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(q_words.astype(jnp.uint32), z_q.astype(jnp.int32), db_words.astype(jnp.uint32))
