"""Pallas TPU kernels for the paper's compute hot-spots.

- hamming_scan: streaming XOR+popcount+Eq.3 scoring (linear-scan baseline,
  distributed reranker)
- verify_tuples: batched exact-tuple verification (AMIH candidate
  pruning); verify_tuples_grouped runs a whole z-group per launch over a
  padded (B, C, W) layout with in-kernel padding masks and fused
  tuple->bucket-key packing
- blockmax_scan: per-block score maxima for the exact bound-pruned scan
  (§Perf R2 — fused traffic: codes once + (B, n_blocks))
- flash_attention: fused flash attention forward (§Perf L2 — prefill/serve
  hot spot of the LM zoo feeding the retrieval encoder)
- ops: jit'd public wrappers (padding, streaming top-K, pruned top-K,
  backend selection)
- ref: pure-jnp oracles used for validation and as the CPU path
"""

from . import ops, ref
from .blockmax_scan import blockmax_scores
from .flash_attention import flash_attention
from .hamming_scan import hamming_scan_scores
from .verify_tuples import verify_tuples, verify_tuples_grouped

__all__ = [
    "blockmax_scores",
    "flash_attention",
    "hamming_scan_scores",
    "ops",
    "ref",
    "verify_tuples",
    "verify_tuples_grouped",
]
