"""Pallas TPU kernel: fused flash attention (forward).

§Perf iteration L2: the pure-JAX blocked attention materializes its
(q_blk, Hq, kv_blk) score tensors to HBM — at llava-next prefill_32k that
scope is 55% of all modeled HBM traffic (1.85e14 B/device). This kernel
keeps scores, softmax state and the output accumulator in VMEM; HBM sees
only q/k/v reads and the output write.

Layout & tiling
  grid = (B, Hq, nq, nk)   — nk is minor-most: TPU grids execute
  sequentially, so VMEM scratch (m, l, acc) persists and accumulates
  across the kv sweep of one (b, h, iq) tile, flash-v2 style.
  q tile (q_blk, D) and kv tiles (kv_blk, D) in VMEM; D = head_dim.
  MXU alignment: q_blk, kv_blk multiples of 128 recommended; D is the
  contraction dim (128 for every assigned arch except gemma's 256 and
  whisper/hymba's 64 — all MXU-friendly).
  GQA: kv BlockSpecs index head h // (Hq // Hkv) — no KV duplication.

VMEM budget at defaults (q_blk=512, kv_blk=1024, D=128, f32 scratch):
  q 256 KiB + k,v 2x512 KiB + acc 256 KiB + m,l 2x2 KiB + s 2 MiB << 16 MiB.

Masking: causal and sliding-window masks are applied from absolute
positions; fully-masked kv tiles are skipped with @pl.when (the dominant
saving for causal prefill: ~2x fewer tiles).

Backward is served by the pure-JAX oracle path (layers.blocked_attention)
— the forward kernel is the serving-path / prefill hot spot; a fused
backward is recorded as future work in EXPERIMENTS.md.

Validated in interpret mode against models.layers._blocked_attention_impl
(tests/test_kernels_attention.py); lowers natively on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_Q_BLK = 512
DEFAULT_KV_BLK = 1024


def _flash_kernel(
    q_ref, k_ref, v_ref, vl_ref, o_ref, m_scr, l_scr, acc_scr,
    *, q_blk: int, kv_blk: int, causal: bool, window: int, sk: int,
    dynamic_len: bool,
):
    ik = pl.program_id(3)
    iq = pl.program_id(2)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, 1), 0)
    k_pos = ik * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (1, kv_blk), 1)

    # tile-level skip: tiles entirely above the causal diagonal, outside
    # the sliding window, or past the valid keys never touch the MXU.
    # With dynamic_len the static bound sk stays a conservative skip.
    first_q = iq * q_blk
    last_q = first_q + q_blk - 1
    first_k = ik * kv_blk
    last_k = first_k + kv_blk - 1
    live = first_k < sk
    if causal:
        live &= first_k <= last_q
    if window > 0 and not dynamic_len:
        live &= last_k >= first_q - window + 1

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)          # (q_blk, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (kv_blk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * (d ** -0.5)                              # (q_blk, kv_blk)
        ok = k_pos < sk
        if dynamic_len:
            # decode: only slots [0, vl) hold keys; with a window, only
            # the last ``window`` of them participate
            vl = vl_ref[0]
            ok = ok & (k_pos < vl)
            if window > 0:
                ok = ok & (k_pos >= vl - window)
        if causal:
            ok = ok & (q_pos >= k_pos)
        if window > 0 and not dynamic_len:
            ok = ok & (q_pos - k_pos < window)
        ok = jnp.broadcast_to(ok, (q_blk, kv_blk))
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                          # (q_blk, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(ok, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _emit():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_blk", "kv_blk", "interpret"),
)
def flash_attention(
    q: jax.Array,           # (B, Sq, Hq, D)
    k: jax.Array,           # (B, Sk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_blk: int = DEFAULT_Q_BLK,
    kv_blk: int = DEFAULT_KV_BLK,
    interpret: bool = True,
    valid_len: jax.Array | None = None,
) -> jax.Array:
    """Fused flash attention forward. Returns (B, Sq, Hq, D) in q.dtype.

    ``valid_len`` (scalar int32) enables flash-DECODE semantics: only key
    slots [0, valid_len) participate (with ``window``: only the trailing
    ``window`` of them) — the single-pass fused read of a partially-filled
    KV cache. Used by models.layers.decode_attention on TPU.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Sk)
    nq = -(-Sq // q_blk)
    nk = -(-Sk // kv_blk)
    Sq_p, Sk_p = nq * q_blk, nk * kv_blk
    # head-major layout so a (b, h) tile is a contiguous (S, D) slab
    qt = jnp.moveaxis(q, 2, 1)                       # (B, Hq, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if Sq_p != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))

    dynamic_len = valid_len is not None
    vl = jnp.full((1,), Sk, jnp.int32) if valid_len is None else (
        jnp.asarray(valid_len, jnp.int32).reshape(1)
    )
    kernel = functools.partial(
        _flash_kernel,
        q_blk=q_blk, kv_blk=kv_blk, causal=causal, window=window, sk=Sk,
        dynamic_len=dynamic_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, kv_blk, D), lambda b, h, i, j: (b, h // G, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, kv_blk, D), lambda b, h, i, j: (b, h // G, j, 0)
            ),
            pl.BlockSpec((1,), lambda b, h, i, j: (0,)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, q_blk, D), lambda b, h, i, j: (b, h, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),     # m: running max
            pltpu.VMEM((q_blk, 1), jnp.float32),     # l: running sum
            pltpu.VMEM((q_blk, D), jnp.float32),     # acc: output accum
        ],
        interpret=interpret,
    )(qt, kt, vt, vl)
    return jnp.moveaxis(out[:, :, :Sq], 1, 2)        # (B, Sq, Hq, D)
