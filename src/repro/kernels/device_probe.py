"""Fused device-resident AMIH probing walk (paper §4–§5, one launch).

``device_probe_walk`` compiles the whole probe -> bucket-lookup ->
verify -> top-K pipeline of one z-group into a single jitted
``lax.while_loop``: each iteration consumes a tile of the precomputed
probe stream (repro.core.probe_device.DeviceSchedule), expands the CSR
bucket ranges into at most ``cap`` candidate slots per query, gathers
the candidate codes from the device-resident padded DB, popcount-
verifies them (the ``verify_tuples_grouped`` Pallas kernel on TPU, the
XLA reference elsewhere), and scatter-mins each candidate's exact walk
position into a per-query (B, n_pad) position map. Rediscoveries
scatter the same position, so deduplication costs nothing.

Early termination is Prop. 2's k-th-cosine bound translated to walk
positions: after the entries of walk step t are all consumed, every
code with position <= t is guaranteed present in the map (pigeonhole
over the Prop. 4 cover), so a query is done once at least ``k``
positions <= min(t, t_stop) are mapped, or the walk has passed
``t_stop`` (the per-query stop-below bound; the full walk length when
unbounded). The check runs every ``check_every`` iterations (it scans
the position map), and the loop also yields after ``budget``
iterations: past that point one exhaustive ``device_probe_scan``
launch is cheaper than continuing to grind tile-by-tile through a
combinatorially deep walk — the device analogue of the host path's
enumeration-cap scan fallback.

Oversized buckets are split across iterations: when even a single
stream entry exceeds ``cap`` candidates for some query, the iteration
takes ``cap`` of them and resumes the same entry at offset ``off``
next round, so device memory stays bounded by (B, cap, W) regardless
of bucket skew.

``device_probe_scan`` is the fallback for truncated schedules (stream
cap or KMAX abort — the device analogue of the host enumeration-cap
guard): one launch verifies EVERY code against the still-undone
queries in chunks of a ``lax.map``, yielding the complete position
map. Either way a z-group costs O(1) launches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import ref
from .verify_tuples import DEFAULT_BLK_C, verify_tuples_grouped

POS_INF = jnp.int32(0x7FFFFFFF)

# Trace-time counters (same contract as verify_tuples.TRACE_COUNTS):
# bumped only when jax traces a new (shape, static-arg) signature, so
# tests can assert the power-of-two padding keeps the jit cache bounded.
TRACE_COUNTS = {
    "device_probe_walk": 0,
    "device_probe_scan": 0,
    "device_probe_walk_batched": 0,
    "device_probe_scan_multi": 0,
}


def _verify(q_words, gathered, totals, *, p, cap, use_pallas, interpret):
    """Packed bucket keys of the gathered (B, cap, W) candidates:
    Pallas kernel natively on TPU, XLA reference elsewhere."""
    if use_pallas:
        return verify_tuples_grouped(
            q_words, gathered, totals,
            p=p, blk_c=min(DEFAULT_BLK_C, cap), interpret=interpret,
        )
    return ref.verify_tuples_grouped_ref(q_words, gathered, totals, p)


@functools.partial(
    jax.jit,
    static_argnames=(
        "p", "tile", "cap", "kmax", "check_every", "use_pallas", "interpret"
    ),
)
def device_probe_walk(
    q_words,      # (B, W) uint32 packed queries
    q_sub,        # (B, m) int32 query substring values
    z_sub,        # (B, m) int32 substring popcounts
    pow1,         # (B, m, wmax+1) int32 one-position bit values
    pow0,         # (B, m, wmax+1) int32 zero-position bit values
    t_stop,       # (B,) int32 last walk position to consider (<0: done)
    k_arr,        # () int32 results wanted per query
    s_len,        # () int32 real stream entries
    budget,       # () int32 max iterations before the scan fallback
    tbl,          # (P,) int32 stream: table id per entry
    step_ext,     # (P+1,) int32 stream: walk step per entry (ext: built)
    idx1,         # (P, kmax) int32 one-side combination indices
    idx0,         # (P, kmax) int32 zero-side combination indices
    maxi1,        # (P,) int32 largest one-side index (-1: none)
    maxi0,        # (P,) int32 largest zero-side index (-1: none)
    widths,       # (m,) int32 substring widths
    offsets,      # (m, 2^wmax + 1) int32 dense CSR bucket offsets
    bucket_ids,   # (m, n_pad) int32 CSR sorted ids (pad: n_pad)
    db_pad,       # (n_pad, W) uint32 zero-padded packed codes
    inv_pos,      # ((p+1)^2,) int32 packed key -> walk position
    *,
    p: int,
    tile: int,
    cap: int,
    kmax: int,
    check_every: int,
    use_pallas: bool,
    interpret: bool,
):
    """One fused launch: walk the probe stream to completion or until
    every query terminates early. Returns (posmap (B, n_pad) int32,
    probes (B,) int32, retrieved (B,) int32, done (B,) bool,
    cursor () int32, iters () int32)."""
    TRACE_COUNTS["device_probe_walk"] += 1
    B = q_words.shape[0]
    n_pad = db_pad.shape[0]
    V = offsets.shape[1]
    wp1 = pow1.shape[2]
    col = jnp.arange(tile, dtype=jnp.int32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    brow = jnp.arange(B, dtype=jnp.int32)[:, None]
    pow1f = pow1.reshape(B, -1)
    pow0f = pow0.reshape(B, -1)
    offsf = offsets.reshape(-1)
    idsf = bucket_ids.reshape(-1)

    posmap0 = jnp.full((B, n_pad), POS_INF, dtype=jnp.int32)
    zeros_b = jnp.zeros((B,), dtype=jnp.int32)
    carry0 = (
        jnp.int32(0),              # cursor: next stream entry
        jnp.int32(0),              # off: resume offset into entry cursor
        t_stop < 0,                # done
        posmap0,
        zeros_b,                   # probes (bucket lookups) per query
        zeros_b,                   # retrieved candidates per query
        jnp.int32(0),              # iterations
    )

    def cond(c):
        cursor, _, done, _, _, _, it = c
        return (cursor < s_len) & ~done.all() & (it < budget)

    def body(c):
        cursor, off, done, posmap, probes, retrieved, it = c
        # -- tile of stream entries (P >= s_len + tile: never clamps)
        t_tbl = lax.dynamic_slice(tbl, (cursor,), (tile,))
        t_idx1 = lax.dynamic_slice(idx1, (cursor, 0), (tile, kmax))
        t_idx0 = lax.dynamic_slice(idx0, (cursor, 0), (tile, kmax))
        t_m1 = lax.dynamic_slice(maxi1, (cursor,), (tile,))
        t_m0 = lax.dynamic_slice(maxi0, (cursor,), (tile,))
        in_stream = (cursor + col) < s_len
        # -- per-query validity: the canonical combination only names
        #    actual one/zero positions of THIS query's substring
        zq = jnp.take(z_sub, t_tbl, axis=1)              # (B, tile)
        wd = jnp.take(widths, t_tbl)                     # (tile,)
        valid = (
            in_stream[None, :]
            & (~done)[:, None]
            & (t_m1[None, :] < zq)
            & (t_m0[None, :] < (wd[None, :] - zq))
        )
        # -- bucket value: XOR the OR-ed flip bits into the substring
        mask = jnp.zeros((B, tile), dtype=jnp.int32)
        for j in range(kmax):
            mask = (
                mask
                | jnp.take(pow1f, t_tbl * wp1 + t_idx1[:, j], axis=1)
                | jnp.take(pow0f, t_tbl * wp1 + t_idx0[:, j], axis=1)
            )
        vals = jnp.clip(jnp.take(q_sub, t_tbl, axis=1) ^ mask, 0, V - 2)
        foff = t_tbl[None, :] * V + vals
        lo = jnp.take(offsf, foff)
        hi = jnp.take(offsf, foff + 1)
        sizes = jnp.where(valid, hi - lo, 0)
        # -- greedy prefix of entries whose total fits cap (per query);
        #    entry `cursor` may resume mid-bucket at offset `off`
        adj = jnp.maximum(
            sizes - jnp.where(col == 0, off, 0)[None, :], 0
        )
        csum = jnp.cumsum(adj, axis=1)
        fits = csum.max(axis=0) <= cap          # monotone: a prefix
        n_take = fits.sum().astype(jnp.int32)
        partial = n_take == 0                   # entry 0 alone overflows
        take_sizes = jnp.where(col[None, :] < n_take, adj, 0)
        take_sizes = jnp.where(
            partial,
            jnp.where(col[None, :] == 0, jnp.minimum(adj, cap), 0),
            take_sizes,
        )
        starts = jnp.cumsum(take_sizes, axis=1) - take_sizes
        totals = take_sizes.sum(axis=1)         # (B,) <= cap
        # -- expand ranges to slots: mark each entry's first slot with
        #    its tile index + 1, running-max fills the rest
        marks = jnp.zeros((B, cap), dtype=jnp.int32).at[
            brow, starts
        ].max((col[None, :] + 1) * (take_sizes > 0), mode="drop")
        ent = jnp.maximum(lax.cummax(marks, axis=1) - 1, 0)
        within = slot[None, :] - jnp.take_along_axis(starts, ent, axis=1)
        base = (
            jnp.take_along_axis(lo, ent, axis=1)
            + jnp.where(ent == 0, off, 0)
            + within
        )
        vslot = slot[None, :] < totals[:, None]
        tt = t_tbl[ent]                         # (B, cap)
        cand = jnp.take(idsf, tt * n_pad + jnp.clip(base, 0, n_pad - 1))
        cand = jnp.where(vslot, cand, n_pad)    # n_pad: dropped below
        gathered = jnp.take(
            db_pad, jnp.minimum(cand, n_pad - 1), axis=0
        )                                        # (B, cap, W)
        keys = _verify(
            q_words, gathered, totals,
            p=p, cap=cap, use_pallas=use_pallas, interpret=interpret,
        )
        pos = jnp.where(
            keys >= 0,
            jnp.take(inv_pos, jnp.maximum(keys, 0)),
            POS_INF,
        )
        # idempotent dedup: a rediscovered candidate scatters its same
        # exact position; out-of-range cand (pad slots, CSR pad) drops
        posmap = posmap.at[brow, cand].min(pos, mode="drop")
        # -- cost counters (resumed entry 0 counts once, at off == 0)
        probes = probes + jnp.where(
            partial,
            (valid[:, 0] & (off == 0)).astype(jnp.int32),
            (
                valid
                & (col[None, :] < n_take)
                & ~((col[None, :] == 0) & (off > 0))
            ).sum(axis=1).astype(jnp.int32),
        )
        retrieved = retrieved + totals
        cursor2 = jnp.where(partial, cursor, cursor + n_take)
        off2 = jnp.where(partial, off + cap, jnp.int32(0))
        it2 = it + 1

        def check(d):
            # last fully completed walk step: every code at a position
            # <= T_comp is in the map (pigeonhole over Prop. 4's cover)
            T_comp = jnp.take(step_ext, cursor2) - 1
            eff = jnp.minimum(T_comp, t_stop)
            cnt = (posmap <= eff[:, None]).sum(axis=1)
            return d | (cnt >= k_arr) | (T_comp >= t_stop)

        done2 = lax.cond(
            ((it2 % check_every) == 0) | (cursor2 >= s_len),
            check,
            lambda d: d,
            done,
        )
        return (cursor2, off2, done2, posmap, probes, retrieved, it2)

    cursor, _, done, posmap, probes, retrieved, iters = lax.while_loop(
        cond, body, carry0
    )
    return posmap, probes, retrieved, done, cursor, iters


@functools.partial(
    jax.jit, static_argnames=("p", "chunk", "use_pallas", "interpret")
)
def device_probe_scan(
    q_words,      # (B, W) uint32 packed queries
    db_pad,       # (n_pad, W) uint32 zero-padded packed codes
    inv_pos,      # ((p+1)^2,) int32 packed key -> walk position
    n_valid,      # () int32 real code count (pad rows -> POS_INF)
    *,
    p: int,
    chunk: int,
    use_pallas: bool,
    interpret: bool,
):
    """Exhaustive position map: verify EVERY code against every query in
    one launch (``lax.map`` over row chunks keeps peak memory at
    (B, chunk, W)). Returns (B, n_pad) int32 exact walk positions —
    the fused form of the host enumeration-cap scan fallback."""
    TRACE_COUNTS["device_probe_scan"] += 1
    B, W = q_words.shape
    n_pad = db_pad.shape[0]
    assert n_pad % chunk == 0, (n_pad, chunk)
    lens = jnp.full((B,), chunk, dtype=jnp.int32)

    def one(args):
        ci, db_chunk = args
        gathered = jnp.broadcast_to(db_chunk[None], (B, chunk, W))
        keys = _verify(
            q_words, gathered, lens,
            p=p, cap=chunk, use_pallas=use_pallas, interpret=interpret,
        )
        rowid = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        return jnp.where(
            (keys >= 0) & (rowid[None, :] < n_valid),
            jnp.take(inv_pos, jnp.maximum(keys, 0)),
            POS_INF,
        )

    parts = lax.map(
        one,
        (
            jnp.arange(n_pad // chunk, dtype=jnp.int32),
            db_pad.reshape(n_pad // chunk, chunk, W),
        ),
    )
    return jnp.transpose(parts, (1, 0, 2)).reshape(B, n_pad)


def device_probe_walk_batched(
    posmap_in,    # (B, n_pad) int32 scratch (donated; contents ignored)
    q_words,      # (B, W) uint32 packed queries (mixed z-groups)
    q_sub,        # (B, m) int32 query substring values
    z_sub,        # (B, m) int32 substring popcounts
    pow1,         # (B, m, wmax+1) int32 one-position bit values
    pow0,         # (B, m, wmax+1) int32 zero-position bit values
    gid,          # (B,) int32 schedule-stack row per query
    t_stop,       # (B,) int32 last walk position to consider (<0: done)
    k_arr,        # () int32 results wanted per query
    budget,       # () int32 max iterations before the scan fallback
    g_start,      # (G,) int32 segment start per stack row (pad: 0)
    g_end,        # (G,) int32 segment start + s_len per row (pad: 0)
    tbl,          # (Pt,) int32 concatenated streams: table id per entry
    step_flat,    # (Pt,) int32 walk step per entry (segment pad: built)
    idx1,         # (Pt, kmax) int32 one-side combination indices
    idx0,         # (Pt, kmax) int32 zero-side combination indices
    maxi1,        # (Pt,) int32 largest one-side index (-1: none)
    maxi0,        # (Pt,) int32 largest zero-side index (-1: none)
    widths,       # (m,) int32 substring widths
    offsets,      # (m, 2^wmax + 1) int32 dense CSR bucket offsets
    bucket_ids,   # (m, n_pad) int32 CSR sorted ids (pad: n_pad)
    db_pad,       # (n_pad, W) uint32 zero-padded packed codes
    inv_pos,      # (G, (p+1)^2) int32 packed key -> walk position per row
    *,
    p: int,
    tile: int,
    cap: int,
    kmax: int,
    check_every: int,
    use_pallas: bool,
    interpret: bool,
):
    """Cross-z-group fused walk: ONE launch per batch, not per z-group.

    Every query carries a ``gid`` row into the concatenated schedule
    stack (``repro.core.probe_device.ScheduleStack``); the carry holds
    one absolute stream cursor and mid-bucket resume offset PER GROUP
    plus per-query done flags, so each group consumes its own stream at
    its own pace while every group's queries share each iteration's
    lookup/verify work. Per-group tile consumption is the per-z-group
    kernel's, computed with a segment scatter-max over that group's
    queries — cursor trajectories (and hence results and counters) are
    identical to running ``device_probe_walk`` once per group.

    A group advances only while it has an undone query and stream left
    (``active``); exhausted groups freeze and their unfinished queries
    fall through to the fused multi-group scan. Returns (posmap
    (B, n_pad) int32, probes (B,) int32, retrieved (B,) int32, done
    (B,) bool, cursor (G,) int32, iters () int32)."""
    TRACE_COUNTS["device_probe_walk_batched"] += 1
    B = q_words.shape[0]
    G = g_start.shape[0]
    n_pad = db_pad.shape[0]
    Pt = tbl.shape[0]
    V = offsets.shape[1]
    wp1 = pow1.shape[2]
    pp2 = inv_pos.shape[1]
    col = jnp.arange(tile, dtype=jnp.int32)
    slot = jnp.arange(cap, dtype=jnp.int32)
    brow = jnp.arange(B, dtype=jnp.int32)[:, None]
    pow1f = pow1.reshape(B, -1)
    pow0f = pow0.reshape(B, -1)
    offsf = offsets.reshape(-1)
    idsf = bucket_ids.reshape(-1)
    inv_posf = inv_pos.reshape(-1)
    g_end_q = jnp.take(g_end, gid)             # (B,)

    posmap0 = jnp.full_like(posmap_in, POS_INF)
    zeros_b = jnp.zeros((B,), dtype=jnp.int32)
    carry0 = (
        g_start,                       # (G,) cursor: next stream entry
        jnp.zeros((G,), jnp.int32),    # (G,) off: mid-bucket resume
        t_stop < 0,                    # (B,) done
        posmap0,
        zeros_b,                       # probes (bucket lookups) per query
        zeros_b,                       # retrieved candidates per query
        jnp.int32(0),                  # iterations
    )

    def group_active(cursor, done):
        g_undone = jnp.zeros((G,), bool).at[gid].max(~done, mode="drop")
        return g_undone & (cursor < g_end)

    def cond(c):
        cursor, _, done, _, _, _, it = c
        return group_active(cursor, done).any() & (it < budget)

    def body(c):
        cursor, off, done, posmap, probes, retrieved, it = c
        active_g = group_active(cursor, done)
        curq = jnp.take(cursor, gid)           # (B,)
        offq = jnp.take(off, gid)              # (B,)
        # -- per-query tile of the group's stream (absolute indices;
        #    clamped for gather safety — out-of-segment entries are
        #    masked by in_stream, so their values never matter)
        raw = curq[:, None] + col[None, :]     # (B, tile)
        tidx = jnp.minimum(raw, Pt - 1)
        t_tbl = jnp.take(tbl, tidx)            # (B, tile)
        t_m1 = jnp.take(maxi1, tidx)
        t_m0 = jnp.take(maxi0, tidx)
        in_stream = raw < g_end_q[:, None]
        zq = jnp.take_along_axis(z_sub, t_tbl, axis=1)
        wd = jnp.take(widths, t_tbl)           # (B, tile)
        valid = (
            in_stream
            & (~done)[:, None]
            & (t_m1 < zq)
            & (t_m0 < (wd - zq))
        )
        # -- bucket value: XOR the OR-ed flip bits into the substring
        mask = jnp.zeros((B, tile), dtype=jnp.int32)
        for j in range(kmax):
            i1 = jnp.take(idx1[:, j], tidx)
            i0 = jnp.take(idx0[:, j], tidx)
            mask = (
                mask
                | jnp.take_along_axis(pow1f, t_tbl * wp1 + i1, axis=1)
                | jnp.take_along_axis(pow0f, t_tbl * wp1 + i0, axis=1)
            )
        vals = jnp.clip(
            jnp.take_along_axis(q_sub, t_tbl, axis=1) ^ mask, 0, V - 2
        )
        foff = t_tbl * V + vals
        lo = jnp.take(offsf, foff)
        hi = jnp.take(offsf, foff + 1)
        sizes = jnp.where(valid, hi - lo, 0)
        # -- greedy per-group prefix of entries whose total fits cap:
        #    the group's limit is the max over ITS queries (segment
        #    scatter-max), exactly the per-z-group kernel's csum.max
        adj = jnp.maximum(
            sizes - jnp.where(col == 0, offq[:, None], 0), 0
        )
        csum = jnp.cumsum(adj, axis=1)
        gmax = jnp.zeros((G, tile), dtype=jnp.int32).at[gid].max(
            csum, mode="drop"
        )
        fits_g = gmax <= cap                    # monotone: a prefix
        n_take_g = fits_g.sum(axis=1).astype(jnp.int32)   # (G,)
        partial_g = n_take_g == 0               # entry 0 alone overflows
        n_take_q = jnp.take(n_take_g, gid)      # (B,)
        partial_q = jnp.take(partial_g, gid)    # (B,)
        take_sizes = jnp.where(col[None, :] < n_take_q[:, None], adj, 0)
        take_sizes = jnp.where(
            partial_q[:, None],
            jnp.where(col[None, :] == 0, jnp.minimum(adj, cap), 0),
            take_sizes,
        )
        starts = jnp.cumsum(take_sizes, axis=1) - take_sizes
        totals = take_sizes.sum(axis=1)         # (B,) <= cap
        # -- expand ranges to slots: mark each entry's first slot with
        #    its tile index + 1, running-max fills the rest
        marks = jnp.zeros((B, cap), dtype=jnp.int32).at[
            brow, starts
        ].max((col[None, :] + 1) * (take_sizes > 0), mode="drop")
        ent = jnp.maximum(lax.cummax(marks, axis=1) - 1, 0)
        within = slot[None, :] - jnp.take_along_axis(starts, ent, axis=1)
        base = (
            jnp.take_along_axis(lo, ent, axis=1)
            + jnp.where(ent == 0, offq[:, None], 0)
            + within
        )
        vslot = slot[None, :] < totals[:, None]
        tt = jnp.take_along_axis(t_tbl, ent, axis=1)      # (B, cap)
        cand = jnp.take(idsf, tt * n_pad + jnp.clip(base, 0, n_pad - 1))
        cand = jnp.where(vslot, cand, n_pad)    # n_pad: dropped below
        gathered = jnp.take(
            db_pad, jnp.minimum(cand, n_pad - 1), axis=0
        )                                        # (B, cap, W)
        keys = _verify(
            q_words, gathered, totals,
            p=p, cap=cap, use_pallas=use_pallas, interpret=interpret,
        )
        pos = jnp.where(
            keys >= 0,
            jnp.take(inv_posf, gid[:, None] * pp2 + jnp.maximum(keys, 0)),
            POS_INF,
        )
        posmap = posmap.at[brow, cand].min(pos, mode="drop")
        # -- cost counters (resumed entry 0 counts once, at off == 0)
        probes = probes + jnp.where(
            partial_q,
            (valid[:, 0] & (offq == 0)).astype(jnp.int32),
            (
                valid
                & (col[None, :] < n_take_q[:, None])
                & ~((col[None, :] == 0) & (offq > 0)[:, None])
            ).sum(axis=1).astype(jnp.int32),
        )
        retrieved = retrieved + totals
        # frozen groups (all queries done, or stream exhausted) keep
        # their cursor/off: they did no work this iteration
        adv = active_g & ~partial_g
        cursor2 = jnp.where(adv, cursor + n_take_g, cursor)
        off2 = jnp.where(
            active_g,
            jnp.where(partial_g, off + cap, jnp.int32(0)),
            off,
        )
        it2 = it + 1

        def check(d):
            # last fully completed walk step OF THE QUERY'S GROUP: every
            # code at a position <= T_comp is in the map (pigeonhole)
            cq = jnp.minimum(jnp.take(cursor2, gid), Pt - 1)
            T_comp = jnp.take(step_flat, cq) - 1
            eff = jnp.minimum(T_comp, t_stop)
            cnt = (posmap <= eff[:, None]).sum(axis=1)
            return d | (cnt >= k_arr) | (T_comp >= t_stop)

        done2 = lax.cond(
            ((it2 % check_every) == 0)
            | ~group_active(cursor2, done).any(),
            check,
            lambda d: d,
            done,
        )
        return (cursor2, off2, done2, posmap, probes, retrieved, it2)

    cursor, _, done, posmap, probes, retrieved, iters = lax.while_loop(
        cond, body, carry0
    )
    return posmap, probes, retrieved, done, cursor, iters


def device_probe_scan_multi(
    q_words,      # (B, W) uint32 packed queries (mixed z-groups)
    gid,          # (B,) int32 schedule-stack row per query
    db_pad,       # (n_pad, W) uint32 zero-padded packed codes
    inv_pos,      # (G, (p+1)^2) int32 packed key -> walk position per row
    n_valid,      # () int32 real code count (pad rows -> POS_INF)
    *,
    p: int,
    chunk: int,
    use_pallas: bool,
    interpret: bool,
):
    """Cross-z-group exhaustive position map: ``device_probe_scan`` with
    a per-query ``gid`` row into the stacked inverse-position tables, so
    ONE launch finishes the bailed queries of EVERY group in the batch.
    Returns (B, n_pad) int32 exact walk positions."""
    TRACE_COUNTS["device_probe_scan_multi"] += 1
    B, W = q_words.shape
    n_pad = db_pad.shape[0]
    pp2 = inv_pos.shape[1]
    inv_posf = inv_pos.reshape(-1)
    assert n_pad % chunk == 0, (n_pad, chunk)
    lens = jnp.full((B,), chunk, dtype=jnp.int32)

    def one(args):
        ci, db_chunk = args
        gathered = jnp.broadcast_to(db_chunk[None], (B, chunk, W))
        keys = _verify(
            q_words, gathered, lens,
            p=p, cap=chunk, use_pallas=use_pallas, interpret=interpret,
        )
        rowid = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        return jnp.where(
            (keys >= 0) & (rowid[None, :] < n_valid),
            jnp.take(
                inv_posf, gid[:, None] * pp2 + jnp.maximum(keys, 0)
            ),
            POS_INF,
        )

    parts = lax.map(
        one,
        (
            jnp.arange(n_pad // chunk, dtype=jnp.int32),
            db_pad.reshape(n_pad // chunk, chunk, W),
        ),
    )
    return jnp.transpose(parts, (1, 0, 2)).reshape(B, n_pad)
