"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

All kernels operate on packed binary codes: uint32 words, LSB-first,
W = ceil(p/32) words per code (see repro.core.packing). The popcount is a
SWAR reduction (no native popcount in jnp on all backends).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "popcount32",
    "tuples_ref",
    "scores_ref",
    "scan_scores_ref",
    "verify_tuples_ref",
    "verify_tuples_grouped_ref",
]


def popcount32(v: jnp.ndarray) -> jnp.ndarray:
    """SWAR popcount of uint32 lanes -> int32 counts."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    v = (v * jnp.uint32(0x01010101)) >> 24
    return v.astype(jnp.int32)


def tuples_ref(q_words: jnp.ndarray, db_words: jnp.ndarray):
    """Hamming tuples of every (query, code) pair.

    q_words: (B, W) uint32; db_words: (N, W) uint32
    returns r10, r01: (B, N) int32.
    """
    q = q_words.astype(jnp.uint32)[:, None, :]
    b = db_words.astype(jnp.uint32)[None, :, :]
    r10 = popcount32(q & ~b).sum(axis=-1)
    r01 = popcount32(~q & b).sum(axis=-1)
    return r10.astype(jnp.int32), r01.astype(jnp.int32)


def scores_from_tuples(z_q: jnp.ndarray, r10: jnp.ndarray, r01: jnp.ndarray):
    """Eq. 3 cosine sims from tuples; zero-norm guards -> 0.0.

    z_q: (B,) int32 query popcounts; r10, r01: (B, N) int32.
    """
    z = z_q.astype(jnp.float32)[:, None]
    num = z - r10.astype(jnp.float32)
    den_sq = z * (z - r10.astype(jnp.float32) + r01.astype(jnp.float32))
    sims = num * jax_rsqrt_safe(den_sq)
    return jnp.where(den_sq <= 0, 0.0, sims)


def jax_rsqrt_safe(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(x > 0, 1.0 / jnp.sqrt(jnp.where(x > 0, x, 1.0)), 0.0)


def scores_ref(q_words: jnp.ndarray, db_words: jnp.ndarray, z_q: jnp.ndarray):
    """(B, N) float32 cosine sims of packed queries vs packed codes."""
    r10, r01 = tuples_ref(q_words, db_words)
    return scores_from_tuples(z_q, r10, r01)


# aliases used by tests to mirror the kernel entry points
scan_scores_ref = scores_ref


def verify_tuples_ref(q_words: jnp.ndarray, cand_words: jnp.ndarray):
    """Single query vs candidate block: (W,), (N, W) -> (r10, r01) (N,) int32."""
    r10, r01 = tuples_ref(q_words[None, :], cand_words)
    return r10[0], r01[0]


def verify_tuples_grouped_ref(
    q_words: jnp.ndarray,
    cand_words: jnp.ndarray,
    lengths: jnp.ndarray,
    p: int,
):
    """Grouped-verification oracle: (B, W), (B, C, W), (B,) -> (B, C) int32
    packed bucket keys ``r10 * (p + 1) + r01``, -1 where ``c >= lengths[b]``
    (padding). Mirrors kernels/verify_tuples.verify_tuples_grouped."""
    q = q_words.astype(jnp.uint32)[:, None, :]
    c = cand_words.astype(jnp.uint32)
    r10 = popcount32(q & ~c).sum(axis=-1).astype(jnp.int32)
    r01 = popcount32(~q & c).sum(axis=-1).astype(jnp.int32)
    key = r10 * jnp.int32(p + 1) + r01
    valid = jnp.arange(c.shape[1], dtype=jnp.int32)[None, :] < (
        lengths.astype(jnp.int32)[:, None]
    )
    return jnp.where(valid, key, jnp.int32(-1))
