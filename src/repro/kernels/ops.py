"""Public jit'd entry points over the Pallas kernels.

Handles block padding/masking, streaming top-K over DB chunks (bounded
memory — never materializes (B, N) for huge N), and backend selection:
Pallas lowers natively on TPU; everywhere else the same kernel body runs
under ``interpret=True`` (and a pure-XLA reference path is available for
speed on CPU).
"""

from __future__ import annotations

import functools
import threading
import warnings
from collections.abc import Mapping
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import trace as _obs
from ..obs.metrics import REGISTRY as _REG
from . import ref
from .hamming_scan import DEFAULT_BLK_N, DEFAULT_BLK_Q, hamming_scan_scores
from .verify_tuples import DEFAULT_BLK_C
from .verify_tuples import verify_tuples as _verify_tuples_kernel
from .verify_tuples import verify_tuples_grouped as _verify_grouped_kernel

__all__ = [
    "LAUNCH_COUNTS",
    "LAUNCH_COUNTS_BY_DEVICE",
    "PendingKeys",
    "PendingWalk",
    "device_key",
    "device_probe_scan_launch",
    "device_probe_scan_multi_launch",
    "device_probe_walk_batched_launch",
    "device_probe_walk_launch",
    "merge_topk",
    "on_tpu",
    "pad_bucket",
    "scan_scores",
    "scan_topk",
    "verify_tuples_grouped_launch",
    "verify_tuples_grouped_op",
    "verify_tuples_op",
]

# Host-side launch accounting: bumped once per device dispatch of each op,
# into the process metrics registry under ``launches.<op>``.
# AMIH's batched verification asserts exactly one grouped launch per
# (z-group, tuple-step) through this counter (see tests/test_verify_grouped);
# the device probe path asserts O(1) launches per z-group through
# "device_probe" (the fused walk) and "device_probe_scan" (the at-most-one
# exhaustive fallback for truncated schedules).
_LAUNCH_KEYS = ("verify_grouped", "verify", "device_probe",
                "device_probe_scan")


class _DeprecatedLaunchCounts(Mapping):
    """The old ``ops.LAUNCH_COUNTS`` dict surface, now a read-only view
    of the ``launches.*`` registry counters. Direct reads warn — new
    code reads ``repro.obs.metrics.REGISTRY.value("launches.<op>")``."""

    def __getitem__(self, key: str) -> int:
        warnings.warn(
            "ops.LAUNCH_COUNTS is deprecated; read "
            "repro.obs.metrics.REGISTRY.value('launches.<op>') instead",
            DeprecationWarning, stacklevel=2,
        )
        if key not in _LAUNCH_KEYS:
            raise KeyError(key)
        return _REG.value("launches." + key)

    def __iter__(self):
        return iter(_LAUNCH_KEYS)

    def __len__(self) -> int:
        return len(_LAUNCH_KEYS)


LAUNCH_COUNTS = _DeprecatedLaunchCounts()

# Per-device split of the grouped-verify launches: device key -> count.
# The mesh-resident sharded AMIH path places each shard's verification on
# that shard's assigned device; tests assert the placement actually
# happened (not just that the arrays were tagged) through this counter.
# Mirrored into the registry as ``launches.device.<dkey>``.
LAUNCH_COUNTS_BY_DEVICE: dict = {}

# Guards the counter bumps: thread-mode shard probing (forced for the
# pallas verify backend) dispatches launches from several threads, and
# dict get+store is not atomic — an unguarded bump could drop counts the
# placement tests assert on.
_LAUNCH_LOCK = threading.Lock()


def _bump_launch(op: str, dkey: "str | None" = None) -> None:
    """One device dispatch of ``op``: bump ``launches.<op>`` (and the
    per-device split when the launch was placed)."""
    _REG.counter("launches." + op).add(1)
    if dkey is not None:
        _REG.counter("launches.device." + dkey).add(1)
        with _LAUNCH_LOCK:
            LAUNCH_COUNTS_BY_DEVICE[dkey] = (
                LAUNCH_COUNTS_BY_DEVICE.get(dkey, 0) + 1
            )


def device_key(device) -> str:
    """Stable string key for a placement device (``"default"`` for None —
    the unplaced path that follows jax's default device)."""
    return "default" if device is None else str(device)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_bucket(size: int, minimum: int = 8) -> int:
    """Next power of two >= max(size, minimum).

    Dynamic AMIH candidate blocks are padded to these buckets before
    hitting jit, so the trace cache holds at most O(log(max_size)) entries
    per axis instead of one per distinct ragged shape.
    """
    target = max(int(size), minimum, 1)
    return 1 << (target - 1).bit_length()


def _pad_to(x: jax.Array, axis: int, multiple: int, fill=0):
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad, constant_values=fill)


def scan_scores(
    q_words: jax.Array,
    db_words: jax.Array,
    *,
    use_pallas: bool | None = None,
    blk_n: int = DEFAULT_BLK_N,
    blk_q: int = DEFAULT_BLK_Q,
) -> jax.Array:
    """(B, W), (N, W) -> (B, N) Eq.3 cosine scores (float32).

    use_pallas=None picks the kernel on TPU and interpret-mode Pallas
    elsewhere only for modest sizes (interpret mode is a correctness tool,
    not a fast CPU path); the jnp reference is semantically identical.
    """
    B, _ = q_words.shape
    N, _ = db_words.shape
    z_q = ref.popcount32(q_words.astype(jnp.uint32)).sum(axis=-1)
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas:
        return ref.scores_ref(q_words, db_words, z_q)
    qp = _pad_to(q_words, 0, blk_q)
    zp = _pad_to(z_q, 0, blk_q)
    dbp = _pad_to(db_words, 0, blk_n)
    sims = hamming_scan_scores(
        qp, zp, dbp, blk_n=blk_n, blk_q=blk_q, interpret=not on_tpu()
    )
    return sims[:B, :N]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "use_pallas"))
def scan_topk(
    q_words: jax.Array,
    db_words: jax.Array,
    k: int,
    *,
    chunk: int = 1 << 16,
    use_pallas: bool = False,
    n_valid: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Streaming exact angular top-K: (B, W) x (N, W) -> sims, ids (B, k).

    The DB is processed in chunks with a running top-K merge
    (lax.scan carry), so peak memory is O(B * (k + chunk)) regardless of N.
    This is the device-side linear-scan baseline *and* the reranker of the
    distributed retrieval path.

    ``n_valid`` (traced scalar) masks rows >= n_valid to -inf sims: shard
    slices padded to a common row count (ShardPlan's device layout) scan
    without their zero-code pad rows ever entering the top-K.
    """
    B, W = q_words.shape
    N, _ = db_words.shape
    k = min(k, N)
    chunk = min(chunk, N)
    n_chunks = (N + chunk - 1) // chunk
    padded_n = n_chunks * chunk
    dbp = jnp.pad(db_words, ((0, padded_n - N), (0, 0)))
    dbp = dbp.reshape(n_chunks, chunk, W)
    row_ids = jnp.arange(padded_n).reshape(n_chunks, chunk)
    base_valid = row_ids < N
    if n_valid is not None:
        base_valid = base_valid & (row_ids < n_valid)

    init_sims = jnp.full((B, k), -jnp.inf, dtype=jnp.float32)
    init_ids = jnp.full((B, k), -1, dtype=jnp.int32)

    def step(carry, inp):
        best_sims, best_ids = carry
        db_chunk, valid, chunk_idx = inp
        sims = scan_scores(q_words, db_chunk, use_pallas=use_pallas)
        sims = jnp.where(valid[None, :], sims, -jnp.inf)
        ids = (chunk_idx * chunk + jnp.arange(chunk, dtype=jnp.int32))[None, :]
        ids = jnp.broadcast_to(ids, sims.shape)
        all_sims = jnp.concatenate([best_sims, sims], axis=1)
        all_ids = jnp.concatenate([best_ids, ids], axis=1)
        new_sims, pos = jax.lax.top_k(all_sims, k)
        new_ids = jnp.take_along_axis(all_ids, pos, axis=1)
        return (new_sims, new_ids), None

    (sims, ids), _ = jax.lax.scan(
        step,
        (init_sims, init_ids),
        (dbp, base_valid, jnp.arange(n_chunks, dtype=jnp.int32)),
    )
    return sims, ids


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(
    sims: jax.Array, ids: jax.Array, k: int
) -> Tuple[jax.Array, jax.Array]:
    """Merge per-shard candidate pools: (B, C) sims/ids -> top-k (B, k).

    C is the concatenation of every shard's local top-K (the O(K)-per-shard
    all-gather layout of the sharded engines); invalid slots carry -inf
    sims so they lose to every real candidate. One lax.top_k, no re-scan.
    """
    k = min(k, sims.shape[1])
    best, pos = jax.lax.top_k(sims, k)
    return best, jnp.take_along_axis(ids, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "blk", "use_pallas"))
def scan_topk_pruned(
    q_words: jax.Array,
    db_words: jax.Array,
    k: int,
    *,
    blk: int = 2048,
    use_pallas: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Block-max pruned EXACT angular top-K (§Perf R2).

    Phase 1: per-block score maxima (blockmax_scan kernel — HBM sees the
    codes once plus a tiny (B, n_blocks) matrix).
    Phase 2: bound mu_k = k-th largest block max per query. A block with
    max < mu_k cannot contain a top-K item: at least k items (one per
    block above the bound) score >= mu_k, so everything in that block is
    beaten. Only surviving blocks are rescored, under ``lax.cond`` so
    pruned blocks skip the scoring work entirely.

    Returns (sims, ids, scanned_fraction) — the last is the measured
    fraction of blocks rescored (pruning power; 1.0 = no pruning).
    Exact for any input; property-tested against scan_topk.
    """
    from .blockmax_scan import blockmax_scores

    B, W = q_words.shape
    N, _ = db_words.shape
    k = min(k, N)
    blk = min(blk, N)
    n_blocks = -(-N // blk)
    padded_n = n_blocks * blk
    dbp = jnp.pad(db_words, ((0, padded_n - N), (0, 0)))
    z_q = ref.popcount32(q_words.astype(jnp.uint32)).sum(axis=-1)

    if use_pallas:
        maxima = blockmax_scores(
            q_words, z_q, dbp, blk_n=blk, interpret=not on_tpu()
        )
        if padded_n != N:  # padded zero-codes score 0.0; mask via re-max
            pass  # zero codes score 0.0 <= any real max; harmless for max
    else:  # jnp oracle path (identical math)
        sims_all = ref.scores_ref(q_words, dbp, z_q)
        valid = jnp.arange(padded_n) < N
        sims_all = jnp.where(valid[None, :], sims_all, -jnp.inf)
        maxima = sims_all.reshape(B, n_blocks, blk).max(axis=-1)

    kk = min(k, n_blocks)
    mu_k = jax.lax.top_k(maxima, kk)[0][:, -1]            # (B,)
    block_needed = (maxima >= mu_k[:, None]).any(axis=0)  # (n_blocks,)

    dbb = dbp.reshape(n_blocks, blk, W)
    base_valid = jnp.arange(padded_n).reshape(n_blocks, blk) < N
    init_sims = jnp.full((B, k), -jnp.inf, dtype=jnp.float32)
    init_ids = jnp.full((B, k), -1, dtype=jnp.int32)

    def rescore(carry, db_blk, valid, j):
        best_sims, best_ids = carry
        sims = ref.scores_ref(q_words, db_blk, z_q)
        sims = jnp.where(valid[None, :], sims, -jnp.inf)
        ids = (j * blk + jnp.arange(blk, dtype=jnp.int32))[None, :]
        ids = jnp.broadcast_to(ids, sims.shape)
        all_sims = jnp.concatenate([best_sims, sims], axis=1)
        all_ids = jnp.concatenate([best_ids, ids], axis=1)
        new_sims, pos = jax.lax.top_k(all_sims, k)
        return new_sims, jnp.take_along_axis(all_ids, pos, axis=1)

    def step(carry, inp):
        db_blk, valid, needed, j = inp
        carry = jax.lax.cond(
            needed,
            lambda c: rescore(c, db_blk, valid, j),
            lambda c: c,
            carry,
        )
        return carry, None

    (sims, ids), _ = jax.lax.scan(
        step,
        (init_sims, init_ids),
        (dbb, base_valid, block_needed,
         jnp.arange(n_blocks, dtype=jnp.int32)),
    )
    return sims, ids, block_needed.mean()


def verify_tuples_op(
    q_words: jax.Array,
    cand_words: jax.Array,
    *,
    use_pallas: bool | None = None,
    blk_n: int = 1024,
):
    """(W,), (N, W) -> exact (r10, r01) int32 tuples for each candidate."""
    N = cand_words.shape[0]
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas:
        return ref.verify_tuples_ref(q_words, cand_words)
    _bump_launch("verify")
    blk = min(blk_n, max(8, N))
    cp = _pad_to(cand_words, 0, blk)
    with _obs.current().span("launch.verify", cat="kernel", n=N):
        r10, r01 = _verify_tuples_kernel(
            q_words, cp, blk_n=blk, interpret=not on_tpu()
        )
    return r10[:N], r01[:N]


def _gather_verify_grouped_impl(
    q_words: jax.Array,
    db_words: jax.Array,
    cand_idx: jax.Array,
    lengths: jax.Array,
    *,
    p: int,
    blk_c: int,
    use_pallas: bool,
    interpret: bool,
):
    """Device side of the grouped verify: gather candidate rows from the
    resident DB and fuse tuple computation + bucket-key packing into one
    compiled computation (one kernel launch on the Pallas path)."""
    cand = jnp.take(db_words, cand_idx, axis=0)        # (B, C, W) on device
    if use_pallas:
        return _verify_grouped_kernel(
            q_words, cand, lengths, p=p, blk_c=blk_c, interpret=interpret
        )
    return ref.verify_tuples_grouped_ref(q_words, cand, lengths, p)


# Per-device jit instances of the gather+verify: one jitted callable (and
# hence one O(log B * log C) executable cache) per placement device.
# Sharded AMIH engines verify each shard on that shard's own device; a
# single shared jit instance would interleave every device's executables
# in one cache and make per-device trace/launch economy unobservable.
# Keyed by ``device_key`` so tests can inspect which devices compiled.
_DEVICE_JITS: dict = {}


def _gather_verify_grouped_for(device):
    """The jitted gather+verify bound to ``device`` (None -> the default
    device), created on first use and cached for the process lifetime.
    Guarded: thread-mode shard probing dispatches concurrently, and an
    unguarded check-then-insert would build (and trace) duplicate jit
    instances for a not-yet-cached device key."""
    key = device_key(device)
    with _LAUNCH_LOCK:
        fn = _DEVICE_JITS.get(key)
        if fn is None:
            fn = jax.jit(
                _gather_verify_grouped_impl,
                static_argnames=("p", "blk_c", "use_pallas", "interpret"),
            )
            _DEVICE_JITS[key] = fn
    return fn


def _device_fn(device, name: str, make):
    """Per-device jit instance registry shared with the grouped verify:
    one jitted callable per (device, op) pair, keyed ``"<dkey>::<op>"``
    in ``_DEVICE_JITS``, created on first use and reused for the process
    lifetime — sustained serving never rebuilds a jit wrapper per batch."""
    key = f"{device_key(device)}::{name}"
    with _LAUNCH_LOCK:
        fn = _DEVICE_JITS.get(key)
        if fn is None:
            fn = make()
            _DEVICE_JITS[key] = fn
    return fn


def device_jit_cache_info() -> Tuple[str, ...]:
    """Device keys that have a compiled grouped-verify cache (testing).
    Per-device probe-walk instances appear as ``"<dkey>::<op>"``."""
    return tuple(sorted(_DEVICE_JITS))


class PendingKeys:
    """Handle for an in-flight grouped-verify launch.

    Holds the (padded) device array of packed bucket keys without forcing
    a host sync — on accelerator backends the computation dispatches
    asynchronously, so the issuing thread can keep probing the next tuple
    step while the device works. ``get()`` materializes the unpadded
    (B, C) host array (blocking until the launch and transfer complete).
    """

    __slots__ = ("_keys", "_B", "_C", "_dkey")

    def __init__(self, keys, B: int, C: int, dkey: str = "default"):
        self._keys = keys
        self._B = B
        self._C = C
        self._dkey = dkey

    def get(self) -> np.ndarray:
        tr = _obs.current()
        if not tr.enabled:
            return np.asarray(self._keys)[: self._B, : self._C]
        t0 = _obs.now_us()
        out = np.asarray(self._keys)[: self._B, : self._C]
        tr.record("launch.verify_grouped.resolve", t0, _obs.now_us(),
                  cat="kernel", device=self._dkey)
        return out


def verify_tuples_grouped_launch(
    q_words,
    db_words: jax.Array,
    cand_idx,
    lengths,
    *,
    p: int,
    use_pallas: bool | None = None,
    blk_c: int = DEFAULT_BLK_C,
    device=None,
) -> PendingKeys:
    """Non-blocking form of ``verify_tuples_grouped_op``: pads, dispatches
    the jitted gather+verify, and returns a ``PendingKeys`` handle
    WITHOUT synchronizing with the device. Same padding/trace-cache
    contract as the blocking op (which is now ``launch().get()``).

    ``device`` places the launch: the query/index/length inputs are
    committed to it (``jax.device_put``) and the computation compiles and
    runs there — ``db_words`` is expected to already be resident on the
    same device (``AMIHIndex.db_dev`` uploads it once at build). Each
    device gets its own jit instance (``_gather_verify_grouped_for``) and
    its own entry in ``LAUNCH_COUNTS_BY_DEVICE``; ``device=None`` keeps
    the old default-device behavior."""
    idx = np.ascontiguousarray(np.asarray(cand_idx, dtype=np.int32))
    lens = np.asarray(lengths, dtype=np.int32)
    B, C = idx.shape
    if C == 0 or B == 0:
        return PendingKeys(np.full((B, C), -1, dtype=np.int32), B, C)
    if use_pallas is None:
        use_pallas = on_tpu()
    Bp = pad_bucket(B, minimum=1)
    Cp = pad_bucket(C, minimum=8)
    blk = min(blk_c, Cp)
    idxp = np.zeros((Bp, Cp), dtype=np.int32)
    idxp[:B, :C] = idx
    lensp = np.zeros(Bp, dtype=np.int32)
    lensp[:B] = lens
    if device is not None:
        # placed launch: pad on the host and upload ONCE to the target
        # device — staging through jnp on the default device would
        # re-funnel every shard's launch through device 0, the exact
        # bottleneck per-shard placement exists to remove
        qh = np.asarray(q_words)
        qp_host = np.zeros((Bp,) + qh.shape[1:], dtype=qh.dtype)
        qp_host[:B] = qh
        qp = jax.device_put(qp_host, device)
        idxp = jax.device_put(idxp, device)
        lensp = jax.device_put(lensp, device)
    else:
        qp = _pad_to(jnp.asarray(q_words), 0, Bp)
    dkey = device_key(device)
    _bump_launch("verify_grouped", dkey)
    with _obs.current().span("launch.verify_grouped.dispatch",
                             cat="kernel", device=dkey, B=B, C=C):
        keys = _gather_verify_grouped_for(device)(
            qp,
            db_words,
            jnp.asarray(idxp),
            jnp.asarray(lensp),
            p=p,
            blk_c=blk,
            use_pallas=use_pallas,
            interpret=not on_tpu(),
        )
    return PendingKeys(keys, B, C, dkey)


def _probe_put(arrays, device):
    """Commit per-call probe arrays: one device_put each to the placement
    device, or a plain jnp.asarray on the default device."""
    if device is not None:
        return [jax.device_put(a, device) for a in arrays]
    return [jnp.asarray(a) for a in arrays]


def device_probe_walk_launch(
    q_words,
    q_sub,
    z_sub,
    pow1,
    pow0,
    t_stop,
    k: int,
    *,
    sched,
    csr,
    p: int,
    device=None,
    use_pallas: bool | None = None,
    tile: int | None = None,
    cap: int | None = None,
    check_every: int | None = None,
    walk_budget: int | None = None,
) -> dict:
    """Dispatch the fused probing-walk launch for one z-group.

    ``sched`` is a ``repro.core.probe_device.DeviceSchedule`` and ``csr``
    the index's committed CSR dict; per-call arrays (queries, substring
    values/popcounts, flip tables, per-query stop positions) are padded to
    a power-of-two batch and committed to ``device``. ``walk_budget``
    caps the loop iterations (default: the point where one exhaustive
    scan launch costs about as much as a quarter of the walk done so
    far); still-undone queries fall through to the scan launch, exactly
    as with a truncated schedule. Returns a host dict with the per-query
    position map and counters, sliced back to B rows:
    {"posmap", "probes", "retrieved", "done", "cursor", "iters"}.
    """
    from ..core.probe_device import (
        DEFAULT_CHECK_EVERY,
        DEFAULT_PROBE_CAP,
        DEFAULT_TILE,
        KMAX,
    )
    from . import device_probe

    if use_pallas is None:
        use_pallas = on_tpu()
    tile = DEFAULT_TILE if tile is None else tile
    if tile > DEFAULT_TILE:
        raise ValueError(
            f"tile={tile} exceeds the schedule pad margin {DEFAULT_TILE}"
        )
    cap = pad_bucket(DEFAULT_PROBE_CAP if cap is None else cap, minimum=8)
    check_every = (
        DEFAULT_CHECK_EVERY if check_every is None else max(1, check_every)
    )
    if walk_budget is None:
        # each iteration verifies <= cap candidates; the scan verifies
        # n_pad rows in one launch. Past n_pad/(4*cap) iterations the
        # walk has burned a quarter of a scan without converging — on a
        # deep walk the exhaustive launch is the cheaper way to finish.
        walk_budget = max(4, int(csr["n_pad"]) // (4 * cap))
    qh = np.ascontiguousarray(np.asarray(q_words))
    B = qh.shape[0]
    Bp = pad_bucket(B, minimum=1)

    def pad_rows(a, fill=0):
        a = np.asarray(a)
        out = np.full((Bp,) + a.shape[1:], fill, dtype=a.dtype)
        out[:B] = a
        return out

    # padded query rows start with t_stop = -1: born done, so they never
    # probe, never block done.all(), and cost nothing
    per_call = _probe_put(
        [
            pad_rows(qh),
            pad_rows(np.asarray(q_sub, dtype=np.int32)),
            pad_rows(np.asarray(z_sub, dtype=np.int32)),
            pad_rows(np.asarray(pow1, dtype=np.int32)),
            pad_rows(np.asarray(pow0, dtype=np.int32)),
            pad_rows(np.asarray(t_stop, dtype=np.int32), fill=-1),
            np.int32(k),
            np.int32(sched.s_len),
            np.int32(walk_budget),
        ],
        device,
    )
    bundle = sched.device_arrays(device)
    dkey = device_key(device)
    _bump_launch("device_probe", dkey)
    with _obs.current().span("launch.device_probe", cat="kernel",
                             device=dkey, B=B):
        posmap, probes, retrieved, done, cursor, iters = (
            device_probe.device_probe_walk(
                *per_call,
                bundle["tbl"],
                bundle["step_ext"],
                bundle["idx1"],
                bundle["idx0"],
                bundle["maxi1"],
                bundle["maxi0"],
                bundle["widths"],
                csr["offsets"],
                csr["ids"],
                csr["db_pad"],
                bundle["inv_pos"],
                p=p,
                tile=tile,
                cap=cap,
                kmax=KMAX,
                check_every=check_every,
                use_pallas=use_pallas,
                interpret=not on_tpu(),
            )
        )
        return {
            "posmap": np.asarray(posmap)[:B],
            "probes": np.asarray(probes)[:B],
            "retrieved": np.asarray(retrieved)[:B],
            "done": np.asarray(done)[:B],
            "cursor": int(cursor),
            "iters": int(iters),
        }


def device_probe_scan_launch(
    q_words,
    *,
    sched,
    csr,
    p: int,
    device=None,
    use_pallas: bool | None = None,
    chunk: int = 2048,
) -> np.ndarray:
    """One exhaustive verify launch: the exact walk position of EVERY
    stored code for each query — the fused scan fallback for queries a
    truncated schedule left unfinished. Returns a host (B, n_pad) int32
    position map."""
    from . import device_probe

    if use_pallas is None:
        use_pallas = on_tpu()
    qh = np.ascontiguousarray(np.asarray(q_words))
    B = qh.shape[0]
    Bp = pad_bucket(B, minimum=1)
    qp = np.zeros((Bp,) + qh.shape[1:], dtype=qh.dtype)
    qp[:B] = qh
    n_pad = csr["n_pad"]
    chunk = min(pad_bucket(chunk, minimum=8), n_pad)
    per_call = _probe_put([qp, np.int32(csr["n"])], device)
    bundle = sched.device_arrays(device)
    dkey = device_key(device)
    _bump_launch("device_probe_scan", dkey)
    with _obs.current().span("launch.device_probe_scan", cat="kernel",
                             device=dkey, B=B):
        pm = device_probe.device_probe_scan(
            per_call[0],
            csr["db_pad"],
            bundle["inv_pos"],
            per_call[1],
            p=p,
            chunk=chunk,
            use_pallas=use_pallas,
            interpret=not on_tpu(),
        )
        return np.asarray(pm)[:B]


# Recycled (B_pad, n_pad) position-map scratch buffers, per placement
# device: the fused batch walk donates its scratch input, so on backends
# that honor donation (TPU/GPU) sustained serving reuses ONE buffer per
# (device, batch-bucket, index) instead of allocating 4*B*n_pad bytes
# every query batch. Keyed (device_key, B_pad, n_pad); small cap so odd
# one-off batch shapes don't pin memory forever.
_POSMAP_POOL: dict = {}
_POSMAP_POOL_MAX = 2


def _take_posmap(device, Bp: int, n_pad: int):
    key = (device_key(device), Bp, n_pad)
    with _LAUNCH_LOCK:
        pool = _POSMAP_POOL.get(key)
        if pool:
            return key, pool.pop()
    buf = np.zeros((Bp, n_pad), dtype=np.int32)
    arr = jax.device_put(buf, device) if device is not None else (
        jnp.asarray(buf)
    )
    return key, arr


def _recycle_posmap(key, arr) -> None:
    with _LAUNCH_LOCK:
        pool = _POSMAP_POOL.setdefault(key, [])
        if len(pool) < _POSMAP_POOL_MAX:
            pool.append(arr)


class PendingWalk:
    """Handle for an in-flight fused batch-walk launch.

    Like ``PendingKeys``, holds the device output arrays without forcing
    a host sync, so the sharded engine can dispatch every device's fused
    launch back-to-back and only block at the final merge. ``get()``
    materializes the host result dict (posmap is force-copied before the
    output buffer is recycled into the donation pool — on CPU jax a
    plain ``np.asarray`` may alias the device buffer the next launch
    would overwrite)."""

    __slots__ = ("_out", "_B", "_pool_key", "_res")

    def __init__(self, out, B: int, pool_key):
        self._out = out
        self._B = B
        self._pool_key = pool_key
        self._res = None

    def get(self) -> dict:
        if self._res is None:
            tr = _obs.current()
            t0 = _obs.now_us() if tr.enabled else 0.0
            posmap, probes, retrieved, done, cursor, iters = self._out
            self._res = {
                "posmap": np.array(posmap)[: self._B],
                "probes": np.asarray(probes)[: self._B],
                "retrieved": np.asarray(retrieved)[: self._B],
                "done": np.asarray(done)[: self._B],
                "cursor": np.asarray(cursor),
                "iters": int(iters),
            }
            _recycle_posmap(self._pool_key, posmap)
            self._out = None
            if tr.enabled:
                tr.record("launch.device_probe.resolve", t0,
                          _obs.now_us(), cat="kernel",
                          device=self._pool_key[0])
        return self._res


def device_probe_walk_batched_launch(
    q_words,
    q_sub,
    z_sub,
    pow1,
    pow0,
    gid,
    t_stop,
    k: int,
    *,
    stack,
    csr,
    p: int,
    device=None,
    use_pallas: bool | None = None,
    tile: int | None = None,
    cap: int | None = None,
    check_every: int | None = None,
    walk_budget: int | None = None,
    blocking: bool = True,
) -> "dict | PendingWalk":
    """Dispatch the fused cross-z-group walk: ONE launch for the whole
    batch, every z-group included.

    ``stack`` is a ``repro.core.probe_device.ScheduleStack`` (the grow-
    only concatenation of the index's per-z schedules) and ``gid`` maps
    each query to its stack row; everything else matches
    ``device_probe_walk_launch``. With ``blocking=False`` returns a
    ``PendingWalk`` handle instead of synchronizing — the sharded
    engine's async multi-device dispatch. The (B_pad, n_pad) position-
    map scratch is drawn from (and recycled to) a per-device donation
    pool, so steady-state serving allocates nothing per batch on
    backends that honor ``donate_argnames``."""
    from ..core.probe_device import (
        DEFAULT_CHECK_EVERY,
        DEFAULT_PROBE_CAP,
        DEFAULT_TILE,
        KMAX,
    )
    from . import device_probe

    if use_pallas is None:
        use_pallas = on_tpu()
    tile = DEFAULT_TILE if tile is None else tile
    if tile > DEFAULT_TILE:
        raise ValueError(
            f"tile={tile} exceeds the schedule pad margin {DEFAULT_TILE}"
        )
    cap = pad_bucket(DEFAULT_PROBE_CAP if cap is None else cap, minimum=8)
    check_every = (
        DEFAULT_CHECK_EVERY if check_every is None else max(1, check_every)
    )
    qh = np.ascontiguousarray(np.asarray(q_words))
    B = qh.shape[0]
    Bp = pad_bucket(B, minimum=1)
    if walk_budget is None:
        # an iteration of the fused walk probes a tile for EVERY query,
        # so it costs ~Bp x the per-group iteration while the bail scan
        # still covers only the undone subset. Scale the per-group
        # crossover down by the batch width: past it, a few stragglers
        # grinding the whole batch width cost more than one exhaustive
        # scan over just those stragglers. At Bp=1 this is exactly the
        # per-group budget; results are identical either way — bailed
        # queries resolve exactly through the scan launch.
        walk_budget = max(4, int(csr["n_pad"]) // (4 * cap * Bp))

    def pad_rows(a, fill=0):
        a = np.asarray(a)
        out = np.full((Bp,) + a.shape[1:], fill, dtype=a.dtype)
        out[:B] = a
        return out

    # padded query rows: gid 0 (a real stack row) with t_stop = -1 —
    # born done, never probed, never block the done check
    per_call = _probe_put(
        [
            pad_rows(qh),
            pad_rows(np.asarray(q_sub, dtype=np.int32)),
            pad_rows(np.asarray(z_sub, dtype=np.int32)),
            pad_rows(np.asarray(pow1, dtype=np.int32)),
            pad_rows(np.asarray(pow0, dtype=np.int32)),
            pad_rows(np.asarray(gid, dtype=np.int32)),
            pad_rows(np.asarray(t_stop, dtype=np.int32), fill=-1),
            np.int32(k),
            np.int32(walk_budget),
        ],
        device,
    )
    bundle = stack.device_arrays(device)
    pool_key, posmap_in = _take_posmap(device, Bp, int(csr["n_pad"]))
    dkey = device_key(device)
    _bump_launch("device_probe", dkey)
    fn = _device_fn(
        device,
        "walk_batched",
        lambda: jax.jit(
            device_probe.device_probe_walk_batched,
            static_argnames=(
                "p", "tile", "cap", "kmax", "check_every",
                "use_pallas", "interpret",
            ),
            donate_argnames=("posmap_in",),
        ),
    )
    _tr = _obs.current()
    _t0 = _obs.now_us() if _tr.enabled else 0.0
    out = fn(
        posmap_in,
        *per_call,
        bundle["g_start"],
        bundle["g_end"],
        bundle["tbl"],
        bundle["step"],
        bundle["idx1"],
        bundle["idx0"],
        bundle["maxi1"],
        bundle["maxi0"],
        bundle["widths"],
        csr["offsets"],
        csr["ids"],
        csr["db_pad"],
        bundle["inv_pos"],
        p=p,
        tile=tile,
        cap=cap,
        kmax=KMAX,
        check_every=check_every,
        use_pallas=use_pallas,
        interpret=not on_tpu(),
    )
    if _tr.enabled:
        _tr.record("launch.device_probe.dispatch", _t0, _obs.now_us(),
                   cat="kernel", device=dkey, B=B)
    pending = PendingWalk(out, B, pool_key)
    return pending.get() if blocking else pending


def device_probe_scan_multi_launch(
    q_words,
    gid,
    *,
    stack,
    csr,
    p: int,
    device=None,
    use_pallas: bool | None = None,
    chunk: int = 2048,
) -> np.ndarray:
    """One exhaustive verify launch across EVERY bailed z-group: the
    fused form of ``device_probe_scan_launch`` with a per-query ``gid``
    row into the stack's inverse-position tables. Returns a host
    (B, n_pad) int32 position map."""
    from . import device_probe

    if use_pallas is None:
        use_pallas = on_tpu()
    qh = np.ascontiguousarray(np.asarray(q_words))
    B = qh.shape[0]
    Bp = pad_bucket(B, minimum=1)
    qp = np.zeros((Bp,) + qh.shape[1:], dtype=qh.dtype)
    qp[:B] = qh
    gp = np.zeros(Bp, dtype=np.int32)
    gp[:B] = np.asarray(gid, dtype=np.int32)
    n_pad = csr["n_pad"]
    chunk = min(pad_bucket(chunk, minimum=8), n_pad)
    per_call = _probe_put([qp, gp, np.int32(csr["n"])], device)
    bundle = stack.device_arrays(device)
    dkey = device_key(device)
    _bump_launch("device_probe_scan", dkey)
    fn = _device_fn(
        device,
        "scan_multi",
        lambda: jax.jit(
            device_probe.device_probe_scan_multi,
            static_argnames=("p", "chunk", "use_pallas", "interpret"),
        ),
    )
    with _obs.current().span("launch.device_probe_scan", cat="kernel",
                             device=dkey, B=B):
        pm = fn(
            per_call[0],
            per_call[1],
            csr["db_pad"],
            bundle["inv_pos"],
            per_call[2],
            p=p,
            chunk=chunk,
            use_pallas=use_pallas,
            interpret=not on_tpu(),
        )
        return np.asarray(pm)[:B]


def verify_tuples_grouped_op(
    q_words,
    db_words: jax.Array,
    cand_idx,
    lengths,
    *,
    p: int,
    use_pallas: bool | None = None,
    blk_c: int = DEFAULT_BLK_C,
    device=None,
):
    """Batched AMIH verification: one launch for a whole z-group.

    q_words (B, W) packed queries; db_words (N, W) device-resident codes;
    cand_idx (B, C_max) int32 candidate rows (entries past ``lengths[b]``
    are don't-cares); lengths (B,) int32 true candidate counts. Returns a
    host (B, C_max) int32 array of packed bucket keys
    ``r10 * (p + 1) + r01`` with -1 in every padded slot.

    B and C_max are padded up to power-of-two buckets (``pad_bucket``)
    before the jitted gather+verify, so the trace cache stays
    O(log B * log C) instead of one entry per ragged candidate shape.
    Candidate rows are gathered from ``db_words`` *on device* — the host
    ships only the (B, C_max) index matrix, never the code rows. For
    host/device overlap use ``verify_tuples_grouped_launch`` and resolve
    the returned handle when the keys are actually needed. ``device``
    places the launch on a specific device (see the launch docstring).
    """
    return verify_tuples_grouped_launch(
        q_words, db_words, cand_idx, lengths,
        p=p, use_pallas=use_pallas, blk_c=blk_c, device=device,
    ).get()
