"""Pallas TPU kernel: batched Hamming-tuple verification (AMIH hot loop).

After AMIH's bucket probes produce a candidate id list, each candidate's
exact full-code tuple (r_1to0, r_0to1) must be computed to (a) confirm it is
a true (r1, r2)-near neighbor and (b) place it in the emission order
(paper §5.1 "final pruning"). One query is verified against a gathered
candidate block:

  grid = (N / BLK_N,); candidate block (BLK_N, W) in VMEM; the query's W
  words are scalars broadcast against (1, BLK_N) word rows — all
  intermediates are 2-D VPU tiles; SWAR popcount as in hamming_scan.

Outputs are exact int32 tuples, so the test oracle comparison is equality,
not allclose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import popcount32

DEFAULT_BLK_N = 1024


def _verify_kernel(q_ref, cand_ref, r10_ref, r01_ref, *, n_words: int):
    blk_n = cand_ref.shape[0]
    r10 = jnp.zeros((1, blk_n), dtype=jnp.int32)
    r01 = jnp.zeros((1, blk_n), dtype=jnp.int32)
    for w in range(n_words):
        qw = q_ref[0, w]                       # scalar uint32
        cw = cand_ref[:, w][None, :]           # (1, BLK_N)
        r10 = r10 + popcount32(qw & ~cw)
        r01 = r01 + popcount32(~qw & cw)
    r10_ref[...] = r10[0]
    r01_ref[...] = r01[0]


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def verify_tuples(
    q_words: jax.Array,
    cand_words: jax.Array,
    *,
    blk_n: int = DEFAULT_BLK_N,
    interpret: bool = True,
):
    """(W,), (N, W) -> (r10, r01), each (N,) int32. N % blk_n == 0."""
    (W,) = q_words.shape
    N, Wd = cand_words.shape
    assert W == Wd
    assert N % blk_n == 0, (N, blk_n)
    grid = (N // blk_n,)
    return pl.pallas_call(
        functools.partial(_verify_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, W), lambda i: (0, 0)),
            pl.BlockSpec((blk_n, W), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        interpret=interpret,
    )(q_words.astype(jnp.uint32)[None, :], cand_words.astype(jnp.uint32))
