"""Pallas TPU kernel: batched Hamming-tuple verification (AMIH hot loop).

After AMIH's bucket probes produce a candidate id list, each candidate's
exact full-code tuple (r_1to0, r_0to1) must be computed to (a) confirm it is
a true (r1, r2)-near neighbor and (b) place it in the emission order
(paper §5.1 "final pruning"). Two shapes are provided:

  - ``verify_tuples``: one query vs one gathered candidate block.
    grid = (N / BLK_N,); candidate block (BLK_N, W) in VMEM; the query's W
    words are scalars broadcast against (1, BLK_N) word rows — all
    intermediates are 2-D VPU tiles; SWAR popcount as in hamming_scan.

  - ``verify_tuples_grouped``: every query of an AMIH z-group at once.
    Candidates are pre-gathered into a padded (B, C, W) layout and the
    grid is 2-D over (query, candidate-block): program (i, j) verifies
    query i against its candidate block j. A per-query length vector
    masks the C-padding (and whole padded query rows) in-kernel: padded
    slots come back as key = -1. The tuple -> Eq. 3 bucket key conversion
    is fused on device — each candidate returns ONE packed int32

        key = r10 * (p + 1) + r01        (p + 1 > any valid r01)

    so a single (B, C) array crosses back to the host bucketer instead of
    two tuple planes.

Outputs are exact int32 tuples/keys, so the test oracle comparison is
equality, not allclose.

This module is the kernel body only. Padding buckets, backend selection,
non-blocking dispatch, and per-device placement/launch accounting (the
mesh-resident sharded path runs one of these launches per shard on that
shard's own device) all live in the wrapper layer, kernels/ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import popcount32

DEFAULT_BLK_N = 1024
DEFAULT_BLK_C = 128

# Trace-time counters, keyed by kernel name: the jitted wrappers bump them
# from their Python bodies, which only execute when jax actually traces a
# new (shape, static-arg) signature. Tests assert the jit cache stays
# bounded under the power-of-two padding buckets (see ops.pad_bucket).
TRACE_COUNTS = {"verify_tuples": 0, "verify_tuples_grouped": 0}


def _verify_kernel(q_ref, cand_ref, r10_ref, r01_ref, *, n_words: int):
    blk_n = cand_ref.shape[0]
    r10 = jnp.zeros((1, blk_n), dtype=jnp.int32)
    r01 = jnp.zeros((1, blk_n), dtype=jnp.int32)
    for w in range(n_words):
        qw = q_ref[0, w]                       # scalar uint32
        cw = cand_ref[:, w][None, :]           # (1, BLK_N)
        r10 = r10 + popcount32(qw & ~cw)
        r01 = r01 + popcount32(~qw & cw)
    r10_ref[...] = r10[0]
    r01_ref[...] = r01[0]


def _verify_grouped_kernel(
    q_ref, cand_ref, len_ref, key_ref, *, n_words: int, p: int
):
    """Program (i, j): query i vs its j-th candidate block.

    q_ref (1, W) uint32; cand_ref (1, BLK_C, W) uint32; len_ref (1, 1)
    int32 (query i's true candidate count); key_ref (1, BLK_C) int32.
    """
    blk_c = cand_ref.shape[1]
    r10 = jnp.zeros((1, blk_c), dtype=jnp.int32)
    r01 = jnp.zeros((1, blk_c), dtype=jnp.int32)
    for w in range(n_words):
        qw = q_ref[0, w]                        # scalar uint32
        cw = cand_ref[0, :, w][None, :]         # (1, BLK_C)
        r10 = r10 + popcount32(qw & ~cw)
        r01 = r01 + popcount32(~qw & cw)
    key = r10 * jnp.int32(p + 1) + r01
    col = pl.program_id(1) * blk_c + jax.lax.broadcasted_iota(
        jnp.int32, (1, blk_c), 1
    )
    valid = col < len_ref[0, 0]
    key_ref[...] = jnp.where(valid, key, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("p", "blk_c", "interpret"))
def verify_tuples_grouped(
    q_words: jax.Array,
    cand_words: jax.Array,
    lengths: jax.Array,
    *,
    p: int,
    blk_c: int = DEFAULT_BLK_C,
    interpret: bool = True,
):
    """(B, W), (B, C, W), (B,) -> packed bucket keys (B, C) int32.

    One launch verifies every query of a z-group against its padded
    candidate block: 2-D grid (B, C / blk_c). Entry (i, c) is
    ``r10 * (p + 1) + r01`` for candidate c of query i when
    ``c < lengths[i]``, and -1 (masked padding) otherwise. C % blk_c == 0.
    """
    TRACE_COUNTS["verify_tuples_grouped"] += 1
    B, W = q_words.shape
    Bc, C, Wd = cand_words.shape
    assert W == Wd and B == Bc and B == lengths.shape[0]
    assert C % blk_c == 0, (C, blk_c)
    grid = (B, C // blk_c)
    return pl.pallas_call(
        functools.partial(_verify_grouped_kernel, n_words=W, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, W), lambda i, j: (i, 0)),
            pl.BlockSpec((1, blk_c, W), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.int32),
        interpret=interpret,
    )(
        q_words.astype(jnp.uint32),
        cand_words.astype(jnp.uint32),
        lengths.astype(jnp.int32)[:, None],
    )


@functools.partial(jax.jit, static_argnames=("blk_n", "interpret"))
def verify_tuples(
    q_words: jax.Array,
    cand_words: jax.Array,
    *,
    blk_n: int = DEFAULT_BLK_N,
    interpret: bool = True,
):
    """(W,), (N, W) -> (r10, r01), each (N,) int32. N % blk_n == 0."""
    TRACE_COUNTS["verify_tuples"] += 1
    (W,) = q_words.shape
    N, Wd = cand_words.shape
    assert W == Wd
    assert N % blk_n == 0, (N, blk_n)
    grid = (N // blk_n,)
    return pl.pallas_call(
        functools.partial(_verify_kernel, n_words=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, W), lambda i: (0, 0)),
            pl.BlockSpec((blk_n, W), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_n,), lambda i: (i,)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ],
        interpret=interpret,
    )(q_words.astype(jnp.uint32)[None, :], cand_words.astype(jnp.uint32))
