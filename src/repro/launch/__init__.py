"""Launch surface: mesh construction, sharding assembly, dry-run, drivers."""

from .mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
