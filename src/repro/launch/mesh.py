"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the fake device count before any
jax initialization).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small runs."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(axes)
    )
