"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the fake device count before any
jax initialization).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types; older versions are all-Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )
    # Older jax: make_mesh has no axis_types kwarg; meshes are implicitly
    # Auto, which is exactly what the explicit call above requests.
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small runs."""
    return _make_mesh(tuple(shape), tuple(axes))


def make_search_mesh(num_devices=None):
    """1-D ``data`` mesh over (up to) every local device — the layout the
    sharded search backends (repro.shard) row-partition a DB across when
    no model parallelism is in play. ``ShardPlan.from_mesh`` derives the
    shard count from it."""
    n_avail = len(jax.devices())
    n = n_avail if num_devices is None else min(num_devices, n_avail)
    return _make_mesh((n,), ("data",))
