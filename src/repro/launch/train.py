"""Production training driver.

    python -m repro.launch.train --arch llama3_8b --steps 100 \
        --ckpt-dir /tmp/ckpt [--mesh-shape 2,4 --mesh-axes data,model]

On a real TPU pod this runs under the production mesh (launch/mesh.py)
with the pjit step proven by the dry-run; on CPU it trains the reduced
(same-family) config so the driver itself is exercised end to end.
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--mesh-shape", default="",
                    help="e.g. 2,4 (needs that many devices)")
    ap.add_argument("--mesh-axes", default="data,model")
    args = ap.parse_args()

    from repro.configs import get_config, get_tiny
    from repro.data import DataConfig
    from repro.optim import OptimConfig
    from repro.train import TrainConfig, Trainer, TrainerConfig

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    mesh = None
    if args.mesh_shape:
        from repro.launch.mesh import make_mesh

        shape = tuple(int(x) for x in args.mesh_shape.split(","))
        mesh = make_mesh(shape, tuple(args.mesh_axes.split(",")))

    trainer = Trainer(
        cfg=cfg,
        ocfg=OptimConfig(
            peak_lr=3e-4,
            warmup_steps=max(1, args.steps // 10),
            decay_steps=args.steps,
        ),
        tcfg=TrainConfig(
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
        ),
        rcfg=TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=max(1, args.steps // 4),
            checkpoint_dir=args.ckpt_dir,
        ),
        data_cfg=DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.global_batch,
        ),
        mesh=mesh,
    )
    out = trainer.run()
    print(
        f"arch={cfg.name} steps={out['final_step']} "
        f"restarts={out['restarts']} "
        f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}"
    )


if __name__ == "__main__":
    main()
