"""Sharding assembly: logical-axes trees -> NamedSharding trees.

Covers parameters, optimizer state (ZeRO-style: quantized moments are flat
and shard over every mesh axis), decode caches, and batch inputs. All
resolution goes through ``models.sharding.resolve_spec`` so non-dividing
axes degrade to replication with a logged decision instead of failing.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import lm
from ..models.api import INPUT_LOGICAL_AXES
from ..models.common import ArchConfig
from ..models.sharding import DEFAULT_RULES, Rules, resolve_spec
from ..optim import OptimConfig, state_specs

# flat (ZeRO) sharding for quantized optimizer moments
FLAT_AXES = ("pod", "data", "model")


def _named(mesh, rules, sds, axes, log, what):
    spec = resolve_spec(mesh, rules, sds.shape, axes, log, what)
    return NamedSharding(mesh, spec)


def param_shardings(cfg: ArchConfig, mesh: Mesh, rules: Rules, log=None):
    specs = lm.param_specs(cfg)
    axes = lm.logical_axes(cfg)
    return jax.tree.map(
        lambda s, a: _named(mesh, rules, s, a, log, "param"),
        specs,
        axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def opt_shardings(
    ocfg: OptimConfig, cfg: ArchConfig, mesh: Mesh, rules: Rules, log=None
):
    """Moments: param-sharded when f32; flat all-axes (ZeRO) when int8."""
    pspecs = lm.param_specs(cfg)
    paxes = lm.logical_axes(cfg)
    ospecs = state_specs(ocfg, pspecs)

    # walk param specs / axes / moment specs in lockstep
    flat_p, tdef = jax.tree.flatten(
        pspecs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    flat_a = tdef.flatten_up_to(paxes)
    flat_m = tdef.flatten_up_to(ospecs["moments"])
    out_m = []
    for ps, ax, m in zip(flat_p, flat_a, flat_m):
        if ocfg.quantized_moments:
            rules_flat = dict(rules)
            rules_flat["flat"] = FLAT_AXES

            def flat_sh(sds):
                return _named(mesh, rules_flat, sds, ("flat",), log, "opt")

            out_m.append(
                jax.tree.map(
                    flat_sh, m,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
                )
            )
        else:
            sh = _named(mesh, rules, ps, ax, log, "opt")
            out_m.append({"mu": sh, "nu": sh})
    return {
        "step": NamedSharding(mesh, P()),
        "moments": jax.tree.unflatten(tdef, out_m),
    }


def cache_shardings(cfg: ArchConfig, mesh: Mesh, rules: Rules, cache_tpl, log=None):
    """Decode-cache shardings by positional convention (see lm.CACHE_AXES)."""

    def leaf_axes(sds):
        nd = len(sds.shape)
        if nd == 5 and sds.shape[-1] in (cfg.ssm_state,) and cfg.has_ssm:
            return ("layers", "batch", "ssm_heads", "head_dim", "ssm_state")
        if nd == 5:   # attn kv: (L, B, S, Hkv, Dh)
            return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
        if nd == 4:   # ssm conv: (L, B, K, conv_dim)
            return ("layers", "batch", "conv_width", "ssm_inner")
        return (None,) * nd

    return jax.tree.map(
        lambda s: _named(mesh, rules, s, leaf_axes(s), log, "cache"),
        cache_tpl,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def batch_shardings(cfg: ArchConfig, mesh: Mesh, rules: Rules, batch_specs, log=None):
    out = {}
    for name, sds in batch_specs.items():
        axes = INPUT_LOGICAL_AXES[name][: len(sds.shape)]
        out[name] = _named(mesh, rules, sds, axes, log, f"in:{name}")
    return out
