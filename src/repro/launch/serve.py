"""Production serving driver: generation engine + AMIH retrieval service.

    python -m repro.launch.serve --arch gemma_2b --tiny --requests 8
    python -m repro.launch.serve --arch gemma_2b --tiny --mode retrieval \
        --docs 300 --queries 5
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--mode", default="generate",
                    choices=["generate", "retrieval"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--docs", type=int, default=300)
    ap.add_argument("--queries", type=int, default=5)
    ap.add_argument("--code-bits", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config, get_tiny
    from repro.models import Model

    cfg = get_tiny(args.arch) if args.tiny else get_config(args.arch)
    cfg = cfg.replace(compute_dtype="float32") if args.tiny else cfg
    model = Model(cfg)
    params = model.init_params(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)

    if args.mode == "generate":
        from repro.serve import ServeConfig, ServeEngine

        eng = ServeEngine(
            cfg, params,
            ServeConfig(
                max_batch=args.max_batch, max_seq=args.max_seq,
                max_new_tokens=args.max_new_tokens,
            ),
        )
        for _ in range(args.requests):
            plen = int(rng.integers(4, args.max_seq // 4))
            eng.submit(rng.integers(1, cfg.vocab_size, plen))
        t0 = time.perf_counter()
        results = eng.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(v) for v in results.values())
        print(f"served {len(results)} requests / {toks} tokens in {dt:.2f}s "
              f"({eng.stats['decode_steps']} batched decode steps)")
        return

    from repro.serve import RetrievalConfig, RetrievalService

    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=args.code_bits, aqbc_iters=8),
    )
    docs = rng.integers(1, cfg.vocab_size, (args.docs, 24)).astype(np.int32)
    info = svc.build_index(docs)
    print(f"indexed {args.docs} docs "
          f"(m={int(info['m_tables'])} tables, "
          f"AQBC objective {info['aqbc_objective']:.3f})")
    for qi in rng.integers(0, args.docs, args.queries):
        ids, sims, stats = svc.search(docs[int(qi)], k=5)
        ids_l, sims_l = svc.search_linear(docs[int(qi)], k=5)
        assert np.allclose(sims, sims_l, atol=1e-9), "exactness violated"
        print(f"  q=doc[{qi}]: hits {ids[:3].tolist()} "
              f"sims {np.round(sims[:3], 3).tolist()} "
              f"probes={stats.probes} (exact vs scan: OK)")


if __name__ == "__main__":
    main()
