import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production meshes, with allocation-free ShapeDtypeStruct inputs.

MUST be run as its own process (python -m repro.launch.dryrun ...): the two
lines above pin 512 placeholder devices BEFORE any jax import — smoke tests
and benches must never see them.

Per cell this produces:
  - proof of shardability: .lower().compile() succeeds on the 16x16
    single-pod mesh and the 2x16x16 multi-pod mesh,
  - compiled.memory_analysis(): per-device bytes (feasibility),
  - compiled.cost_analysis(): XLA's raw counters (recorded; while-bodies
    are counted once there — see roofline.hlo_parse for the corrected
    numbers),
  - the parsed, trip-count-scaled roofline terms (roofline.analysis).

Cells:   10 assigned archs x their 4 shapes (minus recorded long_500k
skips) + the paper's retrieval_step (sharded angular scan) as its own cell.

Usage:
  python -m repro.launch.dryrun --arch llama3_8b --shape train_4k \
      --mesh single --out artifacts/dryrun
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
  python -m repro.launch.dryrun --report artifacts/dryrun   # md table
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def _cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
          save_hlo: bool, rules_json: str = "", opt: str = "f32",
          cfg_overrides: str = "", opt_rules_json: str = "",
          profile: str = "baseline") -> dict:
    """Lower+compile one cell in THIS process. Returns the report dict."""
    import jax

    from repro import jax_compat
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models.common import SHAPES, shape_applicable
    from repro.optim import OptimConfig
    from repro.roofline import analyze, parse_hlo_costs
    from repro.train.step import TrainConfig, make_serve_step, make_train_step

    t_start = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size

    if arch == "retrieval":
        rep = _retrieval_cell(mesh, mesh_name, chips)
    else:
        cfg = get_config(arch)
        if profile == "optimized":
            from repro.configs.profiles import (
                optimized_opt_rules,
                optimized_overrides,
            )

            cfg = cfg.replace(**optimized_overrides(arch))
            if not opt_rules_json:
                opt_rules_json = json.dumps(
                    {"embed": list(optimized_opt_rules()["embed"])}
                )
        if cfg_overrides:
            cfg = cfg.replace(**json.loads(cfg_overrides))
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            return {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": why,
            }
        rules = json.loads(rules_json) if rules_json else None
        if rules:
            rules = {k: tuple(v) if isinstance(v, list) else v
                     for k, v in rules.items()}
        log: list = []
        ocfg = OptimConfig(quantized_moments=(opt == "int8"))
        opt_rules = None
        if opt_rules_json:
            from repro.models.sharding import DEFAULT_RULES

            opt_rules = dict(DEFAULT_RULES)
            opt_rules.update({
                k: tuple(v) if isinstance(v, list) else v
                for k, v in json.loads(opt_rules_json).items()
            })
        if shape.kind == "train":
            built = make_train_step(
                cfg, ocfg, TrainConfig(), mesh=mesh, rules=rules,
                log=log, opt_rules=opt_rules,
            )
            lowered = built["lower_for"](shape)
        elif shape.kind == "prefill":
            built = make_serve_step(cfg, mesh=mesh, rules=rules, log=log)
            lowered = built["lower_prefill"](shape)
        else:  # decode
            built = make_serve_step(cfg, mesh=mesh, rules=rules, log=log)
            lowered = built["lower_for"](shape)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = jax_compat.cost_analysis_dict(compiled)
        hlo = compiled.as_text()
        costs = parse_hlo_costs(hlo)
        per_dev_bytes = (
            ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes
        )
        report = analyze(
            cfg, shape, mesh_name, chips, hlo,
            bytes_per_device=per_dev_bytes, costs=costs,
        )
        rep = json.loads(report.to_json())
        rep.update(
            status="ok",
            xla_flops_raw=float(ca.get("flops", 0.0)),
            xla_bytes_raw=float(ca.get("bytes accessed", 0.0)),
            memory_analysis={
                "argument": ma.argument_size_in_bytes,
                "output": ma.output_size_in_bytes,
                "temp": ma.temp_size_in_bytes,
                "alias": ma.alias_size_in_bytes,
            },
            sharding_log=log[:200],
            collective_op_counts=costs.collective_ops,
        )
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(
                os.path.join(out_dir, f"{mesh_name}_{arch}_{shape_name}.hlo.txt"),
                "w",
            ) as f:
                f.write(hlo)
    rep["compile_wall_s"] = round(time.time() - t_start, 2)
    return rep


def _retrieval_cell(mesh, mesh_name: str, chips: int) -> dict:
    """The paper's technique on the mesh: sharded angular scan + top-K."""
    import jax
    import jax.numpy as jnp

    from repro.shard import make_retrieval_step
    from repro.roofline.hlo_parse import parse_hlo_costs

    # 2^30 codes x 128 bits (SIFT-1B class), sharded over pod+data axes
    N, W, B, K = 1 << 30, 4, 256, 100
    step, in_shardings = make_retrieval_step(mesh, K)
    q = jax.ShapeDtypeStruct((B, W), jnp.uint32, sharding=in_shardings[0])
    db = jax.ShapeDtypeStruct((N, W), jnp.uint32, sharding=in_shardings[1])
    lowered = jax.jit(step, in_shardings=in_shardings).lower(q, db)
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    costs = parse_hlo_costs(hlo)
    hbm_s = costs.hbm_bytes / 819e9
    coll_s = costs.total_collective_bytes / 50e9
    comp_s = costs.flops / 197e12
    terms = {"compute": comp_s, "memory": hbm_s, "collective": coll_s}
    return {
        "arch": "retrieval", "shape": f"scan_n{N}_k{K}", "mesh": mesh_name,
        "chips": chips, "status": "ok",
        "device_flops": costs.flops,
        "device_hbm_bytes": costs.hbm_bytes,
        "device_collective_bytes": costs.total_collective_bytes,
        "collective_breakdown": costs.collective_bytes,
        "compute_s": comp_s, "memory_s": hbm_s, "collective_s": coll_s,
        "dominant": max(terms, key=terms.get),
        "bytes_per_device": ma.argument_size_in_bytes
        + ma.temp_size_in_bytes,
        "note": "paper technique: sharded XOR/popcount scan + all-gather(K) merge",
    }


# --------------------------------------------------------------- sweeping
def _all_cells():
    from repro.configs import ARCH_IDS
    from repro.models.common import SHAPES

    cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    cells.append(("retrieval", "scan"))
    return cells


def run_all(mesh_names, out_dir: str, save_hlo: bool, jobs: int = 2,
            profile: str = "baseline"):
    """Sweep every cell, one subprocess per cell (isolation: a failing or
    OOMing cell never kills the sweep; memory is returned to the OS)."""
    os.makedirs(out_dir, exist_ok=True)
    procs = []
    todo = [
        (arch, shape, mesh)
        for mesh in mesh_names
        for arch, shape in _all_cells()
    ]
    results = {}

    def launch(arch, shape, mesh):
        out_file = os.path.join(out_dir, f"{mesh}_{arch}_{shape}.json")
        if os.path.exists(out_file):
            return None
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--mesh", mesh,
            "--out", out_dir, "--profile", profile,
        ]
        if save_hlo:
            cmd.append("--save-hlo")
        return subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT
        )

    running = []
    while todo or running:
        while todo and len(running) < jobs:
            arch, shape, mesh = todo.pop(0)
            p = launch(arch, shape, mesh)
            if p is not None:
                running.append((arch, shape, mesh, p, time.time()))
                print(f"[launch] {mesh}/{arch}/{shape}")
        still = []
        for arch, shape, mesh, p, t0 in running:
            if p.poll() is None:
                if time.time() - t0 > 1800:
                    p.kill()
                    print(f"[timeout] {mesh}/{arch}/{shape}")
                else:
                    still.append((arch, shape, mesh, p, t0))
            else:
                dt = time.time() - t0
                tag = "ok" if p.returncode == 0 else f"FAIL rc={p.returncode}"
                print(f"[done {dt:5.1f}s] {mesh}/{arch}/{shape}: {tag}")
                if p.returncode != 0:
                    out = p.stdout.read().decode(errors="replace")
                    with open(
                        os.path.join(out_dir, f"{mesh}_{arch}_{shape}.err"),
                        "w",
                    ) as f:
                        f.write(out)
        running = still
        time.sleep(1.0)
    return results


# ---------------------------------------------------------------- report
def report(out_dir: str):
    rows = []
    for name in sorted(os.listdir(out_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(out_dir, name)) as f:
            rows.append(json.load(f))
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skip"]
    print(f"| arch | shape | mesh | dominant | compute_s | memory_s | "
          f"collective_s | step_s | useful | GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['dominant']} "
            f"| {r.get('compute_s', 0):.4f} | {r.get('memory_s', 0):.4f} "
            f"| {r.get('collective_s', 0):.4f} "
            f"| {r.get('step_s', max(r.get('compute_s',0), r.get('memory_s',0), r.get('collective_s',0))):.4f} "
            f"| {r.get('useful_ratio', 0):.3f} "
            f"| {r.get('bytes_per_device', 0)/2**30:.2f} |"
        )
    for r in skipped:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP: "
              f"{r['reason'][:60]} | | | | | | |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--rules-json", default="", help="sharding rule overrides")
    ap.add_argument("--opt", default="f32", choices=["f32", "int8"],
                    help="optimizer moment precision (train shapes)")
    ap.add_argument("--cfg-json", default="",
                    help="ArchConfig field overrides, e.g. "
                         '\'{"remat": "dots", "kv_chunk": 4096}\'')
    ap.add_argument("--opt-rules-json", default="",
                    help="optimizer-state-only sharding rule overrides "
                         "(ZeRO-style), merged over the defaults")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "optimized"],
                    help="baseline = paper-faithful configs; optimized = "
                         "the §Perf-winning overrides (configs/profiles.py)")
    ap.add_argument("--report", metavar="DIR")
    args = ap.parse_args()

    if args.report:
        report(args.report)
        return
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        run_all(meshes, args.out, args.save_hlo, args.jobs, args.profile)
        return
    assert args.arch and (args.shape or args.arch == "retrieval")
    for mesh in meshes:
        try:
            rep = _cell(
                args.arch, args.shape or "scan", mesh, args.out,
                args.save_hlo, args.rules_json, args.opt, args.cfg_json,
                args.opt_rules_json, args.profile,
            )
        except Exception:
            rep = {
                "arch": args.arch, "shape": args.shape, "mesh": mesh,
                "status": "error", "traceback": traceback.format_exc(),
            }
        os.makedirs(args.out, exist_ok=True)
        out_file = os.path.join(
            args.out, f"{mesh}_{args.arch}_{args.shape or 'scan'}.json"
        )
        with open(out_file, "w") as f:
            json.dump(rep, f, indent=1)
        brief = {
            k: rep.get(k)
            for k in ("arch", "shape", "mesh", "status", "dominant",
                      "compute_s", "memory_s", "collective_s",
                      "useful_ratio", "compile_wall_s", "reason")
            if k in rep
        }
        print(json.dumps(brief))
        if rep["status"] == "error":
            print(rep["traceback"], file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
