"""Cross-polytope LSH baselines (paper §6.3: SP-CP and MP-CP).

Approximate angular NN comparators used by the paper's Fig. 8/9 — the
FALCONN-style cross-polytope family (Andoni et al., NeurIPS 2015):

  h(x) = argmax_i [ (Gx)_1, ..., (Gx)_{d'}, -(Gx)_1, ..., -(Gx)_{d'} ]

with a fresh pseudo-random Gaussian G per hash function; ``k`` functions are
concatenated per table; ``l`` independent tables. Single-probe (SP) checks
only the query's own bucket per table; multiprobe (MP) additionally probes
buckets obtained by switching the least-confident hash coordinates to their
runner-up value, ranked by the score gap (the standard multiprobe ordering).

numpy implementation — these are baselines for benchmark comparisons, not a
production path.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["CrossPolytopeLSH"]


def _hash_indices(v: np.ndarray) -> np.ndarray:
    """Cross-polytope bucket index per row: argmax over (v, -v)."""
    ext = np.concatenate([v, -v], axis=-1)
    return np.argmax(ext, axis=-1)


@dataclass
class CrossPolytopeLSH:
    l: int                         # tables
    k: int                         # concatenated hashes per table
    gs: np.ndarray = field(repr=False)       # (l, k, d, proj_dim)
    tables: List[Dict[Tuple[int, ...], np.ndarray]] = field(repr=False)
    data: np.ndarray = field(repr=False)     # normalized dataset

    @classmethod
    def build(
        cls,
        x: np.ndarray,
        l: int = 10,
        k: int = 2,
        proj_dim: int = 32,
        seed: int = 0,
    ) -> "CrossPolytopeLSH":
        rng = np.random.default_rng(seed)
        x = np.asarray(x, dtype=np.float32)
        xn = x / np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-12)
        d = x.shape[1]
        gs = rng.standard_normal((l, k, d, proj_dim)).astype(np.float32)
        gs /= np.sqrt(proj_dim)
        tables: List[Dict[Tuple[int, ...], np.ndarray]] = []
        for t in range(l):
            keys = np.stack(
                [_hash_indices(xn @ gs[t, j]) for j in range(k)], axis=1
            )  # (n, k)
            table: Dict[Tuple[int, ...], List[int]] = {}
            for i, row in enumerate(map(tuple, keys)):
                table.setdefault(row, []).append(i)
            tables.append({kk: np.asarray(v) for kk, v in table.items()})
        return cls(l=l, k=k, gs=gs, tables=tables, data=xn)

    def _probe_keys(self, q: np.ndarray, t: int, n_probes: int):
        """Multiprobe key sequence for table t, best-first by score gap."""
        per_hash = []
        for j in range(self.k):
            v = q @ self.gs[t, j]
            ext = np.concatenate([v, -v])
            order = np.argsort(-ext)
            # (gap_to_best, candidate_index) for top few alternates
            gaps = ext[order[0]] - ext[order]
            per_hash.append((order, gaps))
        base = tuple(int(per_hash[j][0][0]) for j in range(self.k))
        # best-first search over per-hash alternate choices
        heap = [(0.0, tuple([0] * self.k))]
        seen = {tuple([0] * self.k)}
        out = []
        while heap and len(out) < n_probes:
            cost, alt = heapq.heappop(heap)
            key = tuple(
                int(per_hash[j][0][alt[j]]) for j in range(self.k)
            )
            out.append(key)
            for j in range(self.k):
                nxt = list(alt)
                if nxt[j] + 1 < len(per_hash[j][1]):
                    nxt[j] += 1
                    tup = tuple(nxt)
                    if tup not in seen:
                        seen.add(tup)
                        delta = (
                            per_hash[j][1][nxt[j]]
                            - per_hash[j][1][nxt[j] - 1]
                        )
                        heapq.heappush(heap, (cost + float(delta), tup))
        return out

    def query(
        self, q: np.ndarray, k_neighbors: int = 1, probes_per_table: int = 1
    ) -> np.ndarray:
        """Approximate angular KNN: candidate union -> exact rerank.

        probes_per_table = 1 is SP-CP; > 1 is MP-CP.
        """
        q = np.asarray(q, dtype=np.float32)
        qn = q / max(float(np.linalg.norm(q)), 1e-12)
        cands: List[np.ndarray] = []
        for t in range(self.l):
            for key in self._probe_keys(qn, t, probes_per_table):
                hit = self.tables[t].get(key)
                if hit is not None:
                    cands.append(hit)
        if not cands:
            return np.empty(0, dtype=np.int64)
        ids = np.unique(np.concatenate(cands))
        sims = self.data[ids] @ qn
        order = np.argsort(-sims, kind="stable")[:k_neighbors]
        return ids[order]
