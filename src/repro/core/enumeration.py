"""Bucket-index enumeration for a tuple (Eq. 4 support).

Given a query substring value ``q`` (as a python int over ``w`` bits with
``z`` ones), the codes at exactly tuple ``(a, b)`` are obtained by flipping
``a`` of the one-bits and ``b`` of the zero-bits:

    { q ^ (m1 | m0) : m1 in C(ones(q), a), m0 in C(zeros(q), b) }

There are C(z, a) * C(w - z, b) of them (Eq. 4). Enumeration cost is linear
in the output size; AMIH keeps a, b small so this never explodes, but a
safety ``cap`` is enforced and surfaced to the caller.
"""

from __future__ import annotations

import math
from itertools import combinations
from typing import List, Optional

import numpy as np

__all__ = [
    "EnumerationCapExceeded",
    "bit_positions",
    "combination_masks",
    "tuple_bucket_values",
]


class EnumerationCapExceeded(ValueError):
    """A tuple's bucket enumeration would exceed the caller's cap.

    Subclasses ValueError for backward compatibility; callers that fall
    back to scanning catch THIS type so unrelated ValueErrors surface."""


def bit_positions(value: int, width: int) -> List[int]:
    """Positions (LSB-first) of set bits of ``value`` within ``width`` bits."""
    return [j for j in range(width) if (value >> j) & 1]


def combination_masks(positions: List[int], k: int) -> np.ndarray:
    """All C(len(positions), k) OR-masks of k distinct positions, uint64."""
    n = len(positions)
    cnt = math.comb(n, k)
    out = np.empty(cnt, dtype=np.uint64)
    for i, combo in enumerate(combinations(positions, k)):
        m = 0
        for pos in combo:
            m |= 1 << pos
        out[i] = m
    return out


def tuple_bucket_values(
    q_value: int,
    width: int,
    z: int,
    a: int,
    b: int,
    cap: Optional[int] = None,
) -> np.ndarray:
    """All bucket indices at exactly tuple (a, b) from the query substring.

    Returns a uint64 array of length C(z, a) * C(width - z, b).
    Raises ValueError if the count exceeds ``cap`` (guard against probing
    blowup; AMIH's tuple schedule keeps a+b <= floor(r/m) so this is small).
    """
    if not (0 <= a <= z and 0 <= b <= width - z):
        return np.empty(0, dtype=np.uint64)
    count = math.comb(z, a) * math.comb(width - z, b)
    if cap is not None and count > cap:
        raise EnumerationCapExceeded(
            f"bucket enumeration for tuple ({a},{b}) on width={width}, z={z} "
            f"would produce {count} > cap={cap} buckets"
        )
    ones = bit_positions(q_value, width)
    zeros = [j for j in range(width) if not (q_value >> j) & 1]
    m1 = combination_masks(ones, a)          # flip 1 -> 0
    m0 = combination_masks(zeros, b)         # flip 0 -> 1
    masks = (m1[:, None] | m0[None, :]).reshape(-1)
    return np.uint64(q_value) ^ masks
