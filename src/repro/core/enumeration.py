"""Bucket-index enumeration for a tuple (Eq. 4 support).

Given a query substring value ``q`` (as a python int over ``w`` bits with
``z`` ones), the codes at exactly tuple ``(a, b)`` are obtained by flipping
``a`` of the one-bits and ``b`` of the zero-bits:

    { q ^ (m1 | m0) : m1 in C(ones(q), a), m0 in C(zeros(q), b) }

There are C(z, a) * C(w - z, b) of them (Eq. 4). Enumeration cost is linear
in the output size; AMIH keeps a, b small so this never explodes, but a
safety ``cap`` is enforced and surfaced to the caller.
"""

from __future__ import annotations

import math
import threading
from itertools import combinations
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "EnumerationCapExceeded",
    "bit_positions",
    "combination_indices",
    "combination_masks",
    "tuple_bucket_values",
]


class EnumerationCapExceeded(ValueError):
    """A tuple's bucket enumeration would exceed the caller's cap.

    Subclasses ValueError for backward compatibility; callers that fall
    back to scanning catch THIS type so unrelated ValueErrors surface."""


def bit_positions(value: int, width: int) -> List[int]:
    """Positions (LSB-first) of set bits of ``value`` within ``width`` bits."""
    return [j for j in range(width) if (value >> j) & 1]


# Canonical k-out-of-n index combinations, cached process-wide: both the
# host bucket enumeration and the device probe schedule expand the same
# C(n, k) tables (in itertools.combinations order), so they are built once
# and shared. Entries are tiny (C(16, 4) = 1820 rows of k int8s) and the
# (n, k) key space is small; no eviction needed.
_COMBO_CACHE: Dict[Tuple[int, int], np.ndarray] = {}
_COMBO_LOCK = threading.Lock()


def combination_indices(n: int, k: int) -> np.ndarray:
    """All C(n, k) combinations of k indices out of range(n), as a
    (C(n, k), max(k, 1)) int8 array in ``itertools.combinations`` order
    (k == 0 yields one all-padding row). Cached; treat as read-only."""
    with _COMBO_LOCK:
        out = _COMBO_CACHE.get((n, k))
        if out is None:
            cnt = math.comb(n, k)
            out = np.fromiter(
                (j for combo in combinations(range(n), k) for j in combo),
                dtype=np.int8,
                count=cnt * k,
            ).reshape(cnt, k) if k else np.zeros((1, 1), dtype=np.int8)
            out.setflags(write=False)
            _COMBO_CACHE[(n, k)] = out
    return out


def combination_masks(positions: List[int], k: int) -> np.ndarray:
    """All C(len(positions), k) OR-masks of k distinct positions, uint64.

    Vectorized through the shared ``combination_indices`` table: the
    canonical index rows gather per-position bit values and OR-reduce,
    replacing the old per-combination Python loop on the probe hot path."""
    n = len(positions)
    if k == 0:
        return np.zeros(1, dtype=np.uint64)
    if k > n:
        return np.empty(0, dtype=np.uint64)
    pos_bits = np.array(
        [1 << int(pos) for pos in positions], dtype=np.uint64
    )
    idx = combination_indices(n, k)
    return np.bitwise_or.reduce(pos_bits[idx.astype(np.intp)], axis=1)


def tuple_bucket_values(
    q_value: int,
    width: int,
    z: int,
    a: int,
    b: int,
    cap: Optional[int] = None,
) -> np.ndarray:
    """All bucket indices at exactly tuple (a, b) from the query substring.

    Returns a uint64 array of length C(z, a) * C(width - z, b).
    Raises ValueError if the count exceeds ``cap`` (guard against probing
    blowup; AMIH's tuple schedule keeps a+b <= floor(r/m) so this is small).
    """
    if not (0 <= a <= z and 0 <= b <= width - z):
        return np.empty(0, dtype=np.uint64)
    count = math.comb(z, a) * math.comb(width - z, b)
    if cap is not None and count > cap:
        raise EnumerationCapExceeded(
            f"bucket enumeration for tuple ({a},{b}) on width={width}, z={z} "
            f"would produce {count} > cap={cap} buckets"
        )
    ones = bit_positions(q_value, width)
    zeros = [j for j in range(width) if not (q_value >> j) & 1]
    m1 = combination_masks(ones, a)          # flip 1 -> 0
    m0 = combination_masks(zeros, b)         # flip 0 -> 1
    masks = (m1[:, None] | m0[None, :]).reshape(-1)
    return np.uint64(q_value) ^ masks
