"""Device-resident AMIH probing (the ``probe_backend="device"`` path).

The host probing loop in ``amih.py`` walks the (p, z) tuple sequence one
step at a time: enumerate substring probes, look buckets up in host CSR
tables, verify fresh candidates, emit. Every step is a host round-trip.
This module compiles the whole walk into ONE jitted launch per z-group:

1.  **Schedule** (``DeviceSchedule``): the probing sequence depends only
    on (p, z) — not the query — so the entire walk is precomputed as flat
    device arrays. Each *stream entry* is one bucket probe: a table id,
    the walk step it belongs to, and the index combination that flips
    ``a`` one-bits and ``b`` zero-bits of the query substring (Prop. 4's
    T_{r1,r2,m} cover, deduplicated across steps by the same staircase
    the host path uses). The combination is stored as canonical indices
    into the query's *sorted* bit positions (ones first, then zeros), so
    one schedule serves every query: per-query validity is just
    ``max_index < z_s`` (resp. ``< w_s - z_s``) and the probed set per
    query is exactly the host path's.

2.  **CSR** (``build_device_csr``): each ``_SubTable``'s buckets become a
    dense offsets table (``offsets[s, v] .. offsets[s, v + 1]`` bounds
    bucket ``v`` of table ``s``) plus one shared sorted-ids matrix,
    committed next to ``AMIHIndex.db_dev`` — bucket lookup on device is
    two gathers.

3.  **Walk kernel** (``kernels/device_probe.py``): a ``lax.while_loop``
    consumes the stream in tiles, expands bucket ranges into candidate
    slots (at most ``cap`` per query per iteration — oversized buckets
    are resumed across iterations), gathers + popcount-verifies the
    candidates (Pallas kernel on TPU, XLA reference elsewhere), and
    scatter-mins each candidate's exact walk position into a per-query
    position map. Dedup is free: rediscovering a candidate scatters the
    same position. Early termination is the paper's Prop. 2 bound in
    walk-position space: a query is done when at least k codes have
    position <= the last *completed* step (pigeonhole: those are final)
    or the walk has passed its ``stop_below`` position.

4.  **Extraction** (host): the final top-K of query ``qi`` is the k
    smallest (position, id) pairs of its position map; sims are read from
    the host float64 ``sims64`` table at those positions, so emitted sims
    never round-trip through float32 and results are bit-identical to the
    host path and ``linear_scan_knn`` (including in-tuple ties: ascending
    id within a position, walk order across positions).

If the schedule could not be fully built (a probe needs more than
``KMAX`` flips, or the stream would exceed ``stream_cap`` entries — the
device analogue of the host enumeration-cap guard), queries still not
done when the walk exhausts the stream fall back to ONE full-scan verify
launch (every code's exact position), keeping the launch count O(1) per
z-group in every case.
"""

from __future__ import annotations

import math
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .enumeration import combination_indices
from .packing import extract_substring, popcount, substring_spans
from .probing import probing_prefix
from .tuples import rhat, sim_value

__all__ = [
    "DEFAULT_PROBE_CAP",
    "DEFAULT_STREAM_CAP",
    "DeviceSchedule",
    "KMAX",
    "MAX_OFFSET_WIDTH",
    "POS_INF",
    "ScheduleStack",
    "build_device_csr",
    "dispatch_groups_device",
    "get_schedule",
    "get_schedule_stack",
    "resolve_groups_device",
    "run_groups_device",
    "schedule_cache_clear",
    "schedule_cache_info",
    "schedule_cache_stats",
]

# Max flips per substring probe the schedule encodes (index columns per
# side). Probes needing more truncate the schedule -> scan fallback; with
# the paper's m ~ p/log2(n) splits, rsub = floor(r/m) stays tiny and real
# walks never get near this.
KMAX = 8

# "Never probed" sentinel in the per-query position map (int32 max).
POS_INF = np.int32(0x7FFFFFFF)

# Dense CSR offsets spend 4 * (2^w + 1) bytes per table; w <= 20 caps
# that at ~4 MiB/table. Wider substrings should raise m instead.
MAX_OFFSET_WIDTH = 20

# Stream entries consumed per while_loop iteration (also the schedule's
# pad margin, so a tile slice never needs clamping).
DEFAULT_TILE = 1024

# Candidate slots expanded per query per iteration: the walk kernel's
# peak gather is (B_pad, cap, W) words.
DEFAULT_PROBE_CAP = 2048

# Default bound on schedule stream entries per (p, z); the `AMIHIndex`
# field ``probe_stream_cap`` overrides it per index.
DEFAULT_STREAM_CAP = 1 << 16

# Done-check cadence inside the while_loop. A check scans the (B, n_pad)
# position map, but most walks finish within their first tile — checking
# every iteration lets them exit immediately, which beats amortizing the
# scan over iterations the query never needed.
DEFAULT_CHECK_EVERY = 1

_EMPTY_I64 = np.empty(0, dtype=np.int64)


@dataclass
class DeviceSchedule:
    """Precomputed device walk for one (p, m, widths, z, stream_cap).

    Host-side metadata (numpy) plus per-device committed jnp bundles
    (``device_arrays``). Instances are shared process-wide through
    ``get_schedule`` — treat every array as read-only.
    """

    p: int
    m: int
    widths: Tuple[int, ...]
    z: int
    stream_cap: int
    # ---- full walk metadata (all L valid tuples, in walk order)
    L: int = 0
    r1s: np.ndarray = field(default=None, repr=False)      # (L,) int32
    r2s: np.ndarray = field(default=None, repr=False)      # (L,) int32
    sims64: np.ndarray = field(default=None, repr=False)   # (L,) float64
    cum_maxrad: np.ndarray = field(default=None, repr=False)  # (L,) int32
    inv_pos: np.ndarray = field(default=None, repr=False)  # ((p+1)^2,) int32
    # ---- probe stream (built_steps walk steps flattened; padded to P)
    s_len: int = 0          # real stream entries
    built_steps: int = 0    # walk steps fully encoded in the stream
    complete: bool = False  # built_steps == L
    tbl: np.ndarray = field(default=None, repr=False)      # (P,) int32
    step_ext: np.ndarray = field(default=None, repr=False)  # (P+1,) int32
    idx1: np.ndarray = field(default=None, repr=False)     # (P, KMAX) int32
    idx0: np.ndarray = field(default=None, repr=False)     # (P, KMAX) int32
    maxi1: np.ndarray = field(default=None, repr=False)    # (P,) int32
    maxi0: np.ndarray = field(default=None, repr=False)    # (P,) int32
    cum_subtuples: np.ndarray = field(default=None, repr=False)
    _dev: Dict[str, dict] = field(default_factory=dict, repr=False)

    def device_arrays(self, device) -> dict:
        """The committed jnp bundle of the walk arrays for ``device``
        (built on first use per device, cached on the schedule)."""
        from ..kernels import ops

        key = ops.device_key(device)
        bundle = self._dev.get(key)
        if bundle is None:
            import jax
            import jax.numpy as jnp

            put = (
                (lambda a: jax.device_put(a, device))
                if device is not None
                else jnp.asarray
            )
            bundle = {
                "tbl": put(self.tbl),
                "step_ext": put(self.step_ext),
                "idx1": put(self.idx1),
                "idx0": put(self.idx0),
                "maxi1": put(self.maxi1),
                "maxi0": put(self.maxi0),
                "inv_pos": put(self.inv_pos),
                "widths": put(np.asarray(self.widths, dtype=np.int32)),
            }
            self._dev[key] = bundle
        return bundle


def _build_schedule(
    p: int, m: int, widths: Tuple[int, ...], z: int, stream_cap: int
) -> DeviceSchedule:
    from ..kernels import ops

    sched = DeviceSchedule(p=p, m=m, widths=widths, z=z,
                           stream_cap=stream_cap)
    L = (z + 1) * (p - z + 1)
    walk = probing_prefix(p, z, L)
    assert len(walk) == L, "probing sequence shorter than tuple count"
    r1s = np.fromiter((t[0] for t in walk), dtype=np.int32, count=L)
    r2s = np.fromiter((t[1] for t in walk), dtype=np.int32, count=L)
    sched.L = L
    sched.r1s, sched.r2s = r1s, r2s
    sched.sims64 = np.fromiter(
        (sim_value(p, z, r1, r2) for (r1, r2) in walk),
        dtype=np.float64, count=L,
    )
    sched.cum_maxrad = np.maximum.accumulate(r1s + r2s).astype(np.int32)
    inv_pos = np.full((p + 1) * (p + 1), POS_INF, dtype=np.int32)
    inv_pos[r1s.astype(np.int64) * (p + 1) + r2s] = np.arange(
        L, dtype=np.int32
    )
    sched.inv_pos = inv_pos

    wmax = max(widths)
    cover: List[Dict[int, int]] = [{} for _ in range(m)]
    tbl_l: List[np.ndarray] = []
    step_l: List[np.ndarray] = []
    idx1_l: List[np.ndarray] = []
    idx0_l: List[np.ndarray] = []
    maxi1_l: List[np.ndarray] = []
    maxi0_l: List[np.ndarray] = []
    probe_counts: List[int] = []
    total = 0
    built = 0
    complete = False
    for t, (r1, r2) in enumerate(walk):
        rsub = (r1 + r2) // m
        # collect this step's new probes WITHOUT committing the cover:
        # a step is all-or-nothing, so an abort leaves the stream ending
        # exactly at a completed step boundary
        new_probes: List[Tuple[int, int, int]] = []
        cnt = 0
        abort = False
        for s in range(m):
            w = widths[s]
            cov = cover[s]
            for a in range(min(r1, w, rsub) + 1):
                bmax = min(r2, w, rsub - a)
                for b in range(cov.get(a, -1) + 1, bmax + 1):
                    if a > KMAX or b > KMAX:
                        abort = True
                        break
                    cnt += math.comb(w, a) * math.comb(w, b)
                    new_probes.append((s, a, b))
                if abort:
                    break
            if abort:
                break
        if abort or total + cnt > stream_cap:
            break
        for (s, a, b) in new_probes:
            cov = cover[s]
            cov[a] = max(cov.get(a, -1), b)
            w = widths[s]
            c1 = combination_indices(w, a)
            c0 = combination_indices(w, b)
            C1, C0 = len(c1), len(c0)
            i1 = np.full((C1, KMAX), wmax, dtype=np.int32)
            if a:
                i1[:, :a] = c1
            i0 = np.full((C0, KMAX), wmax, dtype=np.int32)
            if b:
                i0[:, :b] = c0
            m1 = (
                c1[:, -1].astype(np.int32)
                if a else np.full(C1, -1, dtype=np.int32)
            )
            m0 = (
                c0[:, -1].astype(np.int32)
                if b else np.full(C0, -1, dtype=np.int32)
            )
            e = C1 * C0
            tbl_l.append(np.full(e, s, dtype=np.int32))
            step_l.append(np.full(e, t, dtype=np.int32))
            idx1_l.append(np.repeat(i1, C0, axis=0))
            idx0_l.append(np.tile(i0, (C1, 1)))
            maxi1_l.append(np.repeat(m1, C0))
            maxi0_l.append(np.tile(m0, C1))
        total += cnt
        probe_counts.append(len(new_probes))
        built = t + 1
    else:
        complete = True

    s_len = total
    P = ops.pad_bucket(s_len + DEFAULT_TILE, minimum=DEFAULT_TILE)

    def cat(parts, pad_shape, pad_val):
        out = np.full(pad_shape, pad_val, dtype=np.int32)
        if parts:
            body = np.concatenate(parts, axis=0)
            out[: len(body)] = body
        return out

    sched.s_len = s_len
    sched.built_steps = built
    sched.complete = complete
    sched.tbl = cat(tbl_l, (P,), 0)
    steps = cat(step_l, (P + 1,), built)
    sched.step_ext = steps
    sched.idx1 = cat(idx1_l, (P, KMAX), wmax)
    sched.idx0 = cat(idx0_l, (P, KMAX), wmax)
    # padded entries carry an impossible max index so they can never be
    # valid for any query (belt and braces next to the in-stream mask)
    sched.maxi1 = cat(maxi1_l, (P,), 1 << 30)
    sched.maxi0 = cat(maxi0_l, (P,), 1 << 30)
    sched.cum_subtuples = np.concatenate(
        ([0], np.cumsum(probe_counts, dtype=np.int64))
    )
    return sched


_SCHED_CACHE: "OrderedDict[tuple, DeviceSchedule]" = OrderedDict()
_SCHED_CACHE_MAX = 32
_SCHED_LOCK = threading.RLock()
_SCHED_HITS = 0
_SCHED_MISSES = 0


def get_schedule(
    p: int, m: int, widths: Tuple[int, ...], z: int, stream_cap: int
) -> DeviceSchedule:
    """Process-wide LRU of device walk schedules — like the probing-prefix
    cache, one (p, m, widths, z) schedule serves every index and shard."""
    global _SCHED_HITS, _SCHED_MISSES
    key = (p, m, tuple(widths), z, stream_cap)
    with _SCHED_LOCK:
        sched = _SCHED_CACHE.get(key)
        if sched is not None:
            _SCHED_CACHE.move_to_end(key)
            _SCHED_HITS += 1
            return sched
        _SCHED_MISSES += 1
    built = _build_schedule(p, m, tuple(widths), z, stream_cap)
    with _SCHED_LOCK:
        sched = _SCHED_CACHE.setdefault(key, built)
        _SCHED_CACHE.move_to_end(key)
        while len(_SCHED_CACHE) > _SCHED_CACHE_MAX:
            _SCHED_CACHE.popitem(last=False)
        return sched


def schedule_cache_clear() -> None:
    with _SCHED_LOCK:
        _SCHED_CACHE.clear()
    with _STACK_LOCK:
        _STACK_CACHE.clear()


def schedule_cache_info() -> Tuple[int, int]:
    """(entries, total stream entries) of the schedule cache."""
    with _SCHED_LOCK:
        return (
            len(_SCHED_CACHE),
            sum(s.s_len for s in _SCHED_CACHE.values()),
        )


def schedule_cache_stats() -> Dict[str, int]:
    """Process-wide schedule-cache health: entries/stream size plus the
    cumulative ``get_schedule`` hit/miss counts (threaded into
    ``EngineStats.cache_info`` and recorded in bench rows, so a cache
    regression shows up as a miss-rate jump instead of a latency mystery)."""
    with _SCHED_LOCK:
        entries, stream = (
            len(_SCHED_CACHE),
            sum(s.s_len for s in _SCHED_CACHE.values()),
        )
        hits, misses = _SCHED_HITS, _SCHED_MISSES
    return {
        "schedule_entries": entries,
        "schedule_stream": stream,
        "schedule_hits": hits,
        "schedule_misses": misses,
    }


# ----------------------------------------------------------------- stack
class ScheduleStack:
    """Grow-only concatenation of every z-schedule of one
    (p, m, widths, stream_cap) config — the batched form of
    ``DeviceSchedule`` the fused cross-z-group walk indexes by row.

    Each new z appends one *segment* of ``s_len + DEFAULT_TILE`` entries
    to the flat stream arrays (the stream itself plus a tile of inert
    pad entries, so a frozen group's cursor can over-advance by one tile
    without reading a neighbor's stream); per-row ``g_start``/``g_end``
    bound the real entries and the inverse-position tables stack one row
    per z. Host capacity grows by power-of-two buckets and the committed
    per-device bundle is re-uploaded only when the version changes, so
    the jit trace cache sees O(log) distinct stream lengths and steady-
    state serving re-commits nothing.
    """

    def __init__(self, p: int, m: int, widths: Tuple[int, ...],
                 stream_cap: int):
        self.p = p
        self.m = m
        self.widths = tuple(widths)
        self.stream_cap = stream_cap
        self.wmax = max(widths)
        self.rows: Dict[int, int] = {}          # z -> row index
        self.scheds: List[DeviceSchedule] = []  # one per row
        self.g_start: List[int] = []
        self.g_end: List[int] = []
        self.version = 0
        self._used = 0
        self._cap = 0
        self.tbl = np.zeros(0, dtype=np.int32)
        self.step = np.zeros(0, dtype=np.int32)
        self.idx1 = np.zeros((0, KMAX), dtype=np.int32)
        self.idx0 = np.zeros((0, KMAX), dtype=np.int32)
        self.maxi1 = np.zeros(0, dtype=np.int32)
        self.maxi0 = np.zeros(0, dtype=np.int32)
        self._dev: Dict[str, tuple] = {}        # dkey -> (version, bundle)
        self._lock = threading.RLock()

    def _grow(self, need: int) -> None:
        from ..kernels import ops

        cap = ops.pad_bucket(need, minimum=4 * DEFAULT_TILE)
        for name in ("tbl", "step", "idx1", "idx0", "maxi1", "maxi0"):
            old = getattr(self, name)
            shape = (cap,) + old.shape[1:]
            new = np.zeros(shape, dtype=np.int32)
            new[: len(old)] = old
            setattr(self, name, new)
        self._cap = cap

    def row(self, z: int) -> int:
        """The stack row for popcount ``z``, appending (and versioning)
        on first sight. Thread-safe; rows never move once assigned."""
        with self._lock:
            r = self.rows.get(z)
            if r is not None:
                return r
        sched = get_schedule(self.p, self.m, self.widths, z,
                             self.stream_cap)
        with self._lock:
            r = self.rows.get(z)
            if r is not None:
                return r
            seg = sched.s_len + DEFAULT_TILE
            start = self._used
            if start + seg > self._cap:
                self._grow(start + seg)
            # the schedule's own pad entries (step=built, maxi=1<<30)
            # fill the segment margin, so a cursor parked past g_end
            # still reads its group's completed-step count
            self.tbl[start : start + seg] = sched.tbl[:seg]
            self.step[start : start + seg] = sched.step_ext[:seg]
            self.idx1[start : start + seg] = sched.idx1[:seg]
            self.idx0[start : start + seg] = sched.idx0[:seg]
            self.maxi1[start : start + seg] = sched.maxi1[:seg]
            self.maxi0[start : start + seg] = sched.maxi0[:seg]
            self._used = start + seg
            self.scheds.append(sched)
            self.g_start.append(start)
            self.g_end.append(start + sched.s_len)
            r = len(self.scheds) - 1
            self.rows[z] = r
            self.version += 1
            return r

    def device_arrays(self, device) -> dict:
        """The committed jnp bundle for ``device`` at the current
        version (row-count and capacity padded to power-of-two buckets;
        re-uploaded only after a new z grew the stack)."""
        from ..kernels import ops

        key = ops.device_key(device)
        with self._lock:
            cur = self._dev.get(key)
            if cur is not None and cur[0] == self.version:
                return cur[1]
            G = len(self.scheds)
            G_pad = ops.pad_bucket(G, minimum=1)
            g_start = np.zeros(G_pad, dtype=np.int32)
            g_start[:G] = self.g_start
            g_end = np.zeros(G_pad, dtype=np.int32)
            g_end[:G] = self.g_end
            pp2 = (self.p + 1) * (self.p + 1)
            inv = np.full((G_pad, pp2), POS_INF, dtype=np.int32)
            for i, s in enumerate(self.scheds):
                inv[i] = s.inv_pos

            import jax
            import jax.numpy as jnp

            put = (
                (lambda a: jax.device_put(a, device))
                if device is not None
                else jnp.asarray
            )
            bundle = {
                "g_start": put(g_start),
                "g_end": put(g_end),
                "tbl": put(self.tbl),
                "step": put(self.step),
                "idx1": put(self.idx1),
                "idx0": put(self.idx0),
                "maxi1": put(self.maxi1),
                "maxi0": put(self.maxi0),
                "inv_pos": put(inv),
                "widths": put(np.asarray(self.widths, dtype=np.int32)),
            }
            self._dev[key] = (self.version, bundle)
            return bundle


_STACK_CACHE: "OrderedDict[tuple, ScheduleStack]" = OrderedDict()
_STACK_CACHE_MAX = 8
_STACK_LOCK = threading.RLock()


def get_schedule_stack(
    p: int, m: int, widths: Tuple[int, ...], stream_cap: int
) -> ScheduleStack:
    """Process-wide LRU of schedule stacks: one grow-only stack per
    (p, m, widths, stream_cap) config serves every index and shard,
    exactly like ``get_schedule`` one level down."""
    key = (p, m, tuple(widths), stream_cap)
    with _STACK_LOCK:
        stack = _STACK_CACHE.get(key)
        if stack is None:
            stack = ScheduleStack(p, m, tuple(widths), stream_cap)
            _STACK_CACHE[key] = stack
        _STACK_CACHE.move_to_end(key)
        while len(_STACK_CACHE) > _STACK_CACHE_MAX:
            _STACK_CACHE.popitem(last=False)
        return stack


# ------------------------------------------------------------------- CSR
def build_device_csr(index) -> dict:
    """Device-resident CSR of every ``_SubTable``, committed to
    ``index.device`` next to ``db_dev``.

    ``offsets`` is dense over bucket values — (m, 2^wmax + 1) int32, so a
    bucket lookup is two gathers with no per-table searchsorted on device;
    ``ids`` is the per-table sorted id rows padded to ``n_pad`` with the
    out-of-bounds marker ``n_pad`` (dropped by the position scatter);
    ``db_pad`` zero-pads the packed codes to ``n_pad`` rows for static
    gather shapes.
    """
    from ..kernels import ops

    widths = [t.width for t in index.tables]
    wmax = max(widths)
    if wmax > MAX_OFFSET_WIDTH:
        raise ValueError(
            f"probe_backend='device' needs substring width <= "
            f"{MAX_OFFSET_WIDTH} bits for the dense CSR offsets "
            f"(got {wmax}); build with larger m (>= "
            f"{-(-index.p // MAX_OFFSET_WIDTH)} for p={index.p})"
        )
    n = index.n
    n_pad = ops.pad_bucket(n, minimum=8)
    m = index.m
    offsets = np.full((m, (1 << wmax) + 1), n, dtype=np.int32)
    ids = np.full((m, n_pad), n_pad, dtype=np.int32)
    for s, table in enumerate(index.tables):
        w = table.width
        offsets[s, : (1 << w) + 1] = np.searchsorted(
            table.sorted_vals, np.arange((1 << w) + 1), side="left"
        ).astype(np.int32)
        ids[s, :n] = table.sorted_ids
    db_pad = np.zeros((n_pad, index.db_words.shape[1]),
                      dtype=index.db_words.dtype)
    db_pad[:n] = index.db_words

    import jax
    import jax.numpy as jnp

    put = (
        (lambda a: jax.device_put(a, index.device))
        if index.device is not None
        else jnp.asarray
    )
    return {
        "offsets": put(offsets),
        "ids": put(ids),
        "db_pad": put(db_pad),
        "n": n,
        "n_pad": n_pad,
        "wmax": wmax,
        "widths": tuple(widths),
    }


def _pow_arrays(
    q_sub: np.ndarray, z_sub: np.ndarray, widths: Tuple[int, ...], wmax: int
):
    """Per-query flip values for the canonical index combinations.

    ``pow1[b, s, i]`` is the bit value of the i-th one-position of query
    b's substring s (ascending position; 0 for i >= z_s and for the KMAX
    padding column i == wmax); ``pow0`` likewise over zero-positions. The
    schedule's index combinations OR these into the XOR mask, so each
    valid stream entry reproduces exactly one host bucket value.
    """
    Bg, m = q_sub.shape
    pow1 = np.zeros((Bg, m, wmax + 1), dtype=np.int32)
    pow0 = np.zeros((Bg, m, wmax + 1), dtype=np.int32)
    for s in range(m):
        w = widths[s]
        bits = (q_sub[:, s, None] >> np.arange(w, dtype=np.uint32)) & 1
        order1 = np.argsort(1 - bits, axis=1, kind="stable")
        order0 = np.argsort(bits, axis=1, kind="stable")
        col = np.arange(w)
        z_s = z_sub[:, s : s + 1].astype(np.int64)
        pow1[:, s, :w] = np.where(col < z_s, 1 << order1, 0)
        pow0[:, s, :w] = np.where(col < (w - z_s), 1 << order0, 0)
    return pow1, pow0


# ---------------------------------------------------------------- driver
def _extract(pm, ts, n, k, sims64):
    """The k smallest (position, id) pairs of one query's position map:
    (out_ids local int64, out_pos int64, out_sims float64)."""
    # work on the found subset only: the full-width (n,) compare is one
    # cheap pass, everything after is O(cnt log cnt)
    idx = np.flatnonzero(pm <= ts)
    take = min(k, idx.size)
    if take > 0:
        pos_f = pm[idx].astype(np.int64)
        order = np.argsort(pos_f * n + idx)[:take]
        out_ids = idx[order].astype(np.int64)
        out_pos = pos_f[order]
        out_sims = sims64[out_pos]
    else:
        out_ids = _EMPTY_I64
        out_pos = _EMPTY_I64
        out_sims = np.empty(0, dtype=np.float64)
    return out_ids, out_pos, out_sims


def _record_stats(st, sched, pm, out_pos, take, probes, retrieved,
                  scanned, r_hat):
    st.probes += int(probes)
    st.retrieved += int(retrieved)
    st.verified += int((pm != POS_INF).sum())
    t_last = int(out_pos[-1]) if take else -1
    st.tuples_processed += t_last + 1
    if t_last >= 0:
        st.max_radius = max(st.max_radius, int(sched.cum_maxrad[t_last]))
        if st.max_radius > r_hat:
            st.exceeded_rhat = True
        st.substring_tuples_probed += int(
            sched.cum_subtuples[min(t_last + 1, sched.built_steps)]
        )
    if scanned:
        st.fell_back_to_scan = True


def _query_substrings(index, q_words):
    """(q_sub uint32, z_sub int32) substring values/popcounts for a
    whole (possibly mixed-z) query batch."""
    q_sub = np.stack(
        [
            np.asarray(extract_substring(q_words, t.lo, t.hi))
            for t in index.tables
        ],
        axis=1,
    ).astype(np.uint32)
    z_sub = np.bitwise_count(q_sub).astype(np.int32)
    return q_sub, z_sub


class _PendingGroups:
    """In-flight fused batch probe: the non-blocking half of
    ``run_groups_device``. Holds the launch handle plus the host-side
    context ``resolve_groups_device`` needs for extraction."""

    __slots__ = ("q_words", "k", "zs", "gid", "t_stop", "stack", "handle")

    def __init__(self, q_words, k, zs, gid, t_stop, stack, handle):
        self.q_words = q_words
        self.k = k
        self.zs = zs
        self.gid = gid
        self.t_stop = t_stop
        self.stack = stack
        self.handle = handle


def dispatch_groups_device(
    index,
    q_words: np.ndarray,
    k: int,
    stop_below: Optional[np.ndarray] = None,
) -> _PendingGroups:
    """Dispatch ONE fused walk launch for the whole batch — every
    z-group rides the same ``lax.while_loop`` via its schedule-stack row
    — and return without blocking. The sharded engine calls this once
    per device back-to-back (async multi-device dispatch); single-index
    callers go through ``run_groups_device``."""
    from ..kernels import ops

    B = q_words.shape[0]
    csr = index.device_csr
    widths = csr["widths"]
    stack = get_schedule_stack(
        index.p, index.m, widths, index.probe_stream_cap
    )
    zs = popcount(q_words)
    gid = np.empty(B, dtype=np.int32)
    t_stop = np.empty(B, dtype=np.int32)
    for z in np.unique(zs):
        r = stack.row(int(z))
        sel = zs == z
        gid[sel] = r
        sched = stack.scheds[r]
        if stop_below is None:
            t_stop[sel] = sched.L - 1
        else:
            # snapshot of the live bounds: bounds only ever rise, so a
            # stale (lower) value is always still a valid lower bound
            t_stop[sel] = (
                np.searchsorted(
                    -sched.sims64, -stop_below[sel], side="right"
                )
                - 1
            ).astype(np.int32)
    q_sub, z_sub = _query_substrings(index, q_words)
    pow1, pow0 = _pow_arrays(q_sub, z_sub, widths, csr["wmax"])

    handle = ops.device_probe_walk_batched_launch(
        q_words,
        q_sub.astype(np.int32),
        z_sub,
        pow1,
        pow0,
        gid,
        t_stop,
        k,
        stack=stack,
        csr=csr,
        p=index.p,
        device=index.device,
        blocking=False,
    )
    index.verify_launches += 1
    return _PendingGroups(q_words, k, zs, gid, t_stop, stack, handle)


def resolve_groups_device(index, pending: _PendingGroups, stats,
                          on_done=None):
    """Block on a dispatched fused walk, finish any bailed queries with
    ONE cross-group scan launch, and extract results — the whole batch
    cost two launches at most. Returns finished ``_QueryState``s with
    the host loop's result contract (LOCAL ids; float64 sims)."""
    from .amih import _QueryState
    from ..kernels import ops

    q_words = pending.q_words
    k = pending.k
    stack = pending.stack
    B = q_words.shape[0]
    csr = index.device_csr
    n = csr["n"]
    res = pending.handle.get()
    posmap = res["posmap"]
    scanned = np.zeros(B, dtype=bool)
    undone = np.flatnonzero(~res["done"])
    if undone.size:
        # truncated schedules / budget bails: finish every straggler of
        # every group with ONE exhaustive verify launch — positions are
        # exact, so results are unchanged, and the batch total stays at
        # two launches
        pm2 = ops.device_probe_scan_multi_launch(
            np.ascontiguousarray(q_words[undone]),
            pending.gid[undone],
            stack=stack,
            csr=csr,
            p=index.p,
            device=index.device,
        )
        posmap[undone] = pm2
        scanned[undone] = True
        index.verify_launches += 1

    states: List[_QueryState] = []
    for qi in range(B):
        sched = stack.scheds[pending.gid[qi]]
        out_ids, out_pos, out_sims = _extract(
            posmap[qi, :n], int(pending.t_stop[qi]), n, k, sched.sims64
        )
        take = out_ids.size
        st = None if stats is None else stats[qi]
        if st is not None:
            _record_stats(
                st, sched, posmap[qi, :n], out_pos, take,
                res["probes"][qi], res["retrieved"][qi],
                bool(scanned[qi]), rhat(int(pending.zs[qi])),
            )
        state = _QueryState(
            qi=qi,
            q_words=q_words[qi],
            q_subs=[],
            z_subs=[],
            seen=np.empty(0, dtype=bool),
            cover=[],
            pending={},
            out_ids=out_ids,
            out_sims=out_sims,
            stats=st,
            scanned=bool(scanned[qi]),
            done=take >= k,
        )
        states.append(state)
        if on_done is not None and state.done:
            on_done(
                qi,
                out_ids + index.id_offset,
                np.asarray(out_sims, dtype=np.float64),
            )
    return states


def run_groups_device(
    index,
    q_words: np.ndarray,
    k: int,
    stats,
    stop_below: Optional[np.ndarray] = None,
    on_done=None,
):
    """Device-path replacement for ``AMIHIndex._run_groups``: ONE fused
    walk launch (plus at most one scan-fallback launch) for the whole
    batch, then host extraction. Returns finished ``_QueryState``s with
    the same result contract as the host loop (LOCAL ids; float64 sims).

    ``index.probe_fused=False`` keeps the PR 6 shape — one walk launch
    per z-group — as a parity oracle; results are bit-identical."""
    if not getattr(index, "probe_fused", True):
        return _run_groups_device_grouped(
            index, q_words, k, stats, stop_below, on_done
        )
    if q_words.shape[0] == 0:
        return []
    if np.unique(popcount(q_words)).size == 1:
        # single z-group (every B=1 call lands here): the stacked
        # kernel buys nothing over the per-group launch — same ONE walk
        # launch, but the per-group kernel's smaller operands dispatch
        # measurably faster at single-query latency. Results identical.
        return _run_groups_device_grouped(
            index, q_words, k, stats, stop_below, on_done
        )
    pending = dispatch_groups_device(index, q_words, k, stop_below)
    return resolve_groups_device(index, pending, stats, on_done=on_done)


def _run_groups_device_grouped(
    index,
    q_words: np.ndarray,
    k: int,
    stats,
    stop_below: Optional[np.ndarray] = None,
    on_done=None,
):
    """The PR 6 per-z-group device path (one walk launch per z-group):
    kept as the fused path's parity oracle and the
    ``probe_fused=False`` escape hatch."""
    from .amih import _QueryState
    from ..kernels import ops

    B = q_words.shape[0]
    zs = popcount(q_words)
    groups: Dict[int, List[int]] = {}
    for qi in range(B):
        groups.setdefault(int(zs[qi]), []).append(qi)

    csr = index.device_csr
    widths = csr["widths"]
    wmax = csr["wmax"]
    n = csr["n"]
    states: List[_QueryState] = []
    for z, qis in groups.items():
        sched = get_schedule(
            index.p, index.m, widths, z, index.probe_stream_cap
        )
        Bg = len(qis)
        q_grp = np.ascontiguousarray(q_words[qis])
        q_sub, z_sub = _query_substrings(index, q_grp)
        pow1, pow0 = _pow_arrays(q_sub, z_sub, widths, wmax)
        if stop_below is None:
            t_stop = np.full(Bg, sched.L - 1, dtype=np.int32)
        else:
            # snapshot of the live bounds: bounds only ever rise, so a
            # stale (lower) value is always still a valid lower bound
            t_stop = (
                np.searchsorted(
                    -sched.sims64, -stop_below[qis], side="right"
                )
                - 1
            ).astype(np.int32)

        res = ops.device_probe_walk_launch(
            q_grp,
            q_sub.astype(np.int32),
            z_sub,
            pow1,
            pow0,
            t_stop,
            k,
            sched=sched,
            csr=csr,
            p=index.p,
            device=index.device,
        )
        index.verify_launches += 1
        posmap = res["posmap"]
        done_dev = res["done"]
        scanned = np.zeros(Bg, dtype=bool)
        undone = np.flatnonzero(~done_dev)
        if undone.size:
            # truncated schedule: finish the stragglers with ONE
            # exhaustive verify launch (the host enumeration-cap
            # fallback, fused) — positions are exact, so results are
            # unchanged, and the z-group total stays at two launches
            pm2 = ops.device_probe_scan_launch(
                q_grp[undone],
                sched=sched,
                csr=csr,
                p=index.p,
                device=index.device,
            )
            posmap = posmap.copy()  # the device-backed view is read-only
            posmap[undone] = pm2
            scanned[undone] = True
            index.verify_launches += 1

        r_hat = rhat(z)
        for gi, qi in enumerate(qis):
            pm = posmap[gi, :n]
            out_ids, out_pos, out_sims = _extract(
                pm, int(t_stop[gi]), n, k, sched.sims64
            )
            take = out_ids.size
            st = None if stats is None else stats[qi]
            if st is not None:
                _record_stats(
                    st, sched, pm, out_pos, take,
                    res["probes"][gi], res["retrieved"][gi],
                    bool(scanned[gi]), r_hat,
                )
            state = _QueryState(
                qi=qi,
                q_words=q_words[qi],
                q_subs=[],
                z_subs=[],
                seen=np.empty(0, dtype=bool),
                cover=[],
                pending={},
                out_ids=out_ids,
                out_sims=out_sims,
                stats=st,
                scanned=bool(scanned[gi]),
                done=take >= k,
            )
            states.append(state)
            if on_done is not None and state.done:
                on_done(
                    qi,
                    out_ids + index.id_offset,
                    np.asarray(out_sims, dtype=np.float64),
                )
    return states
