"""Angular Quantization-based Binary Codes (Gong et al., NIPS 2012).

The binarization the paper uses for its experiments (§6.1): learn an
orthogonal projection ``R`` (d x c, RᵀR = I) so that the binary vertex
``b(x) = argmax_b <b, Rᵀx> / ||b||₂`` preserves angles. Non-negative input
data is assumed (SIFT / bag-of-words, as in the paper); inputs are
L2-normalized internally.

Encoding (their Algorithm 1) is exact and vectorized here: for v = Rᵀx,
sort v descending and pick the prefix length t maximizing
``prefix_sum(t) / sqrt(t)``; the code has ones at the top-t coordinates.

Learning alternates:
  B-step  encode all points with the current R,
  R-step  orthogonal Procrustes: R = U Vᵀ, where U S Vᵀ = svd(Xᵀ B̃),
          B̃ = codes normalized to unit L2 norm,
which monotonically improves the objective  Σᵢ <b̃ᵢ, Rᵀx̂ᵢ>.

Everything is JAX (jit-able); arrays stay on device.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AQBCModel(NamedTuple):
    rotation: jax.Array     # (d, c) with orthonormal columns
    objective_trace: jax.Array  # (iters,) training objective per iteration


def _normalize_rows(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


@functools.partial(jax.jit, static_argnames=())
def encode_projected(v: jax.Array) -> jax.Array:
    """Exact argmax_b <b,v>/||b|| for each row of v: (n, c) -> (n, c) uint8."""
    c = v.shape[-1]
    order = jnp.argsort(-v, axis=-1)                    # descending
    v_sorted = jnp.take_along_axis(v, order, axis=-1)
    prefix = jnp.cumsum(v_sorted, axis=-1)
    scores = prefix / jnp.sqrt(jnp.arange(1, c + 1, dtype=v.dtype))
    t_star = jnp.argmax(scores, axis=-1)                # best prefix length-1
    ranks = jnp.argsort(order, axis=-1)                 # rank of each coord
    bits = (ranks <= t_star[:, None]).astype(jnp.uint8)
    return bits


def encode(x: jax.Array, rotation: jax.Array) -> jax.Array:
    """Binarize raw vectors: (n, d) x (d, c) -> (n, c) uint8 codes."""
    v = _normalize_rows(x.astype(jnp.float32)) @ rotation
    return encode_projected(v)


def _objective(x_hat: jax.Array, rotation: jax.Array, bits: jax.Array):
    b_tilde = _normalize_rows(bits.astype(jnp.float32))
    return jnp.mean(jnp.sum((x_hat @ rotation) * b_tilde, axis=-1))


def learn(
    x: jax.Array | np.ndarray,
    code_bits: int,
    iters: int = 25,
    key: jax.Array | None = None,
) -> AQBCModel:
    """Learn the AQBC rotation on a (n, d) training set; c = code_bits <= d."""
    x = jnp.asarray(x, dtype=jnp.float32)
    n, d = x.shape
    c = code_bits
    if c > d:
        raise ValueError(f"code_bits={c} must be <= data dim {d}")
    if key is None:
        key = jax.random.key(0)
    x_hat = _normalize_rows(x)
    # init: random orthonormal columns
    g = jax.random.normal(key, (d, c), dtype=jnp.float32)
    rotation, _ = jnp.linalg.qr(g)

    def step(rotation, _):
        bits = encode_projected(x_hat @ rotation)
        b_tilde = _normalize_rows(bits.astype(jnp.float32))
        # Procrustes: maximize tr(Rᵀ Xᵀ B̃)
        u, _, vt = jnp.linalg.svd(x_hat.T @ b_tilde, full_matrices=False)
        new_rot = u @ vt
        return new_rot, _objective(x_hat, new_rot, encode_projected(x_hat @ new_rot))

    rotation, trace = jax.lax.scan(step, rotation, None, length=iters)
    return AQBCModel(rotation=rotation, objective_trace=trace)
