"""Probing-sequence generation (paper §4, RQ1, Props 1–3).

Produces Hamming-distance tuples in monotonically non-increasing order of
cosine similarity, using the paper's priority-queue + two-anchor algorithm:

- popping tuple R = (x, y) pushes
  * the **first anchor**: the max-sim tuple at distance x+y+1, i.e.
    ``(c, x+y+1-c)`` with ``c = max(0, x+y+1-(p-z))`` (Prop. 1), and
  * the **second anchor**: ``(x+1, y-1)`` — the next tuple at the same
    distance in decreasing-sim direction (Prop. 1),
  each pushed iff valid and not yet traversed.

We initialize the queue with (0, 0) (the query's own bucket): the paper's
closed-form phase for r <= rhat (Prop. 2) is an optimization of the same
order, which we also implement (``closed_form_prefix``) and property-test
for agreement. Priorities are exact rationals (sim^2 as Fraction) so tuple
ordering is never corrupted by floating point; ties are broken by
(ascending Hamming distance, ascending r1) for determinism.

Degenerate queries: z == 0 makes cosine undefined for every code; we fall
back to Hamming ordering (tuples are (0, r2), emitted by ascending r2), the
natural limit. Codes that are themselves the zero vector sort last.
"""

from __future__ import annotations

import heapq
import threading
import warnings
from collections import OrderedDict
from fractions import Fraction
from typing import Iterator, List, Optional, Tuple

from ..obs.metrics import REGISTRY as _REG
from .tuples import is_valid_tuple, rhat, sim_squared_fraction, sim_value

__all__ = [
    "probing_sequence",
    "closed_form_prefix",
    "first_anchor",
    "second_anchor",
    "probing_prefix",
    "shared_probing_iter",
    "probing_cache_clear",
    "probing_cache_info",
    "probing_cache_stats",
]


def first_anchor(p: int, z: int, x: int, y: int) -> Optional[Tuple[int, int]]:
    """Max-sim tuple at Hamming distance x+y+1 (paper Def. 5a)."""
    d = x + y + 1
    c = max(0, d - (p - z))
    t = (c, d - c)
    return t if is_valid_tuple(p, z, *t) else None


def second_anchor(p: int, z: int, x: int, y: int) -> Optional[Tuple[int, int]]:
    """Next-smaller-sim tuple at the same Hamming distance (paper Def. 5b)."""
    t = (x + 1, y - 1)
    return t if is_valid_tuple(p, z, *t) else None


def _priority(p: int, z: int, t: Tuple[int, int]):
    """Heap key: max-sim first; exact; deterministic tie-break."""
    r1, r2 = t
    if z == 0:
        # Hamming order on the zero query: only (0, r2) tuples are valid.
        return (Fraction(r2), 0, 0)
    return (-sim_squared_fraction(p, z, r1, r2), r1 + r2, r1)


def probing_sequence(
    p: int, z: int, limit: Optional[int] = None
) -> Iterator[Tuple[int, int]]:
    """Yield all valid tuples for (p, z) in non-increasing sim order.

    ``limit`` caps the number of tuples yielded (None = all
    (z+1)*(p-z+1) of them).
    """
    if not 0 <= z <= p:
        raise ValueError(f"need 0 <= z <= p, got z={z}, p={p}")
    start = (0, 0)
    heap = [(_priority(p, z, start), start)]
    traversed = {start}
    emitted = 0
    while heap:
        _, (x, y) = heapq.heappop(heap)
        yield (x, y)
        emitted += 1
        if limit is not None and emitted >= limit:
            return
        for anchor in (first_anchor(p, z, x, y), second_anchor(p, z, x, y)):
            if anchor is not None and anchor not in traversed:
                traversed.add(anchor)
                heapq.heappush(heap, (_priority(p, z, anchor), anchor))


def closed_form_prefix(p: int, z: int):
    """The provably-sorted prefix for r <= rhat (Props. 1–2, t=1).

    Within the Hamming ball C(q, rhat), sim strictly decreases with the
    Hamming distance, and within one distance r the order is
    (0, r), (1, r-1), ..., (r, 0). Returns the list of valid tuples in
    that closed-form order.
    """
    out = []
    for r in range(rhat(z) + 1):
        for r1 in range(r + 1):
            t = (r1, r - r1)
            if is_valid_tuple(p, z, *t):
                out.append(t)
    return out


def probing_sequence_with_sims(p: int, z: int, limit: Optional[int] = None):
    """Convenience for tests/benchmarks: [(tuple, sim_float), ...]."""
    return [
        (t, sim_value(p, z, *t)) for t in probing_sequence(p, z, limit=limit)
    ]


# --------------------------------------------------------------- shared cache
# The sequence depends only on (p, z) — not on the query, the index, or the
# shard — so materialized prefixes are cached at MODULE level and shared by
# every AMIHIndex in the process: a sharded engine with S shards enumerates
# each (p, z) once instead of S times, and the device probe path reads its
# walk arrays straight out of the same entries. The cache is a bounded LRU
# (whole (p, z) entries are evicted, never truncated) and is thread-safe:
# thread-mode shard probing extends entries concurrently.

class _SeqEntry:
    """One (p, z) entry: the materialized prefix plus the live generator
    that extends it. ``prefix`` is append-only — index-based readers can
    scan it without the lock; only extension takes ``_SEQ_LOCK``."""

    __slots__ = ("prefix", "gen", "exhausted")

    def __init__(self, p: int, z: int):
        self.prefix: List[Tuple[int, int]] = []
        self.gen = probing_sequence(p, z)
        self.exhausted = False

    def extend_to(self, count: int) -> None:
        """Materialize at least ``count`` tuples (or until exhaustion).
        Caller must hold ``_SEQ_LOCK``."""
        while len(self.prefix) < count and not self.exhausted:
            try:
                self.prefix.append(next(self.gen))
            except StopIteration:
                self.exhausted = True


_SEQ_CACHE: "OrderedDict[Tuple[int, int], _SeqEntry]" = OrderedDict()
_SEQ_CACHE_MAX = 64
_SEQ_LOCK = threading.RLock()
# process-lifetime hit/miss counters (see probing_cache_stats): a miss is
# one (p, z) enumeration from scratch, so hits/(hits+misses) is the share
# of probing-sequence work the cache absorbed
_SEQ_HITS = 0
_SEQ_MISSES = 0


def _seq_entry(p: int, z: int) -> _SeqEntry:
    """The shared cache entry for (p, z) (LRU-touched; caller need not hold
    the lock — entry internals are guarded separately)."""
    global _SEQ_HITS, _SEQ_MISSES
    with _SEQ_LOCK:
        entry = _SEQ_CACHE.get((p, z))
        if entry is None:
            _SEQ_MISSES += 1
            _REG.counter("cache.probing.misses").add(1)
            entry = _SeqEntry(p, z)
            _SEQ_CACHE[(p, z)] = entry
        else:
            _SEQ_HITS += 1
            _REG.counter("cache.probing.hits").add(1)
            _SEQ_CACHE.move_to_end((p, z))
        while len(_SEQ_CACHE) > _SEQ_CACHE_MAX:
            _SEQ_CACHE.popitem(last=False)
        return entry


def probing_prefix(p: int, z: int, count: int) -> List[Tuple[int, int]]:
    """The first ``count`` tuples of the (p, z) probing sequence (fewer if
    the walk is shorter), materialized once process-wide. The returned
    list is the live cache prefix — callers must treat it as read-only."""
    entry = _seq_entry(p, z)
    if len(entry.prefix) < count and not entry.exhausted:
        with _SEQ_LOCK:
            entry.extend_to(count)
    return entry.prefix


def shared_probing_iter(p: int, z: int) -> Iterator[Tuple[int, int]]:
    """Iterator over the (p, z) sequence backed by the shared cache:
    already-materialized tuples replay from the prefix list; going deeper
    extends it (under the lock) for every future consumer."""
    entry = _seq_entry(p, z)
    prefix = entry.prefix
    i = 0
    while True:
        if i >= len(prefix):
            with _SEQ_LOCK:
                entry.extend_to(i + 1)
            if i >= len(prefix):
                return
        yield prefix[i]
        i += 1


def probing_cache_clear() -> None:
    """Drop every cached sequence (benchmark seed loops; tests)."""
    with _SEQ_LOCK:
        _SEQ_CACHE.clear()


def probing_cache_info() -> Tuple[int, int]:
    """(entries, total materialized tuples) of the shared cache."""
    with _SEQ_LOCK:
        return (
            len(_SEQ_CACHE),
            sum(len(e.prefix) for e in _SEQ_CACHE.values()),
        )


def _cache_stats() -> dict:
    """Occupancy plus process-lifetime hit/miss counters of the shared
    (p, z) sequence cache — surfaced through ``EngineStats.cache_info``
    and the benchmark rows so cache effectiveness is visible per cell.
    Hit/miss counters are mirrored into the metrics registry as
    ``cache.probing.hits`` / ``cache.probing.misses``."""
    with _SEQ_LOCK:
        return {
            "probing_entries": len(_SEQ_CACHE),
            "probing_tuples": sum(
                len(e.prefix) for e in _SEQ_CACHE.values()
            ),
            "probing_hits": _SEQ_HITS,
            "probing_misses": _SEQ_MISSES,
        }


def probing_cache_stats() -> dict:
    """Deprecated alias of the internal cache-stat snapshot: new code
    reads the ``cache.probing.*`` counters off the metrics registry (or
    ``EngineStats.cache_info``, which engines still populate)."""
    warnings.warn(
        "probing_cache_stats() is deprecated; read the cache.probing.* "
        "counters from repro.obs.metrics.REGISTRY instead",
        DeprecationWarning, stacklevel=2,
    )
    return _cache_stats()
