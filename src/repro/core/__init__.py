"""The paper's primary contribution: exact angular KNN over binary codes.

Public surface:
  - SearchEngine / make_engine              (THE query API: one batched
    ``knn_batch`` over every backend — "linear_scan", "single_table",
    "amih" — selected by name; see engine.py. New callers start here.)
  - probing_sequence / closed_form_prefix   (RQ1, Props 1-3)
  - SingleTableIndex                        (single-table search, §4)
  - AMIHIndex / AMIHStats                   (angular multi-index hashing, §5)
  - linear_scan_knn                         (the paper's baseline)
  - aqbc                                    (binarization used in §6)
  - repro.shard                             (pod-scale sharded subsystem:
    ShardPlan + "sharded_scan"/"sharded_amih" backends with per-shard
    device placement; ``core.distributed`` is only a deprecated
    re-export shim over it, kept for old imports)

The index classes remain importable for algorithm-level work; serving,
benchmarks, and examples go through ``make_engine(backend, db_words, p)``
and ``engine.knn_batch(q_words, k) -> (ids, sims, EngineStats)``.
"""

from .amih import AMIHIndex, AMIHStats, default_num_tables
from .engine import (
    ENGINES,
    EngineStats,
    SearchEngine,
    available_backends,
    make_engine,
)
from .linear_scan import (
    linear_scan_knn,
    sims_against_db,
    sims_batch_against_db,
    topk_from_sims,
)
from .packing import (
    hamming_tuples,
    n_words,
    pack_bits,
    popcount,
    substring_spans,
    unpack_bits,
)
from .probing import closed_form_prefix, probing_sequence
from .single_table import SearchStats, SingleTableIndex
from .tuples import rhat, sim_value, tuple_count

__all__ = [
    "AMIHIndex",
    "AMIHStats",
    "ENGINES",
    "EngineStats",
    "SearchEngine",
    "SearchStats",
    "SingleTableIndex",
    "available_backends",
    "closed_form_prefix",
    "default_num_tables",
    "hamming_tuples",
    "linear_scan_knn",
    "make_engine",
    "n_words",
    "pack_bits",
    "popcount",
    "probing_sequence",
    "rhat",
    "sim_value",
    "sims_against_db",
    "sims_batch_against_db",
    "substring_spans",
    "topk_from_sims",
    "tuple_count",
    "unpack_bits",
]
