"""The paper's primary contribution: exact angular KNN over binary codes.

Public surface:
  - probing_sequence / closed_form_prefix   (RQ1, Props 1-3)
  - SingleTableIndex                        (single-table search, §4)
  - AMIHIndex / AMIHStats                   (angular multi-index hashing, §5)
  - linear_scan_knn                         (the paper's baseline)
  - aqbc                                    (binarization used in §6)
  - distributed                             (sharded scan for pod-scale DBs)
"""

from .amih import AMIHIndex, AMIHStats, default_num_tables
from .linear_scan import linear_scan_knn, sims_against_db
from .packing import (
    hamming_tuples,
    n_words,
    pack_bits,
    popcount,
    substring_spans,
    unpack_bits,
)
from .probing import closed_form_prefix, probing_sequence
from .single_table import SearchStats, SingleTableIndex
from .tuples import rhat, sim_value, tuple_count

__all__ = [
    "AMIHIndex",
    "AMIHStats",
    "SearchStats",
    "SingleTableIndex",
    "closed_form_prefix",
    "default_num_tables",
    "hamming_tuples",
    "linear_scan_knn",
    "n_words",
    "pack_bits",
    "popcount",
    "probing_sequence",
    "rhat",
    "sim_value",
    "sims_against_db",
    "substring_spans",
    "tuple_count",
    "unpack_bits",
]
