"""Angular Multi-Index Hashing — the paper's primary contribution (§5, RQ2).

Long p-bit codes are split into ``m`` disjoint substrings; each substring is
indexed in its own table (CSR-sorted, see single_table.py). An exact angular
KNN query walks the full-code tuple sequence (probing.py) in decreasing-sim
order; before emitting the codes at full tuple ``(r1, r2)`` it performs the
substring probes required by Proposition 4:

    T_{r1,r2,m} = { (a, b) : a + b <= floor((r1+r2)/m), a <= r1, b <= r2 }

probed in *every* table. Any code with Hamming tuple <= (r1, r2) — in
particular, exactly (r1, r2) — is guaranteed (pigeonhole) to fall in one of
those buckets, so emission order is exact. Retrieved candidates are verified
once (dedup bitmap) by computing their exact full-code tuple with popcounts.

Counters mirror the paper's cost model (Eq. 13): probes (bucket lookups) and
candidate verifications are the two cost terms.

Batched queries (``knn_batch``) follow the multi-index-hashing serving
shape: queries with identical ``(p, z)`` share one probing-sequence
enumeration (the heap + exact-rational ordering is per-*group*, not
per-query) and advance in lockstep over full-code tuples. Each tuple step
is a probe -> verify -> bucket -> emit pipeline:

  1. probe: every active query runs its outstanding substring-tuple
     probes (host, per-query — the tables are host CSR structures) and
     collects its *fresh* candidate ids;
  2. verify: the whole z-group is verified in ONE call. With
     ``verify_backend="numpy"`` that is a single vectorized popcount over
     the concatenated blocks; with ``verify_backend="pallas"`` the blocks
     become a padded ``(B_g, C_max, W)`` device layout (power-of-two
     padding buckets keep the jit cache bounded) and one
     ``verify_tuples_grouped`` launch per (z-group, tuple-step) returns
     packed bucket keys ``r10 * (p + 1) + r01`` — candidate rows are
     gathered on device from the resident copy of ``db_words`` uploaded
     once at build, so only the (B_g, C_max) index/key matrices cross the
     host-device boundary (see kernels/ops.verify_tuples_grouped_op);
  3. bucket: keys are grouped by one stable argsort per query (no
     ``np.unique(axis=0)`` on the hot path) into the pending dict;
  4. emit: codes whose bucket equals the current tuple are appended in
     ascending-id order at the host float64 ``sim_value`` — emission sims
     never round-trip through float32, keeping results bit-identical to
     ``linear_scan_knn``.

``verify_launches`` on the index counts grouped verification dispatches
(one per (z-group, tuple-step) unless a block exceeds the element budget).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..obs import trace as _obs
from .enumeration import tuple_bucket_values
from .packing import (
    WORD_DTYPE,
    extract_substring,
    hamming_tuples,
    popcount,
    substring_spans,
)
from .probing import shared_probing_iter
from .tuples import rhat, sim_value

__all__ = ["AMIHIndex", "AMIHStats", "default_num_tables"]

_EMPTY_IDS = np.empty(0, dtype=np.int64)


def default_num_tables(p: int, n: int) -> int:
    """Paper §5.2 / §6.2: m ≈ p / log2(n), clamped to [ceil(p/64), p].

    The lower clamp keeps every substring <= 64 bits so bucket indices fit
    an integer word (the paper's tables are likewise word-indexed).
    """
    m_min = (p + 63) // 64
    if n < 2:
        return m_min
    m = int(round(p / max(1.0, math.log2(n))))
    return max(m_min, min(p, m))


@dataclass
class AMIHStats:
    probes: int = 0              # bucket lookups across all tables
    retrieved: int = 0           # ids pulled from buckets (incl. cross-table dups)
    verified: int = 0            # unique candidates tuple-verified
    tuples_processed: int = 0    # full-code tuples traversed
    substring_tuples_probed: int = 0
    max_radius: int = 0
    exceeded_rhat: bool = False
    # The paper (§5) observes that when required probes exceed the dataset
    # size, linear scan is the faster alternative. We make that a guard:
    # once a single substring-tuple's bucket enumeration would cost more
    # than verifying every stored code, the query degrades gracefully to a
    # full verification pass (still exact).
    fell_back_to_scan: bool = False


@dataclass
class _SubTable:
    lo: int
    hi: int
    sorted_vals: np.ndarray = field(repr=False)
    sorted_ids: np.ndarray = field(repr=False)

    @property
    def width(self) -> int:
        return self.hi - self.lo

    def probe(self, bucket_vals: np.ndarray) -> np.ndarray:
        if bucket_vals.size == 0:
            return np.empty(0, dtype=np.int64)
        lo = np.searchsorted(self.sorted_vals, bucket_vals, side="left")
        hi = np.searchsorted(self.sorted_vals, bucket_vals, side="right")
        nz = hi > lo
        if not nz.any():
            return np.empty(0, dtype=np.int64)
        parts = [self.sorted_ids[l:h] for l, h in zip(lo[nz], hi[nz])]
        return np.concatenate(parts)


@dataclass
class _QueryState:
    """Per-query probing state inside a batched search.

    ``cover[s]`` maps substring-tuple weight ``a`` to the largest ``b``
    already probed in table ``s``. Probes for one (s, a) always extend a
    contiguous prefix b = 0..bmax, so the max-b staircase is a lossless
    (and O(1)-membership) replacement for the old probed-(s, a, b) set.
    ``scanned`` marks a query degraded to full verification (every id
    seen) — no more probing needed.
    """

    qi: int                       # row in the query batch
    q_words: np.ndarray
    q_subs: List[int]
    z_subs: List[int]
    seen: np.ndarray
    cover: List[Dict[int, int]]
    pending: Dict[Tuple[int, int], List[np.ndarray]]
    out_ids: List[int]
    out_sims: List[float]
    stats: Optional[AMIHStats]
    scanned: bool = False
    done: bool = False


@dataclass
class AMIHIndex:
    """Exact angular-KNN index over n packed p-bit codes.

    ``id_offset`` supports shard-local builds: an index over rows
    [offset, offset + n) of a larger sharded DB emits *global* ids
    (local row + offset) from every public search method, so per-shard
    result lists merge without any caller-side remapping. Internal state
    (tables, dedup bitmaps, device gathers) stays local-row-indexed.

    ``device`` places the index's device state: ``db_dev`` is committed
    to it (``jax.device_put``) and every grouped-verify launch runs
    there. ``None`` keeps the default device — the single-index engines'
    behavior. The sharded AMIH engine assigns each shard's index its own
    device from the ``ShardPlan`` so per-shard verification scales device
    memory and verify bandwidth with the shard count instead of
    funnelling every shard through device 0.
    """

    p: int
    m: int
    db_words: np.ndarray = field(repr=False)   # (n, W) uint32 — for verification
    tables: List[_SubTable] = field(repr=False, default_factory=list)
    id_offset: int = 0
    # Placement device for db_dev + grouped-verify launches (None: default).
    device: Optional[object] = field(default=None, compare=False)
    # Candidate-verification backend: "numpy" (one vectorized host popcount
    # per z-group and tuple step) or "pallas" (one verify_tuples_grouped
    # launch per z-group and tuple step — native on TPU, interpret-mode
    # elsewhere). Both are exact.
    verify_backend: str = "numpy"
    # Probing backend: "host" walks the tuple sequence in the Python
    # group loop below; "device" compiles the whole walk — probe-step
    # enumeration, CSR bucket lookup, candidate dedup, grouped
    # verification, and Prop. 2 early termination — into ONE jitted
    # launch per batch, every z-group fused (see core/probe_device.py
    # and kernels/device_probe.py). Both are exact and bit-identical.
    probe_backend: str = "host"
    # Device-path schedule bound: max precomputed probe-stream entries
    # per (p, z). Walks that would exceed it are truncated and finish
    # through the fused scan fallback (the device analogue of the host
    # enumeration-cap guard).
    probe_stream_cap: int = 1 << 16
    # Device-path launch shape: True (default) fuses every z-group of a
    # batch into ONE walk launch via the schedule stack; False keeps the
    # PR 6 one-launch-per-z-group shape (the fused path's parity oracle).
    probe_fused: bool = True
    # Grouped verification dispatches so far (one per (z-group, tuple-step)
    # with fresh candidates, unless a step exceeds verify_elem_budget and
    # is chunked). Benchmarks/tests assert launch economy through this.
    verify_launches: int = 0
    # Cap on padded gather elements (B_g_pad * C_max_pad * W words) per
    # device launch; oversized steps (e.g. a fell-back-to-scan query whose
    # block is the whole DB) are split across launches instead of
    # materializing an unbounded (B_g, C_max, W) buffer.
    verify_elem_budget: int = 1 << 24
    # Device-resident copy of db_words: uploaded once (eagerly at build for
    # verify_backend="pallas", lazily otherwise) so grouped verification
    # gathers candidate rows on device instead of re-shipping them per call.
    _db_dev: Optional[object] = field(
        default=None, repr=False, compare=False
    )
    # Device-resident CSR bucket layout (offsets + sorted ids + padded
    # codes), built next to db_dev for probe_backend="device".
    _device_csr: Optional[dict] = field(
        default=None, repr=False, compare=False
    )

    # ------------------------------------------------------------- build
    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        m: Optional[int] = None,
        verify_backend: str = "numpy",
        id_offset: int = 0,
        device: Optional[object] = None,
        probe_backend: str = "host",
        probe_stream_cap: int = 1 << 16,
        probe_fused: bool = True,
    ) -> "AMIHIndex":
        if verify_backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown verify_backend {verify_backend!r}")
        if probe_backend not in ("host", "device"):
            raise ValueError(f"unknown probe_backend {probe_backend!r}")
        db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        n = db_words.shape[0]
        if m is None:
            m = default_num_tables(p, n)
        if p / m > 64:
            raise ValueError(
                f"m={m} gives substrings wider than 64 bits for p={p}; "
                f"need m >= {(p + 63) // 64}"
            )
        tables = []
        for (lo, hi) in substring_spans(p, m):
            vals = extract_substring(db_words, lo, hi)
            order = np.argsort(vals, kind="stable")
            tables.append(
                _SubTable(
                    lo=lo,
                    hi=hi,
                    sorted_vals=vals[order],
                    sorted_ids=np.arange(n, dtype=np.int64)[order],
                )
            )
        index = cls(
            p=p, m=m, db_words=db_words, tables=tables,
            verify_backend=verify_backend, id_offset=id_offset,
            device=device, probe_backend=probe_backend,
            probe_stream_cap=probe_stream_cap, probe_fused=probe_fused,
        )
        if verify_backend == "pallas":
            index.db_dev  # upload once, at build time
        if probe_backend == "device":
            index.device_csr  # validate widths + upload once, at build
        return index

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    @property
    def db_dev(self):
        """Device-resident (n, W) codes (uploaded on first access).

        With a placement ``device`` the upload COMMITS the array there
        (``jax.device_put``), so every jitted computation consuming it —
        the grouped verifies — compiles for and runs on that device."""
        if self._db_dev is None:
            import jax
            import jax.numpy as jnp

            if self.device is not None:
                self._db_dev = jax.device_put(self.db_words, self.device)
            else:
                self._db_dev = jnp.asarray(self.db_words)
        return self._db_dev

    @property
    def device_csr(self) -> dict:
        """Device-resident CSR bucket layout for the fused probing walk
        (built and committed to ``device`` on first access; eagerly at
        build for ``probe_backend="device"``)."""
        if self._device_csr is None:
            from .probe_device import build_device_csr

            self._device_csr = build_device_csr(self)
        return self._device_csr

    # ------------------------------------------------------------- search
    def knn(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[AMIHStats] = None,
        enumeration_cap: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact angular K nearest neighbors of a packed query.

        Returns (ids, sims); deterministic up to ties inside the final
        tuple (all codes of one tuple are exactly equidistant in angle).
        """
        q_words = np.asarray(q_words, dtype=WORD_DTYPE)
        ids, sims = self.knn_batch(
            q_words[None, :], k,
            stats=None if stats is None else [stats],
            enumeration_cap=enumeration_cap,
        )
        return ids[0], sims[0]

    def knn_batch(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[List[AMIHStats]] = None,
        enumeration_cap: Optional[int] = None,
        overlap=None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact angular KNN for a batch of packed queries: (B, W) -> ids,
        sims each (B, min(k, n)).

        Queries with equal popcount z share one probing-sequence
        enumeration and advance in lockstep through the probe ->
        grouped-verify -> bucket -> emit pipeline (one verification call
        per z-group and tuple step, see module docstring); each query
        keeps its own dedup bitmap / probe-cover staircase / pending
        buckets, so per-query results and counters are identical to
        ``knn`` run query-by-query.

        ``overlap`` (a ``repro.pipeline.VerifyOverlap``) pipelines each
        group's tuple loop one step deep — step t verifies while step
        t+1 probes. Results stay bit-identical; probe-side counters of a
        finishing query may run one step past the sequential ones (see
        pipeline/overlap.py).
        """
        q_words = np.ascontiguousarray(
            np.atleast_2d(np.asarray(q_words, dtype=WORD_DTYPE))
        )
        B = q_words.shape[0]
        if stats is not None and len(stats) != B:
            raise ValueError(f"stats list has {len(stats)} entries for B={B}")
        k = min(k, self.n)
        out_ids = np.empty((B, k), dtype=np.int64)
        out_sims = np.empty((B, k), dtype=np.float64)
        if k == 0:
            return out_ids, out_sims
        for s in self._run_groups(
            q_words, k, stats, enumeration_cap, overlap=overlap
        ):
            out_ids[s.qi] = s.out_ids
            out_sims[s.qi] = s.out_sims
        if self.id_offset:
            out_ids += self.id_offset
        return out_ids, out_sims

    def knn_batch_bounded(
        self,
        q_words: np.ndarray,
        k: int,
        stop_below: np.ndarray,
        stats: Optional[List[AMIHStats]] = None,
        enumeration_cap: Optional[int] = None,
        overlap=None,
        on_done=None,
    ) -> List[Tuple[np.ndarray, np.ndarray]]:
        """``knn_batch`` with a per-query early-termination bound: query
        ``qi`` stops as soon as the next probing tuple's sim drops
        *strictly below* ``stop_below[qi]``, so its result list may hold
        fewer than k entries (ragged -> returned as a per-query list).

        This is the cross-shard termination rule of the sharded AMIH
        engine: once the global top-K heap (merged from other shards)
        holds K results with k-th cosine >= bound, a shard may stop —
        every un-emitted local code has sim <= the current tuple's
        sim < bound and cannot enter the global top-K. Ties at exactly
        the bound are still collected, so the merged sims stay
        bit-identical to an unsharded search. Emitted ids carry
        ``id_offset`` like every public method.

        ``stop_below`` is re-read at EVERY tuple step through a no-copy
        view, so callers may hand in a live array whose entries are
        raised concurrently (the shard-parallel shared bound of
        repro.pipeline.shardpool): as long as each entry only ever
        increases and stays a valid lower bound on the query's global
        k-th cosine, results remain exact. The live contract requires a
        float64 array of shape (B,) — any other dtype or shape is
        SNAPSHOTTED by the entry conversion (results stay exact, but
        concurrent raises are never observed).

        ``on_done(qi, ids, sims)`` fires the moment query ``qi`` fills
        its K results (its final, already-offset id/sim arrays) — the
        shard-parallel pool publishes the local k-th to peers through it
        while this search is still probing other queries.
        """
        q_words = np.ascontiguousarray(
            np.atleast_2d(np.asarray(q_words, dtype=WORD_DTYPE))
        )
        B = q_words.shape[0]
        bounds_in = np.asarray(stop_below, dtype=np.float64)
        bounds = (
            bounds_in if bounds_in.shape == (B,)
            else np.broadcast_to(bounds_in, (B,))
        )
        if stats is not None and len(stats) != B:
            raise ValueError(f"stats list has {len(stats)} entries for B={B}")
        k = min(k, self.n)
        empty = (_EMPTY_IDS, np.empty(0, dtype=np.float64))
        out: List[Tuple[np.ndarray, np.ndarray]] = [empty] * B
        if k == 0:
            return out
        for s in self._run_groups(
            q_words, k, stats, enumeration_cap, stop_below=bounds,
            overlap=overlap, on_done=on_done,
        ):
            ids = np.asarray(s.out_ids, dtype=np.int64) + self.id_offset
            out[s.qi] = (ids, np.asarray(s.out_sims, dtype=np.float64))
        return out

    def _run_groups(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[List[AMIHStats]],
        enumeration_cap: Optional[int],
        stop_below: Optional[np.ndarray] = None,
        overlap=None,
        on_done=None,
    ) -> List[_QueryState]:
        """Shared group loop of ``knn_batch`` / ``knn_batch_bounded``:
        same-z queries advance in lockstep through the probe ->
        grouped-verify -> bucket -> emit pipeline. Returns every query's
        final state (out_ids/out_sims hold LOCAL row ids). With
        ``overlap`` (repro.pipeline.VerifyOverlap) each group's loop is
        software-pipelined one tuple step deep instead.

        With ``probe_backend="device"`` the whole group loop is replaced
        by the fused device walk (ONE launch for the whole batch — every
        z-group shares it via the schedule stack — plus at most one
        scan-fallback launch; ``probe_fused=False`` restores the PR 6
        one-launch-per-z-group shape): results and the early-termination
        contract are identical, but ``enumeration_cap`` and ``overlap``
        are no-ops there — the device path bounds work through
        ``probe_stream_cap`` / the fused scan, and has no host loop left
        to overlap."""
        if self.probe_backend == "device":
            from .probe_device import run_groups_device

            return run_groups_device(
                self, q_words, k, stats,
                stop_below=stop_below, on_done=on_done,
            )
        B = q_words.shape[0]
        zs = popcount(q_words)
        groups: Dict[int, List[int]] = {}
        for qi in range(B):
            groups.setdefault(int(zs[qi]), []).append(qi)

        done_states: List[_QueryState] = []
        for z, qis in groups.items():
            states = [self._make_state(q_words[qi], qi, stats) for qi in qis]
            if overlap is not None:
                overlap.run_group(
                    self, z, states, k, enumeration_cap, stop_below,
                    on_done=on_done,
                )
            else:
                self._run_group_sequential(
                    z, states, k, enumeration_cap, stop_below, on_done
                )
            done_states.extend(states)
        return done_states

    def _notify_done(self, states, on_done) -> None:
        """Fire ``on_done`` for states that just filled their K (their
        result lists are final from this point on)."""
        for s in states:
            if s.done:
                on_done(
                    s.qi,
                    np.asarray(s.out_ids, dtype=np.int64) + self.id_offset,
                    np.asarray(s.out_sims, dtype=np.float64),
                )

    def _run_group_sequential(
        self,
        z: int,
        states: List[_QueryState],
        k: int,
        enumeration_cap: Optional[int],
        stop_below: Optional[np.ndarray],
        on_done=None,
    ) -> None:
        """One z-group's strict probe -> verify -> bucket -> emit loop."""
        r_hat = rhat(z)
        # spans observe the loop, never reorder it: the traced path runs
        # the identical statements, it only reads the clock around them
        tr = _obs.current()
        traced = tr.enabled
        for (r1, r2) in self._probing_iter(z):
            active = [s for s in states if not s.done]
            if not active:
                break
            s_val = sim_value(self.p, z, r1, r2)
            if stop_below is not None:
                # every later tuple has sim <= s_val: below the bound
                # nothing more from this query can reach the global
                # top-K (ties at the bound keep probing).
                for s in active:
                    if s_val < stop_below[s.qi]:
                        s.done = True
                active = [s for s in active if not s.done]
                if not active:
                    break
            # 1. probe: per-query table lookups -> fresh candidate ids
            t0 = _obs.now_us() if traced else 0.0
            fresh_states: List[_QueryState] = []
            fresh_blocks: List[np.ndarray] = []
            for s in active:
                fresh = self._probe_step(s, r1, r2, r_hat, enumeration_cap)
                if fresh.size:
                    if s.stats is not None:
                        s.stats.verified += fresh.size
                    fresh_states.append(s)
                    fresh_blocks.append(fresh)
            if traced:
                tr.record("amih.probe", t0, _obs.now_us(), cat="amih",
                          z=z, r1=r1, r2=r2, queries=len(active))
            # 2+3. verify the whole z-group in one call and bucket
            if fresh_blocks:
                self._verify_and_bucket(fresh_states, fresh_blocks)
            # 4. emit this tuple's bucket per query
            t0 = _obs.now_us() if traced else 0.0
            self._emit_tuple(active, r1, r2, s_val, k)
            if traced:
                tr.record("amih.emit", t0, _obs.now_us(), cat="amih", z=z)
            if on_done is not None:
                self._notify_done(active, on_done)

    def _probe_step(
        self,
        s: _QueryState,
        r1: int,
        r2: int,
        r_hat: int,
        enumeration_cap: Optional[int],
    ) -> np.ndarray:
        """Per-query probing for one tuple step, with its stats updates
        (shared by the sequential and the pipelined group loop)."""
        if s.stats is not None:
            s.stats.tuples_processed += 1
            s.stats.max_radius = max(s.stats.max_radius, r1 + r2)
            if r1 + r2 > r_hat:
                s.stats.exceeded_rhat = True
        return self._probe_tables_for_tuple(s, r1, r2, enumeration_cap)

    def _emit_tuple(self, states, r1: int, r2: int, s_val: float, k: int):
        """Step 4: emit tuple (r1, r2)'s bucket for each given state, in
        ascending-id order at the host float64 sim, capping at k."""
        for s in states:
            hits = s.pending.pop((r1, r2), None)
            if hits:
                ids = np.sort(np.concatenate(hits))
                take = min(ids.size, k - len(s.out_ids))
                s.out_ids.extend(ids[:take].tolist())
                s.out_sims.extend([s_val] * take)
                if len(s.out_ids) >= k:
                    s.done = True

    def _probing_iter(self, z: int) -> Iterator[Tuple[int, int]]:
        """Probing sequence for popcount z, served from the MODULE-level
        shared cache (repro.core.probing): the heap + exact-rational tuple
        ordering depends only on (p, z), so one materialized prefix serves
        every index, shard, and batch in the process."""
        return shared_probing_iter(self.p, z)

    def _make_state(
        self,
        q_words: np.ndarray,
        qi: int,
        stats: Optional[List[AMIHStats]],
    ) -> _QueryState:
        q_subs = [
            int(extract_substring(q_words[None, :], t.lo, t.hi)[0])
            for t in self.tables
        ]
        return _QueryState(
            qi=qi,
            q_words=q_words,
            q_subs=q_subs,
            z_subs=[int(v).bit_count() for v in q_subs],
            seen=np.zeros(self.n, dtype=bool),
            cover=[{} for _ in self.tables],
            pending={},
            out_ids=[],
            out_sims=[],
            stats=None if stats is None else stats[qi],
        )

    def search_radius(
        self,
        q_words: np.ndarray,
        r1: int,
        r2: int,
        stats: Optional[AMIHStats] = None,
        enumeration_cap: Optional[int] = None,
    ) -> np.ndarray:
        """The (r1, r2)-near neighbor problem (Def. 4): all codes with
        Hamming tuple <= (r1, r2) componentwise. Returns sorted ids."""
        q_words = np.asarray(q_words, dtype=WORD_DTYPE)
        state = self._make_state(q_words, 0, None)
        state.stats = stats
        fresh = self._probe_tables_for_tuple(state, r1, r2, enumeration_cap)
        if fresh.size:
            if stats is not None:
                stats.verified += fresh.size
            self._verify_and_bucket([state], [fresh])
        matches = [
            np.concatenate(v)
            for (e1, e2), v in state.pending.items()
            if e1 <= r1 and e2 <= r2
        ]
        if not matches:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.concatenate(matches)) + self.id_offset

    # ------------------------------------------------------------ private
    def _probe_tables_for_tuple(
        self,
        state: _QueryState,
        r1: int,
        r2: int,
        enumeration_cap: Optional[int],
    ) -> np.ndarray:
        """Run all not-yet-done probes required by T_{r1,r2,m} (Prop. 4)
        for one query; return its fresh (never-seen) candidate ids.

        Probing only — verification happens once per z-group in
        ``_verify_and_bucket``. The per-table ``cover`` staircase (max b
        probed per a) makes the already-probed check O(tables * rsub)
        instead of re-enumerating and set-filtering every (s, a, b) combo
        per tuple step.

        Cost guard: if a single substring-tuple enumeration would probe
        more buckets than there are stored codes (or than
        ``enumeration_cap``), bucket probing has lost to exhaustive
        verification — every not-yet-seen code becomes a candidate instead
        (exact; the paper's §5 observation that "linear scan is a faster
        alternative" past that point) and ``state.scanned``
        short-circuits later tuples.
        """
        if state.scanned:
            return _EMPTY_IDS
        rsub = (r1 + r2) // self.m
        if enumeration_cap is None:
            # same n-scaled default as the engine layer: max(8n, 16384)
            enumeration_cap = max(8 * self.n, 1 << 14)
        cap = min(enumeration_cap, max(self.n, 1))
        stats = state.stats
        z_subs = state.z_subs
        new_ids: List[np.ndarray] = []
        for s, table in enumerate(self.tables):
            w_s, z_s = table.width, z_subs[s]
            amax = min(r1, z_s, rsub)
            cov = state.cover[s]
            for a in range(amax + 1):
                bmax = min(r2, w_s - z_s, rsub - a)
                b0 = cov.get(a, -1) + 1
                if b0 > bmax:
                    continue
                cov[a] = bmax
                for b in range(b0, bmax + 1):
                    n_buckets = math.comb(z_s, a) * math.comb(w_s - z_s, b)
                    if n_buckets > cap:
                        state.scanned = True
                        fresh = np.flatnonzero(~state.seen)
                        state.seen[:] = True
                        if fresh.size:
                            new_ids.append(fresh)
                        if stats is not None:
                            stats.fell_back_to_scan = True
                            stats.retrieved += fresh.size
                        return (
                            np.concatenate(new_ids) if len(new_ids) > 1
                            else new_ids[0] if new_ids else _EMPTY_IDS
                        )
                    buckets = tuple_bucket_values(
                        state.q_subs[s], w_s, z_s, a, b, cap=None
                    )
                    if stats is not None:
                        stats.substring_tuples_probed += 1
                        stats.probes += len(buckets)
                    ids = table.probe(buckets)
                    if stats is not None:
                        stats.retrieved += len(ids)
                    if ids.size:
                        fresh = ids[~state.seen[ids]]
                        if fresh.size:
                            state.seen[fresh] = True
                            new_ids.append(fresh)
        if not new_ids:
            return _EMPTY_IDS
        return np.concatenate(new_ids) if len(new_ids) > 1 else new_ids[0]

    def _verify_and_bucket(
        self,
        states: List[_QueryState],
        blocks: List[np.ndarray],
    ) -> None:
        """Verify every query's fresh candidate block in ONE backend call
        and bucket the candidates by their exact full-code tuple.

        Tuples are handled as packed keys ``r10 * (p + 1) + r01``
        throughout; bucketing is one stable argsort + boundary scan per
        query (the old np.unique(axis=0) row-sort was the dominant fixed
        cost of small verification batches).
        """
        self._bucket_keys(states, blocks, self._verify_keys(states, blocks))

    def _verify_keys(
        self, states: List[_QueryState], blocks: List[np.ndarray]
    ) -> List[np.ndarray]:
        """Backend half of ``_verify_and_bucket``: the grouped tuple
        verification alone, returning per-query packed-key arrays. Reads
        only the index and the DB — safe to run on a worker thread while
        the main thread probes the next tuple step (pipeline/overlap.py);
        the mutable bucketing stays on the caller's thread."""
        tr = _obs.current()
        if not tr.enabled:
            if self.verify_backend == "pallas":
                return self._verify_group_pallas(states, blocks)
            return self._verify_group_numpy(states, blocks)
        t0 = _obs.now_us()
        if self.verify_backend == "pallas":
            out = self._verify_group_pallas(states, blocks)
        else:
            out = self._verify_group_numpy(states, blocks)
        tr.record("amih.verify", t0, _obs.now_us(), cat="amih",
                  backend=self.verify_backend, queries=len(states),
                  candidates=int(sum(b.size for b in blocks)))
        return out

    def _bucket_keys(
        self,
        states: List[_QueryState],
        blocks: List[np.ndarray],
        keys_list: List[np.ndarray],
    ) -> None:
        """Bucketing half of ``_verify_and_bucket``: group each query's
        candidates by packed key into its pending dict."""
        tr = _obs.current()
        t0 = _obs.now_us() if tr.enabled else 0.0
        pp = self.p + 1
        for state, cand, keys in zip(states, blocks, keys_list):
            order = np.argsort(keys, kind="stable")
            ks = keys[order]
            cuts = np.flatnonzero(ks[1:] != ks[:-1]) + 1
            bounds = np.concatenate(([0], cuts, [ks.size]))
            pending = state.pending
            for i in range(bounds.size - 1):
                lo, hi = bounds[i], bounds[i + 1]
                kk = int(ks[lo])
                pending.setdefault((kk // pp, kk % pp), []).append(
                    cand[order[lo:hi]]
                )
        if tr.enabled:
            tr.record("amih.bucket", t0, _obs.now_us(), cat="amih",
                      queries=len(states))

    def _verify_group_numpy(
        self, states: List[_QueryState], blocks: List[np.ndarray]
    ) -> List[np.ndarray]:
        """One vectorized host popcount over the whole z-group: blocks are
        concatenated (ragged — no padding needed on host) with queries
        repeated per-candidate, then split back per query."""
        self.verify_launches += 1
        if len(blocks) == 1:
            r10, r01 = hamming_tuples(
                states[0].q_words, self.db_words[blocks[0]]
            )
            return [r10 * (self.p + 1) + r01]
        lengths = [b.size for b in blocks]
        cand = np.concatenate(blocks)
        q_rep = np.repeat(
            np.stack([s.q_words for s in states]), lengths, axis=0
        )
        r10, r01 = hamming_tuples(q_rep, self.db_words[cand])
        keys = r10 * (self.p + 1) + r01
        out, off = [], 0
        for length in lengths:
            out.append(keys[off : off + length])
            off += length
        return out

    def _verify_group_pallas(
        self, states: List[_QueryState], blocks: List[np.ndarray]
    ) -> List[np.ndarray]:
        """``verify_tuples_grouped`` launches for the z-group: blocks are
        gathered device-side from the resident DB into a padded
        (B_g, C_max, W) layout and come back as packed bucket keys.

        Steps whose padded gather would exceed ``verify_elem_budget``
        words are split across several launches — greedily over query
        rows, and along the candidate axis when even a single block is
        oversized (a fell-back-to-scan query's block is the whole DB) —
        bounded device memory beats launch economy there. Regular
        sub-batches are double-buffered: the next launch is DISPATCHED
        (``ops.verify_tuples_grouped_launch`` is non-blocking) before
        the previous one is resolved, overlapping device work and
        transfers — but at most two launches are ever in flight, and the
        column chunks of an oversized block resolve eagerly, because
        each in-flight launch holds its padded buffers live and an
        unbounded queue would rebuild exactly the footprint the budget
        exists to prevent.
        """
        from ..kernels import ops

        W = self.db_words.shape[1]
        budget = max(self.verify_elem_budget, 8 * W)
        # largest power of two <= budget // W: keeps segments aligned with
        # the op's pad_bucket so padding never blows past the budget
        col_step = max(8, 1 << (max(budget // W, 1).bit_length() - 1))
        # deferred materializers, double-buffered: at most 2 in flight
        pending: List[object] = []
        out: List[Optional[np.ndarray]] = [None] * len(blocks)
        i = 0
        while i < len(blocks):
            if ops.pad_bucket(blocks[i].size, minimum=8) * W > budget:
                # oversized single block: chunk along the candidate axis
                # and resolve each segment eagerly (keeping them all in
                # flight would hold ~N/col_step padded buffers live)
                block = blocks[i]
                q_row = states[i].q_words[None, :]
                parts: List[np.ndarray] = []
                for lo in range(0, block.size, col_step):
                    seg = block[lo : lo + col_step]
                    self.verify_launches += 1
                    parts.append(ops.verify_tuples_grouped_launch(
                        q_row,
                        self.db_dev,
                        np.ascontiguousarray(seg[None, :]),
                        np.array([seg.size], dtype=np.int32),
                        p=self.p,
                        use_pallas=True,
                        device=self.device,
                    ).get()[0].astype(np.int64))
                out[i] = np.concatenate(parts)
                i += 1
                continue
            # greedy row sub-batch whose shared padded width fits budget
            j, c_pad = i, 0
            while j < len(blocks):
                c_j = ops.pad_bucket(blocks[j].size, minimum=8)
                if c_j * W > budget:
                    break  # oversized block: column-chunked next round
                c_new = max(c_pad, c_j)
                rows_pad = ops.pad_bucket(j - i + 1, minimum=1)
                if j > i and rows_pad * c_new * W > budget:
                    break
                c_pad = c_new
                j += 1
            sub_states, sub_blocks = states[i:j], blocks[i:j]
            c_max = max(b.size for b in sub_blocks)
            idx = np.zeros((len(sub_blocks), c_max), dtype=np.int32)
            lengths = np.empty(len(sub_blocks), dtype=np.int32)
            for t, b in enumerate(sub_blocks):
                idx[t, : b.size] = b
                lengths[t] = b.size
            self.verify_launches += 1
            handle = ops.verify_tuples_grouped_launch(
                np.stack([s.q_words for s in sub_states]),
                self.db_dev,
                idx,
                lengths,
                p=self.p,
                use_pallas=True,
                device=self.device,
            )

            def resolve_grouped(row=i, handle=handle, sizes=[b.size for b in sub_blocks]):
                keys = handle.get()
                for t, size in enumerate(sizes):
                    out[row + t] = keys[t, :size].astype(np.int64)

            pending.append(resolve_grouped)
            if len(pending) >= 2:
                pending.pop(0)()
            i = j
        for resolve in pending:
            resolve()
        return out
