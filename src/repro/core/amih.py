"""Angular Multi-Index Hashing — the paper's primary contribution (§5, RQ2).

Long p-bit codes are split into ``m`` disjoint substrings; each substring is
indexed in its own table (CSR-sorted, see single_table.py). An exact angular
KNN query walks the full-code tuple sequence (probing.py) in decreasing-sim
order; before emitting the codes at full tuple ``(r1, r2)`` it performs the
substring probes required by Proposition 4:

    T_{r1,r2,m} = { (a, b) : a + b <= floor((r1+r2)/m), a <= r1, b <= r2 }

probed in *every* table. Any code with Hamming tuple <= (r1, r2) — in
particular, exactly (r1, r2) — is guaranteed (pigeonhole) to fall in one of
those buckets, so emission order is exact. Retrieved candidates are verified
once (dedup bitmap) by computing their exact full-code tuple with popcounts.

Counters mirror the paper's cost model (Eq. 13): probes (bucket lookups) and
candidate verifications are the two cost terms.

Batched queries (``knn_batch``) follow the multi-index-hashing serving
shape: queries with identical ``(p, z)`` share one probing-sequence
enumeration (the heap + exact-rational ordering is per-*group*, not
per-query), advance in lockstep over full-code tuples, and verify their
candidate blocks through a pluggable backend — vectorized NumPy popcounts
or the Pallas ``verify_tuples`` kernel (``verify_backend="pallas"``), which
gathers the candidate codes, pads to the kernel block size, and masks the
padding (see kernels/ops.verify_tuples_op).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .enumeration import tuple_bucket_values
from .packing import (
    WORD_DTYPE,
    extract_substring,
    hamming_tuples,
    popcount,
    substring_spans,
)
from .probing import probing_sequence
from .tuples import rhat, sim_value

__all__ = ["AMIHIndex", "AMIHStats", "default_num_tables"]

# Sentinel stored in the per-query ``probed`` set once the query has
# degraded to full verification (every id seen) — no more probing needed.
_SCANNED = ("__scanned__",)


def default_num_tables(p: int, n: int) -> int:
    """Paper §5.2 / §6.2: m ≈ p / log2(n), clamped to [ceil(p/64), p].

    The lower clamp keeps every substring <= 64 bits so bucket indices fit
    an integer word (the paper's tables are likewise word-indexed).
    """
    m_min = (p + 63) // 64
    if n < 2:
        return m_min
    m = int(round(p / max(1.0, math.log2(n))))
    return max(m_min, min(p, m))


@dataclass
class AMIHStats:
    probes: int = 0              # bucket lookups across all tables
    retrieved: int = 0           # ids pulled from buckets (incl. cross-table dups)
    verified: int = 0            # unique candidates tuple-verified
    tuples_processed: int = 0    # full-code tuples traversed
    substring_tuples_probed: int = 0
    max_radius: int = 0
    exceeded_rhat: bool = False
    # The paper (§5) observes that when required probes exceed the dataset
    # size, linear scan is the faster alternative. We make that a guard:
    # once a single substring-tuple's bucket enumeration would cost more
    # than verifying every stored code, the query degrades gracefully to a
    # full verification pass (still exact).
    fell_back_to_scan: bool = False


@dataclass
class _SubTable:
    lo: int
    hi: int
    sorted_vals: np.ndarray = field(repr=False)
    sorted_ids: np.ndarray = field(repr=False)

    @property
    def width(self) -> int:
        return self.hi - self.lo

    def probe(self, bucket_vals: np.ndarray) -> np.ndarray:
        if bucket_vals.size == 0:
            return np.empty(0, dtype=np.int64)
        lo = np.searchsorted(self.sorted_vals, bucket_vals, side="left")
        hi = np.searchsorted(self.sorted_vals, bucket_vals, side="right")
        nz = hi > lo
        if not nz.any():
            return np.empty(0, dtype=np.int64)
        parts = [self.sorted_ids[l:h] for l, h in zip(lo[nz], hi[nz])]
        return np.concatenate(parts)


@dataclass
class _QueryState:
    """Per-query probing state inside a batched search."""

    qi: int                       # row in the query batch
    q_words: np.ndarray
    q_subs: List[int]
    z_subs: List[int]
    seen: np.ndarray
    probed: set
    pending: Dict[Tuple[int, int], List[np.ndarray]]
    out_ids: List[int]
    out_sims: List[float]
    stats: Optional[AMIHStats]
    done: bool = False


@dataclass
class AMIHIndex:
    """Exact angular-KNN index over n packed p-bit codes."""

    p: int
    m: int
    db_words: np.ndarray = field(repr=False)   # (n, W) uint32 — for verification
    tables: List[_SubTable] = field(repr=False, default_factory=list)
    # Candidate-verification backend: "numpy" (vectorized popcounts on host)
    # or "pallas" (kernels/verify_tuples via ops.verify_tuples_op — native
    # on TPU, interpret-mode elsewhere). Both are exact.
    verify_backend: str = "numpy"
    # Materialized probing-sequence prefixes keyed by query popcount z:
    # the heap + exact-rational tuple ordering is query-independent given
    # (p, z), so it is enumerated once per z across all queries and
    # batches. Total memory is bounded by (z+1)(p-z+1) tuples per z.
    _probing_cache: Dict[int, Tuple[List[Tuple[int, int]], Iterator]] = field(
        default_factory=dict, repr=False, compare=False
    )

    # ------------------------------------------------------------- build
    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        m: Optional[int] = None,
        verify_backend: str = "numpy",
    ) -> "AMIHIndex":
        if verify_backend not in ("numpy", "pallas"):
            raise ValueError(f"unknown verify_backend {verify_backend!r}")
        db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        n = db_words.shape[0]
        if m is None:
            m = default_num_tables(p, n)
        if p / m > 64:
            raise ValueError(
                f"m={m} gives substrings wider than 64 bits for p={p}; "
                f"need m >= {(p + 63) // 64}"
            )
        tables = []
        for (lo, hi) in substring_spans(p, m):
            vals = extract_substring(db_words, lo, hi)
            order = np.argsort(vals, kind="stable")
            tables.append(
                _SubTable(
                    lo=lo,
                    hi=hi,
                    sorted_vals=vals[order],
                    sorted_ids=np.arange(n, dtype=np.int64)[order],
                )
            )
        return cls(
            p=p, m=m, db_words=db_words, tables=tables,
            verify_backend=verify_backend,
        )

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    # ------------------------------------------------------------- search
    def knn(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[AMIHStats] = None,
        enumeration_cap: Optional[int] = 2_000_000,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact angular K nearest neighbors of a packed query.

        Returns (ids, sims); deterministic up to ties inside the final
        tuple (all codes of one tuple are exactly equidistant in angle).
        """
        q_words = np.asarray(q_words, dtype=WORD_DTYPE)
        ids, sims = self.knn_batch(
            q_words[None, :], k,
            stats=None if stats is None else [stats],
            enumeration_cap=enumeration_cap,
        )
        return ids[0], sims[0]

    def knn_batch(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[List[AMIHStats]] = None,
        enumeration_cap: Optional[int] = 2_000_000,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact angular KNN for a batch of packed queries: (B, W) -> ids,
        sims each (B, min(k, n)).

        Queries with equal popcount z share one probing-sequence
        enumeration and advance in lockstep; each keeps its own dedup
        bitmap / probed set / pending buckets, so per-query results and
        counters are identical to ``knn`` run query-by-query.
        """
        q_words = np.ascontiguousarray(
            np.atleast_2d(np.asarray(q_words, dtype=WORD_DTYPE))
        )
        B = q_words.shape[0]
        if stats is not None and len(stats) != B:
            raise ValueError(f"stats list has {len(stats)} entries for B={B}")
        k = min(k, self.n)
        out_ids = np.empty((B, k), dtype=np.int64)
        out_sims = np.empty((B, k), dtype=np.float64)
        if k == 0:
            return out_ids, out_sims

        zs = popcount(q_words)
        groups: Dict[int, List[int]] = {}
        for qi in range(B):
            groups.setdefault(int(zs[qi]), []).append(qi)

        for z, qis in groups.items():
            states = [self._make_state(q_words[qi], qi, stats) for qi in qis]
            r_hat = rhat(z)
            for (r1, r2) in self._probing_iter(z):
                active = [s for s in states if not s.done]
                if not active:
                    break
                s_val = sim_value(self.p, z, r1, r2)
                for s in active:
                    if s.stats is not None:
                        s.stats.tuples_processed += 1
                        s.stats.max_radius = max(s.stats.max_radius, r1 + r2)
                        if r1 + r2 > r_hat:
                            s.stats.exceeded_rhat = True
                    self._probe_for_tuple(
                        s.q_words, r1, r2, s.q_subs, s.z_subs, s.probed,
                        s.seen, s.pending, s.stats, enumeration_cap,
                    )
                    hits = s.pending.pop((r1, r2), None)
                    if hits:
                        ids = np.sort(np.concatenate(hits))
                        take = min(ids.size, k - len(s.out_ids))
                        s.out_ids.extend(ids[:take].tolist())
                        s.out_sims.extend([s_val] * take)
                        if len(s.out_ids) >= k:
                            s.done = True
            for s in states:
                out_ids[s.qi] = s.out_ids
                out_sims[s.qi] = s.out_sims
        return out_ids, out_sims

    def _probing_iter(self, z: int) -> Iterator[Tuple[int, int]]:
        """Probing sequence for popcount z, served from the per-index
        cache: already-materialized tuples replay from the prefix list;
        going deeper pulls the underlying generator and extends it."""
        entry = self._probing_cache.get(z)
        if entry is None:
            entry = ([], probing_sequence(self.p, z))
            self._probing_cache[z] = entry
        prefix, gen = entry
        i = 0
        while True:
            if i >= len(prefix):
                try:
                    prefix.append(next(gen))
                except StopIteration:
                    return
            yield prefix[i]
            i += 1

    def _make_state(
        self,
        q_words: np.ndarray,
        qi: int,
        stats: Optional[List[AMIHStats]],
    ) -> _QueryState:
        q_subs = [
            int(extract_substring(q_words[None, :], t.lo, t.hi)[0])
            for t in self.tables
        ]
        return _QueryState(
            qi=qi,
            q_words=q_words,
            q_subs=q_subs,
            z_subs=[int(v).bit_count() for v in q_subs],
            seen=np.zeros(self.n, dtype=bool),
            probed=set(),
            pending={},
            out_ids=[],
            out_sims=[],
            stats=None if stats is None else stats[qi],
        )

    def search_radius(
        self,
        q_words: np.ndarray,
        r1: int,
        r2: int,
        stats: Optional[AMIHStats] = None,
        enumeration_cap: Optional[int] = 2_000_000,
    ) -> np.ndarray:
        """The (r1, r2)-near neighbor problem (Def. 4): all codes with
        Hamming tuple <= (r1, r2) componentwise. Returns sorted ids."""
        q_words = np.asarray(q_words, dtype=WORD_DTYPE)
        q_subs = [
            int(extract_substring(q_words[None, :], t.lo, t.hi)[0])
            for t in self.tables
        ]
        z_subs = [int(v).bit_count() for v in q_subs]
        seen = np.zeros(self.n, dtype=bool)
        pending: Dict[Tuple[int, int], List[np.ndarray]] = {}
        self._probe_for_tuple(
            q_words, r1, r2, q_subs, z_subs, set(), seen, pending, stats,
            enumeration_cap,
        )
        matches = [
            np.concatenate(v)
            for (e1, e2), v in pending.items()
            if e1 <= r1 and e2 <= r2
        ]
        if not matches:
            return np.empty(0, dtype=np.int64)
        return np.sort(np.concatenate(matches))

    # ------------------------------------------------------------ private
    def _probe_for_tuple(
        self,
        q_words: np.ndarray,
        r1: int,
        r2: int,
        q_subs: List[int],
        z_subs: List[int],
        probed: set,
        seen: np.ndarray,
        pending: Dict[Tuple[int, int], List[np.ndarray]],
        stats: Optional[AMIHStats],
        enumeration_cap: Optional[int],
    ) -> None:
        """Run all not-yet-done probes required by T_{r1,r2,m} (Prop. 4),
        verify new candidates, and bucket them by exact full tuple.

        Cost guard: if a single substring-tuple enumeration would probe more
        buckets than there are stored codes (or than ``enumeration_cap``),
        bucket probing has lost to exhaustive verification — we verify every
        not-yet-seen code instead (exact; the paper's §5 observation that
        "linear scan is a faster alternative" past that point). The
        ``_SCANNED`` sentinel in ``probed`` short-circuits later tuples.
        """
        if _SCANNED in probed:
            return
        rsub = (r1 + r2) // self.m
        new_ids: List[np.ndarray] = []
        todo = [
            (s, a, b)
            for s, table in enumerate(self.tables)
            for a in range(min(r1, z_subs[s], rsub) + 1)
            for b in range(min(r2, table.width - z_subs[s], rsub - a) + 1)
            if (s, a, b) not in probed
        ]
        for (s, a, b) in todo:
            probed.add((s, a, b))
            table = self.tables[s]
            w_s, z_s = table.width, z_subs[s]
            n_buckets = math.comb(z_s, a) * math.comb(w_s - z_s, b)
            cap = min(enumeration_cap or self.n, max(self.n, 1))
            if n_buckets > cap:
                probed.add(_SCANNED)
                fresh = np.flatnonzero(~seen)
                seen[:] = True
                if fresh.size:
                    new_ids.append(fresh)
                if stats is not None:
                    stats.fell_back_to_scan = True
                    stats.retrieved += fresh.size
                break
            buckets = tuple_bucket_values(q_subs[s], w_s, z_s, a, b, cap=None)
            if stats is not None:
                stats.substring_tuples_probed += 1
                stats.probes += len(buckets)
            ids = table.probe(buckets)
            if stats is not None:
                stats.retrieved += len(ids)
            if ids.size:
                fresh = ids[~seen[ids]]
                if fresh.size:
                    seen[fresh] = True
                    new_ids.append(fresh)
        if new_ids:
            cand = np.concatenate(new_ids)
            if stats is not None:
                stats.verified += cand.size
            # exact full-code tuples for all new candidates, vectorized
            e1, e2 = self._verify_candidates(q_words, cand)
            for t in np.unique(np.stack([e1, e2], axis=1), axis=0):
                mask = (e1 == t[0]) & (e2 == t[1])
                pending.setdefault((int(t[0]), int(t[1])), []).append(
                    cand[mask]
                )

    def _verify_candidates(
        self, q_words: np.ndarray, cand: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact full-code tuples of a gathered candidate block.

        "numpy": host popcounts (hamming_tuples). "pallas": the
        verify_tuples kernel via kernels/ops.verify_tuples_op, which pads
        the gathered block to the kernel block size and masks the padding.
        Both return identical int64 (r10, r01); jax is imported lazily so
        the core package stays NumPy-only unless the knob is turned.
        """
        if self.verify_backend == "pallas":
            import jax.numpy as jnp

            from ..kernels.ops import verify_tuples_op

            r10, r01 = verify_tuples_op(
                jnp.asarray(q_words),
                jnp.asarray(self.db_words[cand]),
                use_pallas=True,
            )
            return (
                np.asarray(r10).astype(np.int64),
                np.asarray(r01).astype(np.int64),
            )
        return hamming_tuples(q_words, self.db_words[cand])
