"""Unified batched SearchEngine: one query API over every exact-KNN
algorithm in the repo.

Every caller (serving, benchmarks, examples, tests) talks to one surface:

    engine = make_engine("amih", db_words, p, verify_backend="pallas")
    ids, sims, stats = engine.knn_batch(q_words, k)   # q_words: (B, W)

Backends (registry below):

  - "linear_scan"  — exhaustive Eq. 3 scan, batched over queries with
                     chunked popcounts (the paper's comparator);
                     ``compute_backend="pallas"`` routes scoring through
                     the streaming device top-K (kernels/ops.scan_topk)
                     over a device-resident DB, with an exact float64
                     host rerank of the preselected candidates.
  - "single_table" — one CSR-sorted table probed in the paper's tuple
                     order (§4); practical for p <= 64.
  - "amih"         — angular multi-index hashing (§5): probing-sequence
                     sharing across same-z queries and grouped candidate
                     verification — one vectorized NumPy popcount or one
                     Pallas ``verify_tuples_grouped`` launch per
                     (z-group, tuple-step) on a padded (B_g, C_max, W)
                     layout (``verify_backend="pallas"``).

All three are EXACT: ``knn_batch`` returns, for every row, results whose
sims match per-query ``linear_scan_knn`` bit-for-bit (up to ties inside
one Hamming tuple — equal sims by construction). ``EngineStats`` carries
per-query counter objects plus aggregated totals, the serving-side cost
accounting of the paper's Eq. 13.
"""

from __future__ import annotations

import abc
from collections import OrderedDict
from dataclasses import dataclass, field, fields as dc_fields, replace
from typing import Any, ClassVar, Dict, List, Optional, Tuple

import numpy as np

from ..obs import trace as _obs
from .amih import AMIHIndex, AMIHStats
from .enumeration import EnumerationCapExceeded
from .linear_scan import (
    sims_against_db,
    sims_batch_against_db,
    sims_for_ids,
    topk_from_sims,
)
from .packing import WORD_DTYPE, n_words, popcount
from .single_table import SearchStats, SingleTableIndex

__all__ = [
    "ENGINES",
    "EngineStats",
    "SearchEngine",
    "available_backends",
    "make_engine",
    "probe_cache_snapshot",
    "register_engine",
]


@dataclass
class EngineStats:
    """Batched-search accounting: one stats object per query row plus
    lazily-aggregated totals.

    ``per_query`` holds one counter object per query row (AMIHStats or
    SearchStats — every backend provides them); ``aggregate()`` sums
    every numeric counter across queries (bools count occurrences), so
    e.g. ``stats.aggregate()["verified"]`` is the batch's total candidate
    verifications. Counters that are per-query maxima (``max_radius``)
    aggregate with max, not sum.

    Sharded backends additionally fill ``shards`` and ``per_shard`` (one
    dict per shard: rows held, candidates contributed/verified, device
    launches issued, and ``"device"`` — the placement device the shard's
    codes live on and its verification ran on) — the serving-side view
    of where a batch's work landed. The cross-host cluster engine
    (repro.cluster) adds ``per_host``: one dict per worker host
    aggregating its rows, shard count, summed launch/probe counters,
    its own ``per_shard``/``cache_info`` sections, and RPC timing — the
    same attribution one level up, so serving dashboards stay honest
    about WHICH HOST work ran on, not just which device. ``cache_hits`` counts query rows
    answered from the engine's hot-query cache without any probing
    (AMIHEngine's LRU). ``cache_info`` snapshots the process-wide shared
    caches after the batch: the (p, z) probing-sequence cache and — on
    the device probe path — the device schedule cache, each with
    occupancy plus lifetime hit/miss counters (see
    ``probe_cache_snapshot``); empty for backends that touch neither.

    Streaming serving (repro.pipeline.stream) fills the queue-side
    counters: ``queue_depth`` is the number of queries still waiting
    behind the batch step this stats object belongs to, and
    ``latency_ms`` holds rolling answered-query latency percentiles
    ({"p50": ..., "p99": ..., "mean": ..., "count": ...}); both stay at
    their defaults for direct ``knn_batch`` calls.
    """

    backend: str
    queries: int = 0
    per_query: List[Optional[object]] = field(default_factory=list)
    shards: int = 0
    per_shard: List[Dict[str, int]] = field(default_factory=list)
    per_host: List[Dict[str, object]] = field(default_factory=list)
    cache_hits: int = 0
    cache_info: Dict[str, int] = field(default_factory=dict)
    queue_depth: int = 0
    latency_ms: Dict[str, float] = field(default_factory=dict)

    _MAX_COUNTERS = frozenset({"max_radius"})

    def aggregate(self) -> Dict[str, int]:
        totals: Dict[str, int] = {}
        for s in self.per_query:
            if s is None:
                continue
            for f in dc_fields(s):
                v = getattr(s, f.name)
                if not isinstance(v, (bool, int, np.bool_, np.integer)):
                    continue
                if f.name in self._MAX_COUNTERS:
                    totals[f.name] = max(totals.get(f.name, 0), int(v))
                else:
                    totals[f.name] = totals.get(f.name, 0) + int(v)
        return totals

    def total(self, counter: str) -> int:
        return self.aggregate().get(counter, 0)


class SearchEngine(abc.ABC):
    """Exact batched angular-KNN engine over packed binary codes.

    Subclasses register under ``name`` and implement ``build`` (index
    construction from a packed (n, W) code array) and ``knn_batch``.
    """

    name: ClassVar[str]

    #: the Tracer handed to ``make_engine(..., tracer=...)``, if any —
    #: kept on the engine so callers can drain/export its spans.
    tracer = None

    @classmethod
    @abc.abstractmethod
    def build(
        cls, db_words: np.ndarray, p: int, **cfg: Any
    ) -> "SearchEngine":
        ...

    @abc.abstractmethod
    def knn_batch(
        self, q_words: np.ndarray, k: int
    ) -> Tuple[np.ndarray, np.ndarray, EngineStats]:
        """Exact batched angular KNN: (B, W) packed queries ->
        (ids (B, k'), sims (B, k'), stats) with k' = min(k, n). A 1-D
        (W,) query is treated as B=1.

        Contract every backend honors:

          - ids are global DB row indices (int64); sims are the exact
            float64 Eq. 3 cosines of those rows — bit-identical to
            per-query ``linear_scan_knn`` up to ties inside one Hamming
            tuple (codes of equal tuple are exactly equidistant; any
            order among them is correct).
          - rows are sorted by descending sim, ascending id within a
            tie, and never contain duplicates.
          - ``stats`` is an ``EngineStats`` with one per-query counter
            object per row (AMIHStats / SearchStats); sharded backends
            also fill the per-shard view (rows, candidates, launches,
            placement device).
        """
        ...

    # ------------------------------------------------------------ helpers
    @property
    @abc.abstractmethod
    def n(self) -> int:
        ...

    def _check_queries(self, q_words: np.ndarray, p: int) -> np.ndarray:
        q = np.atleast_2d(np.asarray(q_words, dtype=WORD_DTYPE))
        if q.ndim != 2 or q.shape[1] != n_words(p):
            raise ValueError(
                f"queries must be (B, {n_words(p)}) packed words for "
                f"p={p}; got shape {np.asarray(q_words).shape}"
            )
        return np.ascontiguousarray(q)


def probe_cache_snapshot() -> Dict[str, int]:
    """Occupancy + lifetime hit/miss counters of the process-wide probing
    caches: the shared (p, z) sequence cache always, plus the device
    schedule/stack cache when the device probe path has been imported.
    Engines stamp this into ``EngineStats.cache_info`` per batch, so the
    benchmark rows can report cache effectiveness per cell."""
    from .probing import _cache_stats

    out: Dict[str, int] = dict(_cache_stats())
    import sys

    mod = sys.modules.get(__package__ + ".probe_device")
    if mod is not None:   # only if already imported: no jax import here
        out.update(mod.schedule_cache_stats())
    return out


ENGINES: Dict[str, type] = {}


def register_engine(cls: type) -> type:
    ENGINES[cls.name] = cls
    return cls


def available_backends() -> List[str]:
    return sorted(ENGINES)


def make_engine(
    backend: str, db_words: np.ndarray, p: int, **cfg: Any
) -> SearchEngine:
    """Build a search engine by backend name (see ``available_backends``).

    ``db_words`` is the packed (n, W) uint32 code array (``pack_bits``),
    ``p`` the code length in bits. ``cfg`` is forwarded to the backend's
    ``build``; unknown keys raise ``TypeError``. The registered backends
    and their main knobs (full details in docs/tuning.md):

      - "linear_scan"   — exhaustive baseline.
                          ``compute_backend`` ("numpy" | "pallas"),
                          ``chunk``.
      - "single_table"  — one CSR table (paper §4, p <= 64).
                          ``enumeration_cap``.
      - "amih"          — angular multi-index hashing (paper §5).
                          ``m``, ``verify_backend`` ("numpy" | "pallas"),
                          ``probe_backend`` ("host" | "device" — the
                          fused probing walk: ONE launch for the whole
                          batch, every z-group stacked into it;
                          ``probe_fused=False`` restores one launch per
                          z-group), ``probe_stream_cap``,
                          ``enumeration_cap``, ``query_cache_size``,
                          ``overlap_verify``.
      - "sharded_scan"  — row-sharded exhaustive scan (repro.shard).
                          ``mesh`` | ``num_shards`` | ``plan``,
                          ``shard_axes``, ``devices``, ``chunk``.
      - "sharded_amih"  — one shard-local AMIH index per slice, each
                          placed on its own device; with
                          ``probe_backend="device"`` the shards on each
                          device fuse into ONE launch per device,
                          dispatched to all devices without blocking.
                          sharding knobs as above plus ``m``,
                          ``verify_backend``, ``probe_backend``,
                          ``probe_fused``, ``enumeration_cap``,
                          ``probe_workers``, ``probe_mode``,
                          ``prime_bound``.
      - "cluster"       — cross-host coordinator over worker processes
                          (repro.cluster): each worker runs an
                          ``inner_backend`` sharded engine over its
                          host-partitioned slice; the monotone k-th
                          cosine floor broadcasts between hosts.
                          ``hosts`` | ``workers`` (address list),
                          ``inner_backend``, ``num_shards``,
                          ``prime_bound``, ``request_timeout``; extra
                          knobs forward to every worker's engine.

    Every backend answers the same batched ``knn_batch(q_words, k)`` and
    returns results bit-identical to ``linear_scan_knn`` (up to ties
    inside one Hamming tuple). The sharded backends live in
    ``repro.shard`` and are registered on first use, so numpy-only
    callers of the host backends never pay the jax import. Engines that
    hold workers ("amih" with ``overlap_verify``, "sharded_amih" with
    ``probe_workers``) expose ``close()``; GC closes them too.

    ``tracer=`` (a ``repro.obs.Tracer``) threads end-to-end tracing
    through: it is installed as the process tracer — the instrumentation
    sites at every layer read one process-wide tracer, since kernel
    launch sites cannot know which engine they serve — and attached to
    the returned engine as ``engine.tracer`` for draining/export.
    Tracing is off unless the tracer is enabled; spans observe, never
    reorder, so results are bit-identical either way.
    """
    tracer = cfg.pop("tracer", None)
    if tracer is not None:
        from ..obs import trace as _obs_trace

        _obs_trace.set_tracer(tracer)
    cls = ENGINES.get(backend)
    if cls is None and backend.startswith("sharded"):
        try:
            from .. import shard  # noqa: F401  (registers them)
        except ImportError:
            pass  # no jax on this host: fall through to the ValueError
        cls = ENGINES.get(backend)
    if cls is None and backend == "cluster":
        from .. import cluster  # noqa: F401  (registers ClusterEngine)

        cls = ENGINES.get(backend)
    if cls is None:
        raise ValueError(
            f"unknown search backend {backend!r}; "
            f"available: {available_backends()}"
        )
    eng = cls.build(db_words, p, **cfg)
    eng.tracer = tracer
    return eng


@register_engine
class LinearScanEngine(SearchEngine):
    """Exhaustive baseline: batched Eq. 3 sims + per-row deterministic
    top-k (identical selection code path to ``linear_scan_knn``).

    ``compute_backend`` selects the scoring path:

      - "numpy"  — chunked host popcounts (default; no jax dependency).
      - "pallas" — the streaming device top-K ``kernels/ops.scan_topk``
        (hamming_scan kernel on TPU, the identical-math XLA reference
        elsewhere) over a device-resident copy of the DB uploaded once.
        The device preselects ``k + slack`` candidates in float32; their
        sims are then recomputed on host in float64 (``sims_for_ids``)
        and re-ranked, so the returned (ids, sims) stay bit-identical to
        ``linear_scan_knn``. Both ``k`` (fetch size) and the batch dim are
        padded to power-of-two buckets so the jitted top-K retraces
        O(log) times per axis at most.

    This engine is also AMIH's degrade-to-scan comparator, so the kernel
    path keeps the exhaustive fallback regime fast on device-rich hosts.
    """

    name = "linear_scan"

    # Device preselect slack: candidates fetched beyond k so float32
    # rounding at the selection boundary cannot evict a true top-k item.
    # Distinct Eq. 3 sims differ by >~1/p^3 (integer cross-multiplication
    # bound), which stays well above float32 resolution for p <= ~192;
    # beyond that, sims can collapse in float32, so the slack grows with p
    # to keep room for a whole collapsed boundary population. Candidates
    # with *identical* float64 sims are genuine ties (any k of them is a
    # correct answer), so only distinct-sim collisions matter.
    @property
    def _topk_slack(self) -> int:
        return 16 + max(0, self.p - 128) // 4

    def __init__(
        self,
        db_words: np.ndarray,
        p: int,
        chunk: int,
        compute_backend: str = "numpy",
    ):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.chunk = chunk
        self.compute_backend = compute_backend
        self._db_dev = None   # device-resident codes, uploaded on first use

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        chunk: int = 1 << 15,
        compute_backend: str = "numpy",
        **cfg: Any,
    ) -> "LinearScanEngine":
        if cfg:
            raise TypeError(f"unknown linear_scan options: {sorted(cfg)}")
        if compute_backend not in ("numpy", "pallas"):
            raise ValueError(
                f"unknown compute_backend {compute_backend!r}"
            )
        return cls(db_words, p, chunk, compute_backend)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    # Cap on live sims-matrix elements: query rows are processed in
    # groups of max(1, _SIMS_BUDGET // n) so peak scratch stays ~64 MB
    # float64 regardless of B and N, while each row is still computed
    # and top-k'd whole — bit-identical to per-query linear_scan_knn.
    _SIMS_BUDGET = 1 << 23

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        with _obs.current().span("engine.knn_batch", cat="engine",
                                 backend=self.name, B=B, k=k_eff):
            return self._knn_batch_traced(q, B, k_eff)

    def _knn_batch_traced(self, q, B, k_eff):
        if self.compute_backend == "pallas" and k_eff > 0:
            ids_out, sims_out = self._knn_batch_device(q, k_eff)
        else:
            ids_out = np.empty((B, k_eff), dtype=np.int64)
            sims_out = np.empty((B, k_eff), dtype=np.float64)
            group = max(1, self._SIMS_BUDGET // max(self.n, 1))
            for lo in range(0, B, group):
                sims = sims_batch_against_db(
                    q[lo : lo + group], self.db_words, chunk=self.chunk
                )
                for i in range(sims.shape[0]):
                    ids_out[lo + i], sims_out[lo + i] = topk_from_sims(
                        sims[i], k_eff
                    )
        # retrieved = codes scored per query: the whole DB, exhaustively.
        stats = EngineStats(
            backend=self.name, queries=B,
            per_query=[SearchStats(retrieved=self.n) for _ in range(B)],
        )
        return ids_out, sims_out, stats

    def _knn_batch_device(self, q, k_eff):
        """Device streaming top-K preselect + exact float64 host rerank.

        Both the fetch size and the batch dim are padded to power-of-two
        buckets (zero query rows score 0.0 everywhere and are sliced off),
        so the jitted ``scan_topk`` retraces O(log) times per axis instead
        of once per distinct (B, k).
        """
        import jax.numpy as jnp

        from ..kernels import ops

        if self._db_dev is None:
            self._db_dev = jnp.asarray(self.db_words)
        B = q.shape[0]
        k_fetch = min(
            self.n, ops.pad_bucket(k_eff + self._topk_slack, minimum=8)
        )
        Bp = ops.pad_bucket(B, minimum=8)
        qp = np.zeros((Bp, q.shape[1]), dtype=q.dtype)
        qp[:B] = q
        _, ids32 = ops.scan_topk(
            jnp.asarray(qp), self._db_dev, k_fetch, use_pallas=ops.on_tpu()
        )
        fetched = np.asarray(ids32)[:B].astype(np.int64)   # (B, k_fetch)
        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        for i in range(B):
            cand = fetched[i]
            sub = sims_for_ids(q[i], self.db_words, cand)  # exact float64
            order = np.lexsort((cand, -sub))[:k_eff]
            ids_out[i] = cand[order]
            sims_out[i] = sub[order]
        return ids_out, sims_out


@register_engine
class SingleTableEngine(SearchEngine):
    """Single hash table (paper §4); exact for p <= 64.

    The raw index has no cost guard: on sparse occupancy a single tuple's
    bucket enumeration is C(z, r1)*C(p-z, r2) — combinatorial. The engine
    caps it (default ``max(8n, 16384)``) and degrades the affected query
    to an exact linear scan (the paper's §5 observation), flagged in
    ``SearchStats.fell_back_to_scan``. Counters accumulated before the
    fallback are kept — they are probes actually performed.
    """

    name = "single_table"

    def __init__(self, index: SingleTableIndex, db_words, enumeration_cap):
        self.index = index
        self.p = index.p
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.enumeration_cap = enumeration_cap

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        enumeration_cap: Optional[int] = None,
        **cfg: Any,
    ) -> "SingleTableEngine":
        if cfg:
            raise TypeError(f"unknown single_table options: {sorted(cfg)}")
        n = np.asarray(db_words).shape[0]
        if enumeration_cap is None:
            enumeration_cap = max(8 * n, 1 << 14)
        return cls(SingleTableIndex.build(db_words, p), db_words,
                   enumeration_cap)

    @property
    def n(self) -> int:
        return self.index.n

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        with _obs.current().span("engine.knn_batch", cat="engine",
                                 backend=self.name, B=B, k=k_eff):
            return self._knn_batch_traced(q, B, k_eff)

    def _knn_batch_traced(self, q, B, k_eff):
        zs = popcount(q)
        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        per_query: List[SearchStats] = []
        for i in range(B):
            st = SearchStats()
            if zs[i] == 0:
                # Zero-norm query: cosine is undefined, every code scores
                # exactly 0.0, so any k ids are a correct answer — and the
                # table would enumerate C(p, r2) buckets per tuple trying
                # to find them. Emit the deterministic tie order directly.
                ids_out[i] = np.arange(k_eff, dtype=np.int64)
                sims_out[i] = 0.0
            else:
                try:
                    ids_out[i], sims_out[i] = self.index.knn(
                        q[i], k_eff, stats=st,
                        enumeration_cap=self.enumeration_cap,
                    )
                except EnumerationCapExceeded:
                    # probing has lost to exhaustive verification for
                    # this query.
                    st.fell_back_to_scan = True
                    ids_out[i], sims_out[i] = topk_from_sims(
                        sims_against_db(q[i], self.db_words), k_eff
                    )
            per_query.append(st)
        return ids_out, sims_out, EngineStats(
            backend=self.name, queries=B, per_query=per_query
        )


@register_engine
class AMIHEngine(SearchEngine):
    """Angular multi-index hashing (paper §5): batch-aware probing with
    per-(p, z) probing-sequence sharing and grouped NumPy/Pallas
    verification.

    Each tuple step verifies the fresh candidates of ALL same-z queries in
    one backend call: ``verify_backend="numpy"`` is a single vectorized
    host popcount over the concatenated ragged blocks;
    ``verify_backend="pallas"`` gathers them into a padded
    (B_g, C_max, W) device layout (power-of-two buckets -> bounded jit
    cache) and issues one ``verify_tuples_grouped`` launch per (z-group,
    tuple-step) against the device-resident DB uploaded at build
    (``index.verify_launches`` counts dispatches).

    ``enumeration_cap`` bounds a single substring-tuple's bucket
    enumeration before the query degrades to an exact full scan; the
    default scales with the DB like SingleTableEngine's
    (``max(8n, 16384)``) instead of a fixed constant.

    Hot-query cache: serving traffic repeats query codes (hot documents,
    retried requests), and probing + verification for a repeated packed
    code is fully deterministic — so ``knn_batch`` memoizes per
    (code bytes, k) in a bounded LRU (``query_cache_size`` entries,
    0 disables). Hits skip probing entirely and are counted in
    ``EngineStats.cache_hits`` / ``engine.cache_hits``; the cached stats
    counters are replayed (copied) so per-query accounting stays
    identical to an uncached run.

    ``overlap_verify=True`` pipelines each z-group's tuple loop one step
    deep (repro.pipeline.VerifyOverlap): tuple step t's grouped
    verification runs on a worker thread / the device while the host
    probes step t+1. Results are bit-identical to the sequential loop;
    probe-side counters of a query that finishes at step t may include
    one extra (discarded) probing step — see pipeline/overlap.py.
    """

    name = "amih"

    def __init__(self, index: AMIHIndex, enumeration_cap,
                 query_cache_size: int = 256, overlap_verify: bool = False):
        self.index = index
        self.p = index.p
        self.enumeration_cap = enumeration_cap
        self.query_cache_size = query_cache_size
        self.overlap_verify = overlap_verify
        self._overlap = None   # VerifyOverlap, created on first use
        # (q_words bytes, k) -> (ids row, sims row, AMIHStats); ordered
        # oldest-first so popitem(last=False) evicts the LRU entry.
        self._query_cache: "OrderedDict[Tuple[bytes, int], tuple]" = (
            OrderedDict()
        )
        self.cache_hits = 0

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        m: Optional[int] = None,
        verify_backend: str = "numpy",
        enumeration_cap: Optional[int] = None,
        query_cache_size: int = 256,
        overlap_verify: bool = False,
        probe_backend: str = "host",
        probe_stream_cap: int = 1 << 16,
        probe_fused: bool = True,
        **cfg: Any,
    ) -> "AMIHEngine":
        if cfg:
            raise TypeError(f"unknown amih options: {sorted(cfg)}")
        n = np.asarray(db_words).shape[0]
        if enumeration_cap is None:
            enumeration_cap = max(8 * n, 1 << 14)
        index = AMIHIndex.build(
            db_words, p, m=m, verify_backend=verify_backend,
            probe_backend=probe_backend,
            probe_stream_cap=probe_stream_cap,
            probe_fused=probe_fused,
        )
        return cls(index, enumeration_cap, query_cache_size, overlap_verify)

    def _overlap_driver(self):
        """The engine's VerifyOverlap (one worker, lazily created)."""
        if self._overlap is None and self.overlap_verify:
            from ..pipeline.overlap import VerifyOverlap

            self._overlap = VerifyOverlap()
        return self._overlap

    def close(self) -> None:
        """Release the overlap worker thread (idempotent); engines are
        also closed on GC so sweeps that build many pipelined engines
        don't accumulate idle verify workers."""
        overlap, self._overlap = self._overlap, None
        if overlap is not None:
            overlap.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass   # interpreter shutdown: executors may already be gone

    @property
    def n(self) -> int:
        return self.index.n

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        with _obs.current().span("engine.knn_batch", cat="engine",
                                 backend=self.name, B=B, k=k_eff):
            return self._knn_batch_traced(q, B, k_eff)

    def _knn_batch_traced(self, q, B, k_eff):
        cache = self._query_cache if self.query_cache_size > 0 else None

        # Split rows into cache hits and (deduplicated) misses. Duplicate
        # rows inside one batch do identical probing work, so one compute
        # serves them all — counters are copies of the computed row's,
        # exactly what per-row computation would have produced.
        per_query: List[Optional[AMIHStats]] = [None] * B
        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        hits = 0
        miss_keys: Dict[bytes, List[int]] = {}
        for i in range(B):
            key = q[i].tobytes()
            cached = cache.get((key, k_eff)) if cache is not None else None
            if cached is not None:
                cache.move_to_end((key, k_eff))
                c_ids, c_sims, c_stats = cached
                ids_out[i], sims_out[i] = c_ids, c_sims
                per_query[i] = replace(c_stats)
                hits += 1
            else:
                miss_keys.setdefault(key, []).append(i)

        if miss_keys:
            rows = [idxs[0] for idxs in miss_keys.values()]
            miss_stats = [AMIHStats() for _ in rows]
            m_ids, m_sims = self.index.knn_batch(
                q[rows], k_eff, stats=miss_stats,
                enumeration_cap=self.enumeration_cap,
                overlap=self._overlap_driver(),
            )
            for j, (key, idxs) in enumerate(miss_keys.items()):
                for i in idxs:
                    ids_out[i], sims_out[i] = m_ids[j], m_sims[j]
                    per_query[i] = replace(miss_stats[j])
                if cache is not None:
                    cache[(key, k_eff)] = (
                        m_ids[j].copy(), m_sims[j].copy(), miss_stats[j]
                    )
                    while len(cache) > self.query_cache_size:
                        cache.popitem(last=False)

        self.cache_hits += hits
        return ids_out, sims_out, EngineStats(
            backend=self.name, queries=B, per_query=per_query,
            cache_hits=hits, cache_info=probe_cache_snapshot(),
        )
