"""Single-hash-table exact angular KNN (paper §4, RQ1).

The hash "table" is stored TPU/cache-friendly as a CSR-style sorted array:
codes sorted by integer value with their ids. Probing a bucket is a binary
search returning a contiguous id range — batched over all bucket indices of
one tuple with ``np.searchsorted``. This is the storage adaptation described
in DESIGN.md §3; the probing *order* is exactly the paper's.

Practical only for short codes (p <= ~32, the paper's own observation);
AMIH (amih.py) is the long-code solution.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from .enumeration import tuple_bucket_values
from .packing import WORD_DTYPE, codes_to_ints, popcount
from .probing import probing_sequence
from .tuples import sim_value

__all__ = ["SingleTableIndex", "SearchStats"]


@dataclass
class SearchStats:
    """Counters mirroring the paper's cost accounting."""

    probes: int = 0            # bucket lookups performed
    retrieved: int = 0         # ids pulled out of buckets (incl. duplicates)
    tuples_processed: int = 0  # Hamming-distance tuples traversed
    max_radius: int = 0        # largest Hamming distance reached
    exceeded_rhat: bool = False
    # Set by SingleTableEngine when a tuple's bucket enumeration exceeded
    # the cap and the query degraded to an exact linear scan (the paper's
    # §5 observation, applied to the single table).
    fell_back_to_scan: bool = False


@dataclass
class SingleTableIndex:
    """Exact angular KNN over one table of p-bit codes (p <= 64)."""

    p: int
    sorted_vals: np.ndarray = field(repr=False)   # (n,) uint64, ascending
    sorted_ids: np.ndarray = field(repr=False)    # (n,) int64

    @classmethod
    def build(cls, db_words: np.ndarray, p: int) -> "SingleTableIndex":
        if p > 64:
            raise ValueError("SingleTableIndex supports p <= 64; use AMIH")
        vals = codes_to_ints(db_words, p)
        order = np.argsort(vals, kind="stable")
        return cls(p=p, sorted_vals=vals[order], sorted_ids=np.arange(len(vals))[order])

    @property
    def n(self) -> int:
        return self.sorted_vals.shape[0]

    def probe_buckets(self, bucket_vals: np.ndarray) -> np.ndarray:
        """ids stored in any of the given buckets (batched binary search)."""
        if bucket_vals.size == 0:
            return np.empty(0, dtype=np.int64)
        lo = np.searchsorted(self.sorted_vals, bucket_vals, side="left")
        hi = np.searchsorted(self.sorted_vals, bucket_vals, side="right")
        counts = hi - lo
        nz = counts > 0
        if not nz.any():
            return np.empty(0, dtype=np.int64)
        parts = [self.sorted_ids[l:h] for l, h in zip(lo[nz], hi[nz])]
        return np.concatenate(parts)

    def knn(
        self,
        q_words: np.ndarray,
        k: int,
        stats: Optional[SearchStats] = None,
        enumeration_cap: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact angular KNN: probe buckets tuple-by-tuple in sim order.

        Returns (ids, sims) with len == min(k, n); deterministic up to ties
        within the final tuple (codes in one tuple are exactly equidistant).
        """
        from .tuples import rhat  # local import to keep module deps acyclic

        q_words = np.asarray(q_words, dtype=WORD_DTYPE)
        q_val = int(codes_to_ints(q_words[None, :], self.p)[0])
        z = int(popcount(q_words[None, :])[0])
        k = min(k, self.n)
        out_ids: list = []
        out_sims: list = []
        r_hat = rhat(z)
        for (r1, r2) in probing_sequence(self.p, z):
            if stats is not None:
                stats.tuples_processed += 1
                stats.max_radius = max(stats.max_radius, r1 + r2)
                if r1 + r2 > r_hat:
                    stats.exceeded_rhat = True
            buckets = tuple_bucket_values(
                q_val, self.p, z, r1, r2, cap=enumeration_cap
            )
            if stats is not None:
                stats.probes += len(buckets)
            ids = self.probe_buckets(buckets)
            if stats is not None:
                stats.retrieved += len(ids)
            if ids.size:
                s = sim_value(self.p, z, r1, r2)
                take = min(ids.size, k - len(out_ids))
                ids_sorted = np.sort(ids)  # deterministic tie order
                out_ids.extend(ids_sorted[:take].tolist())
                out_sims.extend([s] * take)
            if len(out_ids) >= k:
                break
        return np.asarray(out_ids, dtype=np.int64), np.asarray(out_sims)
