"""Hamming-distance-tuple arithmetic (paper §3–§4, Eq. 3, Props 1–2).

A tuple ``(r1, r2)`` relative to a query with ``z = ||q||_1`` ones out of
``p`` bits describes every code with exactly ``r1`` bits flipped 1->0 and
``r2`` bits flipped 0->1. All such codes share one cosine similarity
(Eq. 3):

    sim = (z - r1) / (sqrt(z) * sqrt(z - r1 + r2))

Ordering tuples by sim is the paper's core primitive. Floating point is
avoided for *comparisons*: since sim >= 0 on the valid domain, ordering by
sim equals ordering by

    sim^2 = (z - r1)^2 / (z * (z - r1 + r2))

which is an exact rational in small integers -> exact cross-multiplication.
"""

from __future__ import annotations

import math
from fractions import Fraction

__all__ = [
    "sim_value",
    "sim_squared_fraction",
    "sim_compare",
    "is_valid_tuple",
    "tuple_count",
    "rhat",
    "all_valid_tuples",
]


def is_valid_tuple(p: int, z: int, r1: int, r2: int) -> bool:
    """A tuple is valid iff 0 <= r1 <= z and 0 <= r2 <= p - z."""
    return 0 <= r1 <= z and 0 <= r2 <= p - z


def sim_value(p: int, z: int, r1: int, r2: int) -> float:
    """Cosine similarity for a tuple (Eq. 3). Degenerate cases -> 0.0.

    Degenerate: z == 0 (query is the zero vector) or z - r1 + r2 == 0
    (the *code* is the zero vector). Cosine is undefined there; we define
    it as 0.0 so such codes sort last, matching the convention that the
    zero vector is maximally dissimilar.
    """
    if z == 0:
        return 0.0
    norm_b_sq = z - r1 + r2
    if norm_b_sq == 0:
        return 0.0
    return (z - r1) / (math.sqrt(z) * math.sqrt(norm_b_sq))


def sim_squared_fraction(p: int, z: int, r1: int, r2: int) -> Fraction:
    """Exact sim^2 as a Fraction (valid since sim >= 0 on the domain)."""
    if z == 0:
        return Fraction(0)
    norm_b_sq = z - r1 + r2
    if norm_b_sq == 0:
        return Fraction(0)
    num = (z - r1) * (z - r1)
    den = z * norm_b_sq
    return Fraction(num, den)


def sim_compare(p: int, z: int, a: tuple, b: tuple) -> int:
    """Exact integer comparison: -1 if sim(a) < sim(b), 0 if ==, +1 if >."""
    (a1, a2), (b1, b2) = a, b
    if z == 0:
        return 0
    na, da = (z - a1) ** 2, z * (z - a1 + a2)
    nb, db = (z - b1) ** 2, z * (z - b1 + b2)
    # handle zero-vector codes (den == 0 -> sim defined as 0)
    sa_zero = da == 0
    sb_zero = db == 0
    if sa_zero and sb_zero:
        return 0
    if sa_zero:
        return -1 if nb > 0 else 0
    if sb_zero:
        return 1 if na > 0 else 0
    lhs = na * db
    rhs = nb * da
    return (lhs > rhs) - (lhs < rhs)


def tuple_count(p: int, z: int, r1: int, r2: int) -> int:
    """Number of codes at exactly tuple (r1, r2) from the query (Eq. 4)."""
    if not is_valid_tuple(p, z, r1, r2):
        return 0
    return math.comb(z, r1) * math.comb(p - z, r2)


def rhat(z: int) -> int:
    """Integer part of the positive root of r^2 + r - z (Prop. 2, t=1).

    For all radii r < rhat (strictly: while z > r(r+1)), every code inside
    the Hamming ball C(q, r) has larger sim than every code outside.
    """
    if z <= 0:
        return 0
    return (math.isqrt(4 * z + 1) - 1) // 2


def all_valid_tuples(p: int, z: int):
    """All valid tuples for (p, z) — O((z+1)(p-z+1)) of them."""
    return [(r1, r2) for r1 in range(z + 1) for r2 in range(p - z + 1)]
