"""Exhaustive angular KNN baseline (the paper's comparator, §6.2).

Like the paper's optimized linear scan: sims are computed from the Hamming
tuple (Eq. 3) via XOR/ANDN + popcount, norm terms come from a lookup table
over the p+1 possible code norms, and sqrt(z) of the query is dropped from
comparisons (it is query-constant).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from .packing import WORD_DTYPE, hamming_tuples, popcount

__all__ = [
    "linear_scan_knn",
    "sims_against_db",
    "sims_batch_against_db",
    "sims_for_ids",
    "topk_from_sims",
]


def sims_against_db(q_words: np.ndarray, db_words: np.ndarray) -> np.ndarray:
    """Cosine sims of every db code vs one query, via Eq. 3 (float64).

    Zero-norm codes (or a zero query) get sim = 0.0 (see tuples.sim_value).
    """
    q_words = np.asarray(q_words, dtype=WORD_DTYPE)
    z = int(popcount(q_words[None, :])[0])
    r10, r01 = hamming_tuples(q_words, db_words)
    if z == 0:
        return np.zeros(r10.shape[0], dtype=np.float64)
    norm_b_sq = (z - r10 + r01).astype(np.float64)
    num = (z - r10).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        sims = num / (np.sqrt(float(z)) * np.sqrt(norm_b_sq))
    sims = np.where(norm_b_sq == 0, 0.0, sims)
    return sims


def sims_batch_against_db(
    q_words: np.ndarray, db_words: np.ndarray, chunk: int = 1 << 15
) -> np.ndarray:
    """(B, W) x (N, W) -> (B, N) float64 Eq. 3 sims, chunked over the DB
    so peak scratch stays O(B * chunk * W) regardless of N.

    Row i is elementwise-identical to ``sims_against_db(q_words[i], db)``
    (same broadcasted float ops), which is what lets batched callers reuse
    the per-query top-K selection bit-for-bit.
    """
    q = np.atleast_2d(np.asarray(q_words, dtype=WORD_DTYPE))
    db = np.asarray(db_words, dtype=WORD_DTYPE)
    B, N = q.shape[0], db.shape[0]
    z = popcount(q).astype(np.float64)                  # (B,)
    out = np.empty((B, N), dtype=np.float64)
    for lo in range(0, max(N, 1), chunk):
        blk = db[lo : lo + chunk]                       # (C, W)
        r10 = np.bitwise_count(q[:, None, :] & ~blk[None, :, :]).sum(-1)
        r01 = np.bitwise_count(~q[:, None, :] & blk[None, :, :]).sum(-1)
        norm_b_sq = (z[:, None] - r10 + r01).astype(np.float64)
        num = (z[:, None] - r10).astype(np.float64)
        with np.errstate(divide="ignore", invalid="ignore"):
            sims = num / (np.sqrt(z)[:, None] * np.sqrt(norm_b_sq))
        sims = np.where(norm_b_sq == 0, 0.0, sims)
        out[:, lo : lo + chunk] = np.where(z[:, None] == 0, 0.0, sims)
    return out


def sims_for_ids(
    q_words: np.ndarray, db_words: np.ndarray, ids: np.ndarray
) -> np.ndarray:
    """Eq. 3 sims of a *subset* of db rows vs one query (float64).

    Elementwise-identical to ``sims_against_db(q, db)[ids]`` (the math is
    per-row, so gathering first changes nothing) — this is the host-side
    exact rescorer of the device top-K path: the kernel preselects
    candidate ids in float32, this recomputes their sims in float64 so the
    final output is bit-identical to ``linear_scan_knn``.
    """
    return sims_against_db(
        q_words, np.asarray(db_words, dtype=WORD_DTYPE)[ids]
    )


def topk_from_sims(sims: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic top-k of one query's sim row: sorted by (-sim, id)."""
    n = sims.shape[0]
    k = min(k, n)
    if k == n:
        idx = np.arange(n)
    else:
        idx = np.argpartition(-sims, k - 1)[:k]
    order = np.lexsort((idx, -sims[idx]))
    ids = idx[order]
    return ids, sims[ids]


def linear_scan_knn(
    q_words: np.ndarray, db_words: np.ndarray, k: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact angular KNN by exhaustive scan.

    Returns (ids, sims), sorted by (-sim, id) for determinism. ``k`` is
    clamped to the dataset size.
    """
    sims = sims_against_db(q_words, db_words)
    return topk_from_sims(sims, k)
