"""Bit-packing and popcount utilities for binary codes.

Conventions
-----------
- A *code* is a p-bit binary vector. Bit ``j`` of code ``i`` lives in word
  ``j // word_bits`` at bit position ``j % word_bits`` (LSB-first).
- Host-side packed arrays use ``uint32`` words so the exact same buffers can
  be shipped to device (JAX defaults to 32-bit integer types without x64).
- ``W = ceil(p / 32)`` words per code. Trailing bits of the last word are 0.
"""

from __future__ import annotations

import numpy as np

WORD_BITS = 32
WORD_DTYPE = np.uint32


def n_words(p: int) -> int:
    return (p + WORD_BITS - 1) // WORD_BITS


def pack_bits(bits: np.ndarray) -> np.ndarray:
    """Pack a (n, p) {0,1} array into (n, W) uint32 words (LSB-first)."""
    bits = np.asarray(bits)
    if bits.ndim == 1:
        return pack_bits(bits[None, :])[0]
    n, p = bits.shape
    W = n_words(p)
    padded = np.zeros((n, W * WORD_BITS), dtype=np.uint8)
    padded[:, :p] = bits.astype(np.uint8) & 1
    # (n, W, 32) -> weight by bit position -> sum
    grouped = padded.reshape(n, W, WORD_BITS).astype(np.uint64)
    weights = (np.uint64(1) << np.arange(WORD_BITS, dtype=np.uint64))
    words = (grouped * weights).sum(axis=2)
    return words.astype(WORD_DTYPE)


def unpack_bits(words: np.ndarray, p: int) -> np.ndarray:
    """Unpack (n, W) uint32 words into (n, p) uint8 bits."""
    words = np.asarray(words, dtype=WORD_DTYPE)
    if words.ndim == 1:
        return unpack_bits(words[None, :], p)[0]
    n, W = words.shape
    shifts = np.arange(WORD_BITS, dtype=WORD_DTYPE)
    bits = (words[:, :, None] >> shifts[None, None, :]) & WORD_DTYPE(1)
    return bits.reshape(n, W * WORD_BITS)[:, :p].astype(np.uint8)


def popcount(words: np.ndarray) -> np.ndarray:
    """Per-row popcount of packed codes: (n, W) -> (n,) int64."""
    return np.bitwise_count(np.asarray(words)).sum(axis=-1).astype(np.int64)


def hamming_tuples(q_words: np.ndarray, db_words: np.ndarray):
    """Exact Hamming-distance tuples (Definition 1) of every db code vs q.

    Returns (r_1to0, r_0to1) as int64 arrays of shape (n,):
      r_1to0 = #bits 1 in q and 0 in b  = popcount(q & ~b)
      r_0to1 = #bits 0 in q and 1 in b  = popcount(~q & b)

    Trailing pad bits are zero in both q and b, so ``~q & b`` is unaffected
    and ``q & ~b`` is unaffected (q pad bits are 0).
    """
    q = np.asarray(q_words, dtype=WORD_DTYPE)
    b = np.asarray(db_words, dtype=WORD_DTYPE)
    r10 = np.bitwise_count(q & ~b).sum(axis=-1).astype(np.int64)
    r01 = np.bitwise_count(~q & b).sum(axis=-1).astype(np.int64)
    return r10, r01


def codes_to_ints(words: np.ndarray, p: int) -> np.ndarray:
    """Packed (n, W) codes -> python-int-exact uint64 values. Requires p <= 64."""
    if p > 64:
        raise ValueError(f"codes_to_ints requires p <= 64, got {p}")
    words = np.asarray(words, dtype=np.uint64)
    if words.ndim == 1:
        words = words[None, :]
    vals = words[:, 0].copy()
    if words.shape[1] > 1:
        vals |= words[:, 1] << np.uint64(32)
    return vals


def ints_to_codes(vals: np.ndarray, p: int) -> np.ndarray:
    """Inverse of codes_to_ints: uint64 values -> (n, W) uint32 words."""
    vals = np.asarray(vals, dtype=np.uint64)
    W = n_words(p)
    out = np.zeros((vals.shape[0], W), dtype=WORD_DTYPE)
    out[:, 0] = (vals & np.uint64(0xFFFFFFFF)).astype(WORD_DTYPE)
    if W > 1:
        out[:, 1] = (vals >> np.uint64(32)).astype(WORD_DTYPE)
    return out


def extract_substring(words: np.ndarray, lo: int, hi: int) -> np.ndarray:
    """Extract bit range [lo, hi) of each packed code as uint64 values.

    Requires hi - lo <= 64. Vectorized over rows.
    """
    w = hi - lo
    if w > 64:
        raise ValueError("substring wider than 64 bits")
    words = np.asarray(words, dtype=WORD_DTYPE)
    if words.ndim == 1:
        words = words[None, :]
    n, W = words.shape
    # Place each overlapping word directly at its offset in the RESULT
    # (offset = 32k - shift). Building a pre-shift window would need up to
    # 65 bits when shift > 0 and w == 64 — a uint64 shift by >= 64 is UB.
    first = lo // WORD_BITS
    shift = lo - first * WORD_BITS
    vals = np.zeros(n, dtype=np.uint64)
    nw = (w + shift + WORD_BITS - 1) // WORD_BITS
    for k in range(nw):
        idx = first + k
        if idx >= W:
            break
        w64 = words[:, idx].astype(np.uint64)
        off = 32 * k - shift
        if off >= 64:
            break
        if off >= 0:
            vals |= w64 << np.uint64(off)
        else:
            vals |= w64 >> np.uint64(-off)
    if w < 64:
        vals &= (np.uint64(1) << np.uint64(w)) - np.uint64(1)
    return vals


def substring_spans(p: int, m: int):
    """Split p bits into m near-equal contiguous spans [(lo, hi), ...].

    The first ``p % m`` spans get one extra bit, mirroring the MIH convention.
    """
    if not 1 <= m <= p:
        raise ValueError(f"need 1 <= m <= p, got m={m}, p={p}")
    base = p // m
    extra = p % m
    spans = []
    lo = 0
    for s in range(m):
        w = base + (1 if s < extra else 0)
        spans.append((lo, lo + w))
        lo += w
    assert lo == p
    return spans
