"""Deprecated shim: the device-sharded scan moved to ``repro.shard``.

The one-off helper grew into the sharded search subsystem
(``repro.shard``: ShardPlan + distributed primitives + the
"sharded_scan"/"sharded_amih" engine backends). Existing imports of
``repro.core.distributed`` keep working through this re-export but now
raise a ``DeprecationWarning``; new code should import from
``repro.shard``.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.distributed is deprecated; import ShardPlan and the "
    "sharded-scan primitives from repro.shard instead",
    DeprecationWarning,
    stacklevel=2,
)

from ..shard.distributed import (  # noqa: F401,E402
    make_retrieval_step,
    sharded_scan_candidates,
    sharded_scan_topk,
)
from ..shard.plan import ShardPlan  # noqa: F401,E402

__all__ = [
    "ShardPlan",
    "make_retrieval_step",
    "sharded_scan_candidates",
    "sharded_scan_topk",
]
