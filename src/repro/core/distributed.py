"""Back-compat shim: the device-sharded scan moved to ``repro.shard``.

The one-off helper grew into the sharded search subsystem
(``repro.shard``: ShardPlan + distributed primitives + the
"sharded_scan"/"sharded_amih" engine backends). Existing imports of
``repro.core.distributed`` keep working through this re-export; new code
should import from ``repro.shard``.
"""

from __future__ import annotations

from ..shard.distributed import (  # noqa: F401
    make_retrieval_step,
    sharded_scan_candidates,
    sharded_scan_topk,
)
from ..shard.plan import ShardPlan  # noqa: F401

__all__ = [
    "ShardPlan",
    "make_retrieval_step",
    "sharded_scan_candidates",
    "sharded_scan_topk",
]
