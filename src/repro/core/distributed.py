"""Distributed angular search: DB sharded over the mesh ``data`` axis.

The 10^9+-code regime (paper §6, SIFT-1B) does not fit one accelerator's
HBM; production deployments shard the packed code array row-wise across the
``data`` axis (and across pods via the ``pod`` axis). A query broadcast to
all shards runs the streaming scan/verify kernels locally, keeps a local
top-K, and a global top-K is obtained by all-gathering the K-sized partial
results (K * devices values, tiny) and re-selecting — one all-gather of
O(K) per query batch, no code movement.

This module is pure pjit/shard_map JAX and is exercised both by tests (with
8 fake CPU devices in a subprocess) and by the production-mesh dry-run
(``retrieval_step``).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import jax_compat

from ..kernels import ops

__all__ = ["sharded_scan_topk", "make_retrieval_step"]


def _local_topk_then_merge(q_words, db_shard, shard_offset, k, chunk, axes):
    """Per-shard body: local streaming top-K then cross-shard merge."""
    sims, ids = ops.scan_topk(q_words, db_shard, k, chunk=chunk)
    ids = ids + shard_offset            # local -> global ids
    # all-gather the K-sized partials along the DB-sharding axes
    all_sims = sims
    all_ids = ids
    for ax in axes:
        all_sims = jax.lax.all_gather(all_sims, ax, axis=1, tiled=True)
        all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
    best_sims, pos = jax.lax.top_k(all_sims, k)
    best_ids = jnp.take_along_axis(all_ids, pos, axis=1)
    return best_sims, best_ids


def sharded_scan_topk(
    mesh: Mesh,
    q_words: jax.Array,
    db_words: jax.Array,
    k: int,
    *,
    chunk: int = 1 << 14,
    shard_axes: Optional[Tuple[str, ...]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact global angular top-K with the DB row-sharded over the mesh.

    q_words: (B, W) replicated; db_words: (N, W) sharded on rows.
    Returns (sims, ids) (B, k) replicated. N must divide evenly by the
    number of DB shards (pad the DB with zero codes otherwise — zero codes
    score 0.0 and are filtered by id >= 0 semantics upstream).

    shard_axes defaults to EVERY mesh axis (§Perf iteration R1): the scan
    is embarrassingly row-parallel, so the original pod/data-only layout
    left the 16-wide 'model' axis idle — 16x redundant per-device work.
    """
    db_axes = shard_axes if shard_axes is not None else tuple(mesh.axis_names)
    db_axes = tuple(n for n in db_axes if n in mesh.axis_names)
    n_shards = 1
    for ax in db_axes:
        n_shards *= mesh.shape[ax]
    N = db_words.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    shard_rows = N // n_shards

    def body(q, db_shard):
        idx = jnp.int32(0)
        for ax in db_axes:
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        offset = (idx * shard_rows).astype(jnp.int32)
        return _local_topk_then_merge(q, db_shard, offset, k, chunk, db_axes)

    fn = jax_compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(db_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(q_words, db_words)


def make_retrieval_step(
    mesh: Mesh,
    k: int,
    chunk: int = 1 << 14,
    shard_axes: Optional[Tuple[str, ...]] = None,
):
    """jit-able retrieval step for serving + the production dry-run."""
    if shard_axes is None:
        shard_axes = tuple(mesh.axis_names)

    @functools.partial(jax.jit, static_argnums=())
    def retrieval_step(q_words, db_words):
        return sharded_scan_topk(
            mesh, q_words, db_words, k, chunk=chunk, shard_axes=shard_axes
        )

    in_shardings = (
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(shard_axes)),
    )
    return retrieval_step, in_shardings
