"""Sharded search subsystem: pod-scale DBs behind the unified engine API.

Layout:
  - plan.py        — ShardPlan: balanced row partition + global-id offsets
                     + per-shard device assignment + serializable summary
                     (the layout contract).
  - distributed.py — device-sharded scan primitives (shard_map + O(K)
                     all-gather merge). ``repro.core.distributed`` is a
                     deprecated re-export shim over this module.
  - engines.py     — "sharded_scan" / "sharded_amih" SearchEngine
                     backends, registered on import; each shard's state
                     is placed on its plan-assigned device.

``make_engine("sharded_scan" | "sharded_amih", ...)`` imports this
package on demand (see core.engine.make_engine), so host-only callers
never pay for it.
"""

from .distributed import (
    make_retrieval_step,
    sharded_scan_candidates,
    sharded_scan_topk,
)
from .engines import ShardedAMIHEngine, ShardedScanEngine
from .plan import ShardPlan, devices_from_mesh

__all__ = [
    "ShardPlan",
    "ShardedAMIHEngine",
    "ShardedScanEngine",
    "devices_from_mesh",
    "make_retrieval_step",
    "sharded_scan_candidates",
    "sharded_scan_topk",
]
