"""Sharded search subsystem: pod-scale DBs behind the unified engine API.

Layout:
  - plan.py        — ShardPlan: balanced row partition + global-id offsets
                     + serializable summary (the layout contract).
  - distributed.py — device-sharded scan primitives (shard_map + O(K)
                     all-gather merge), absorbed from core/distributed.
  - engines.py     — "sharded_scan" / "sharded_amih" SearchEngine
                     backends, registered on import.

``make_engine("sharded_scan" | "sharded_amih", ...)`` imports this
package on demand (see core.engine.make_engine), so host-only callers
never pay for it.
"""

from .distributed import (
    make_retrieval_step,
    sharded_scan_candidates,
    sharded_scan_topk,
)
from .engines import ShardedAMIHEngine, ShardedScanEngine
from .plan import ShardPlan

__all__ = [
    "ShardPlan",
    "ShardedAMIHEngine",
    "ShardedScanEngine",
    "make_retrieval_step",
    "sharded_scan_candidates",
    "sharded_scan_topk",
]
