"""ShardPlan: the row-partition layout of a pod-scale packed-code DB.

One plan answers every layout question the sharded engines ask:

  - which global rows shard ``s`` holds (balanced remainder: shard sizes
    differ by at most one row, never a trailing empty shard),
  - the per-shard global-id offset (``starts[s]``) that turns a shard's
    local row index into a DB-wide id,
  - the common padded row count (``rows_padded``) of the device layout —
    every shard occupies an equal-size slice of a (S * rows_padded, W)
    array so the mesh can shard it evenly; pad rows are zero codes that
    the scan masks out via per-shard ``counts``,
  - which DEVICE owns shard ``s`` (``devices`` / ``device_for``): the
    placement map the mesh-resident sharded AMIH engine uses to upload
    each shard's codes to — and verify candidates on — that shard's own
    device instead of funnelling every shard through device 0,
  - a JSON-serializable ``summary()`` (and ``from_summary`` inverse) so a
    serving fleet can ship the layout next to the checkpoint (device
    assignments serialize as strings, for observability only — a fresh
    host re-derives its own placement via ``place``/``from_mesh``;
    ``from_summary(strict=True)`` turns that documented drop into an
    error for callers that must not lose placement silently).

Plans are mesh-agnostic: ``balanced(n, num_shards)`` covers host-side
sharding (one process walking the shards), ``from_mesh(mesh, n)`` derives
the shard count — and the per-shard device assignment — from the mesh
axes the DB rows are split over (the ``pod``/``data`` axes of the
production meshes — any mesh axis works). ``place(devices)`` assigns an
explicit device list round-robin (wrapping when there are fewer devices
than shards — the single-device host degenerates to today's layout).

Cross-host serving (repro.cluster) splits one plan across worker hosts:
``host_partition(num_hosts)`` hands each host a sub-plan over a
contiguous run of the parent's shards, with ``base`` recording the
global id of the sub-plan's local row 0 — ``starts`` stay GLOBAL ids
(so shard-emitted ids need no per-host fixup at the merge) while
``shard_slice`` indexes the host's LOCAL row array. A worker rebuilds
its exact slice layout from the sub-plan's wire ``summary()`` alone.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["ShardPlan", "devices_from_mesh", "resolve_mesh_axes"]


def resolve_mesh_axes(mesh, shard_axes=None):
    """(axes, n_shards) for the mesh axes DB rows shard across: the
    requested axes filtered to ones the mesh has (default: every mesh
    axis), and the product of their sizes. The single source of this
    rule — used by both ShardPlan.from_mesh and the shard_map bodies in
    shard/distributed.py, which must agree on the shard count."""
    axes = tuple(shard_axes) if shard_axes is not None \
        else tuple(mesh.axis_names)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    return axes, n_shards


def devices_from_mesh(mesh, shard_axes=None) -> Tuple[object, ...]:
    """One owner device per shard, in linear shard-index order (row-major
    over the shard axes — the same order ``_shard_index`` walks them in
    shard/distributed.py). When the shard axes are a strict subset of the
    mesh axes, each shard's group of devices is represented by its first
    device (the one ``shard_map`` gives replica index 0 on the remaining
    axes)."""
    axes, n_shards = resolve_mesh_axes(mesh, shard_axes)
    names = list(mesh.axis_names)
    perm = [names.index(a) for a in axes] + [
        i for i, a in enumerate(names) if a not in axes
    ]
    dev = np.transpose(np.asarray(mesh.devices), perm).reshape(n_shards, -1)
    return tuple(dev[:, 0])


@dataclass(frozen=True)
class ShardPlan:
    """Balanced row partition of ``n`` DB rows into ``num_shards`` shards.

    ``devices`` (when non-empty) is the per-shard placement map: entry
    ``s`` is the device shard ``s``'s codes live on and its candidate
    verification runs on. It is excluded from equality/serialization
    round-trips — placement is a property of the serving host, not of
    the layout contract.

    ``base`` is the global DB id of the plan's local row 0 (0 for a
    whole-DB plan). Sub-plans cut by ``host_partition`` carry the
    offset of their host's first row here: ``starts`` remain GLOBAL
    ids (``n`` and ``counts`` stay host-local), so engines built over
    the host's local row slice still emit DB-wide ids without any
    merge-time fixup.
    """

    n: int
    starts: Tuple[int, ...]
    counts: Tuple[int, ...]
    axis_names: Tuple[str, ...] = ()
    devices: Tuple[object, ...] = field(default=(), compare=False)
    base: int = 0

    def __post_init__(self):
        if len(self.starts) != len(self.counts) or not self.starts:
            raise ValueError("starts/counts must be equal-length, non-empty")
        if sum(self.counts) != self.n:
            raise ValueError(
                f"counts sum to {sum(self.counts)}, expected n={self.n}"
            )
        if self.starts[0] != self.base:
            raise ValueError(
                f"starts[0]={self.starts[0]} must equal base={self.base} "
                f"(starts are global ids; base is the global id of local "
                f"row 0)"
            )
        if self.devices and len(self.devices) != len(self.counts):
            raise ValueError(
                f"devices maps {len(self.devices)} shards, plan has "
                f"{len(self.counts)}"
            )

    # ------------------------------------------------------------ builders
    @classmethod
    def balanced(
        cls,
        n: int,
        num_shards: int,
        axis_names: Tuple[str, ...] = (),
    ) -> "ShardPlan":
        """Partition ``n`` rows into ``num_shards`` contiguous slices whose
        sizes differ by at most one (the first ``n % num_shards`` shards
        take the extra row)."""
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        base, rem = divmod(n, num_shards)
        counts = tuple(
            base + (1 if s < rem else 0) for s in range(num_shards)
        )
        starts = tuple(int(x) for x in np.cumsum((0,) + counts[:-1]))
        return cls(n=n, starts=starts, counts=counts,
                   axis_names=tuple(axis_names))

    @classmethod
    def from_mesh(
        cls,
        mesh,
        n: int,
        shard_axes: Optional[Tuple[str, ...]] = None,
    ) -> "ShardPlan":
        """Plan over the product of the mesh axes the DB rows shard across
        (default: every mesh axis, matching ``sharded_scan_topk``). The
        per-shard ``devices`` map is derived from the mesh too
        (``devices_from_mesh``), so shard ``s``'s index state lands on the
        device that owns shard ``s``'s rows in the mesh layout."""
        axes, num_shards = resolve_mesh_axes(mesh, shard_axes)
        if not axes:
            raise ValueError(
                f"no shard axes among mesh axes {tuple(mesh.axis_names)}"
            )
        plan = cls.balanced(n, num_shards, axis_names=axes)
        return plan.place(devices_from_mesh(mesh, axes))

    # ----------------------------------------------------------- placement
    def place(self, devices) -> "ShardPlan":
        """A copy of this plan with ``devices`` assigned round-robin over
        the shards: shard ``s`` gets ``devices[s % len(devices)]``, so
        fewer devices than shards wraps (devices host several shards —
        the 1-device host maps every shard to it, exactly the pre-placed
        behavior) and extra devices are simply left idle. An empty/None
        list clears the placement."""
        devices = tuple(devices or ())
        if not devices:
            return replace(self, devices=())
        return replace(self, devices=tuple(
            devices[s % len(devices)] for s in range(self.num_shards)
        ))

    def device_for(self, s: int):
        """Shard ``s``'s assigned device (None when the plan is unplaced
        — callers fall back to the default device)."""
        return self.devices[s] if self.devices else None

    # -------------------------------------------------------- partitioning
    def host_partition(self, num_hosts: int) -> List["ShardPlan"]:
        """Split this plan into ``num_hosts`` per-host sub-plans, each
        covering a contiguous run of the parent's shards (run lengths
        differ by at most one shard). Sub-plan ``starts`` keep the
        parent's GLOBAL ids and ``base`` records the global id of the
        host's first row, so a worker that loads only its local row
        slice (``[base, base + n)`` of the parent DB) still emits
        DB-wide ids — the coordinator merges without any offset fixup.
        Device placements are not carried: each host re-derives its own
        via ``place``/``from_mesh``."""
        if num_hosts < 1:
            raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
        if num_hosts > self.num_shards:
            raise ValueError(
                f"num_hosts={num_hosts} exceeds num_shards="
                f"{self.num_shards}; a host needs at least one shard"
            )
        per, rem = divmod(self.num_shards, num_hosts)
        plans: List[ShardPlan] = []
        s0 = 0
        for h in range(num_hosts):
            run = per + (1 if h < rem else 0)
            starts = self.starts[s0 : s0 + run]
            counts = self.counts[s0 : s0 + run]
            plans.append(ShardPlan(
                n=int(sum(counts)),
                starts=starts,
                counts=counts,
                axis_names=self.axis_names,
                base=int(starts[0]),
            ))
            s0 += run
        return plans

    # ------------------------------------------------------------ geometry
    @property
    def num_shards(self) -> int:
        return len(self.counts)

    @property
    def rows_padded(self) -> int:
        """Common per-shard row count of the padded device layout."""
        return max(self.counts) if self.counts else 0

    def shard_slice(self, s: int) -> slice:
        """Shard ``s``'s rows in the plan's LOCAL row array (for a
        whole-DB plan, local == global; a ``host_partition`` sub-plan
        subtracts ``base`` so it slices the host's own row slab)."""
        lo = self.starts[s] - self.base
        return slice(lo, lo + self.counts[s])

    def global_ids(self, s: int, local_ids: np.ndarray) -> np.ndarray:
        return np.asarray(local_ids) + self.starts[s]

    def padded_layout(self, db_words: np.ndarray) -> np.ndarray:
        """(n, W) -> (num_shards * rows_padded, W): shard ``s`` occupies
        rows [s * rows_padded, (s+1) * rows_padded), its real rows first,
        zero-code pad rows after. This is the array a mesh row-shards
        evenly; the scan masks pads via ``counts`` (``scan_topk
        n_valid``), so they never reach a top-K."""
        db = np.asarray(db_words)
        R = self.rows_padded
        out = np.zeros((self.num_shards * R,) + db.shape[1:], dtype=db.dtype)
        for s in range(self.num_shards):
            out[s * R : s * R + self.counts[s]] = db[self.shard_slice(s)]
        return out

    # -------------------------------------------------------- serialization
    def summary(self) -> Dict[str, object]:
        """JSON-serializable description (round-trips via from_summary;
        device assignments serialize as strings and are observability
        only — ``from_summary`` returns an unplaced plan)."""
        out = {
            "n": self.n,
            "num_shards": self.num_shards,
            "rows_padded": self.rows_padded,
            "starts": list(self.starts),
            "counts": list(self.counts),
            "axis_names": list(self.axis_names),
        }
        if self.base:
            out["base"] = self.base
        if self.devices:
            out["devices"] = [str(d) for d in self.devices]
        return out

    @classmethod
    def from_summary(
        cls, d: Dict[str, object], strict: bool = False
    ) -> "ShardPlan":
        """Rebuild a plan from ``summary()`` output. Device placements do
        NOT round-trip (they serialize as strings, for observability) —
        the result is always unplaced. A summary that recorded a
        placement triggers a warning, or a ValueError with
        ``strict=True`` for callers that must not lose placement
        silently."""
        if "devices" in d:
            msg = (
                "ShardPlan.from_summary drops device placements "
                f"({len(d['devices'])} recorded): device strings cannot "
                "be resolved to live devices on a different host — "
                "re-place via ShardPlan.place or from_mesh"
            )
            if strict:
                raise ValueError(msg)
            warnings.warn(msg, stacklevel=2)
        return cls(
            n=int(d["n"]),
            starts=tuple(int(x) for x in d["starts"]),
            counts=tuple(int(x) for x in d["counts"]),
            axis_names=tuple(d.get("axis_names", ())),
            base=int(d.get("base", 0)),
        )
