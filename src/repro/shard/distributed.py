"""Device-sharded angular search: DB rows split over mesh axes.

The 10^9+-code regime (paper §6, SIFT-1B) does not fit one accelerator's
HBM; production deployments shard the packed code array row-wise across
the ``data`` axis (and across pods via the ``pod`` axis). A query
broadcast to all shards runs the streaming scan kernels locally, keeps a
local top-K, and the K-sized partials are all-gathered (K * devices
values, tiny) — one all-gather of O(K) per query batch, no code movement.

Two merge shapes:

  - ``sharded_scan_topk``: gather + re-select the global top-K on device
    (float32 end to end) — the retrieval-step / dry-run path.
  - ``sharded_scan_candidates``: gather WITHOUT the final re-selection,
    returning every shard's top-``k_fetch`` (global ids, -1 in invalid
    slots). The sharded engine reranks this pool on host in exact float64
    so its results stay bit-identical to ``linear_scan_knn``; pad rows of
    a ShardPlan layout are masked on device (``scan_topk n_valid``), so
    uneven N never leaks zero-code pads into the pool.

This module is pure pjit/shard_map JAX and is exercised both by tests
(with 8 fake CPU devices in a subprocess) and by the production-mesh
dry-run (``retrieval_step``). It covers the *scan* side of mesh
residency; the sharded AMIH engine reaches the same placement without
shard_map — each shard's index commits its codes to the plan-assigned
device (``ShardPlan.devices``) and issues per-device verify launches
through kernels/ops.py (host-driven, since probing is a host-side table
walk).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import jax_compat

from ..kernels import ops
from .plan import ShardPlan, resolve_mesh_axes

__all__ = [
    "make_retrieval_step",
    "sharded_scan_candidates",
    "sharded_scan_topk",
]


def _shard_index(mesh: Mesh, axes) -> jax.Array:
    """Linear shard index of the executing device (row-major over axes)."""
    idx = jnp.int32(0)
    for ax in axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def _local_topk_then_merge(q_words, db_shard, shard_offset, k, chunk, axes):
    """Per-shard body: local streaming top-K then cross-shard merge."""
    sims, ids = ops.scan_topk(q_words, db_shard, k, chunk=chunk)
    ids = ids + shard_offset            # local -> global ids
    # all-gather the K-sized partials along the DB-sharding axes
    all_sims = sims
    all_ids = ids
    for ax in axes:
        all_sims = jax.lax.all_gather(all_sims, ax, axis=1, tiled=True)
        all_ids = jax.lax.all_gather(all_ids, ax, axis=1, tiled=True)
    return ops.merge_topk(all_sims, all_ids, k)


def sharded_scan_topk(
    mesh: Mesh,
    q_words: jax.Array,
    db_words: jax.Array,
    k: int,
    *,
    chunk: int = 1 << 14,
    shard_axes: Optional[Tuple[str, ...]] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact global angular top-K with the DB row-sharded over the mesh.

    q_words: (B, W) replicated; db_words: (N, W) sharded on rows.
    Returns (sims, ids) (B, k) replicated. N must divide evenly by the
    number of DB shards (pad the DB with zero codes otherwise — zero codes
    score 0.0 and are filtered by id >= 0 semantics upstream).

    shard_axes defaults to EVERY mesh axis (§Perf iteration R1): the scan
    is embarrassingly row-parallel, so the original pod/data-only layout
    left the 16-wide 'model' axis idle — 16x redundant per-device work.
    """
    db_axes, n_shards = resolve_mesh_axes(mesh, shard_axes)
    N = db_words.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    shard_rows = N // n_shards

    def body(q, db_shard):
        offset = (_shard_index(mesh, db_axes) * shard_rows).astype(jnp.int32)
        return _local_topk_then_merge(q, db_shard, offset, k, chunk, db_axes)

    fn = jax_compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(db_axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(q_words, db_words)


def sharded_scan_candidates(
    mesh: Mesh,
    q_words: jax.Array,
    db_padded: jax.Array,
    plan: ShardPlan,
    k_fetch: int,
    *,
    chunk: int = 1 << 14,
) -> Tuple[jax.Array, jax.Array]:
    """Per-shard top-``k_fetch`` pools, gathered but NOT re-selected.

    ``db_padded`` is the plan's device layout ((S * rows_padded, W),
    sharded on rows over ``plan.axis_names``); each shard's scan masks
    its pad rows via the plan's per-shard ``counts`` and maps local rows
    to global ids via ``starts``. Returns replicated
    (sims (B, S * k_fetch) float32, gids (B, S * k_fetch) int32) with
    sim = -inf / gid = -1 in invalid slots — the host-rerank candidate
    pool of the sharded_scan engine.
    """
    axes, n_shards = resolve_mesh_axes(mesh, plan.axis_names or None)
    if n_shards != plan.num_shards:
        raise ValueError(
            f"plan has {plan.num_shards} shards but mesh axes {axes} "
            f"give {n_shards}"
        )
    starts = jnp.asarray(plan.starts, dtype=jnp.int32)
    counts = jnp.asarray(plan.counts, dtype=jnp.int32)

    def body(q, db_shard, starts_arr, counts_arr):
        idx = _shard_index(mesh, axes)
        sims, ids = ops.scan_topk(
            q, db_shard, k_fetch, chunk=chunk, n_valid=counts_arr[idx]
        )
        gids = jnp.where(sims > -jnp.inf, ids + starts_arr[idx], -1)
        for ax in axes:
            sims = jax.lax.all_gather(sims, ax, axis=1, tiled=True)
            gids = jax.lax.all_gather(gids, ax, axis=1, tiled=True)
        return sims, gids

    fn = jax_compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(axes), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return fn(q_words, db_padded, starts, counts)


def make_retrieval_step(
    mesh: Mesh,
    k: int,
    chunk: int = 1 << 14,
    shard_axes: Optional[Tuple[str, ...]] = None,
):
    """jit-able retrieval step for serving + the production dry-run."""
    if shard_axes is None:
        shard_axes = tuple(mesh.axis_names)

    @functools.partial(jax.jit, static_argnums=())
    def retrieval_step(q_words, db_words):
        return sharded_scan_topk(
            mesh, q_words, db_words, k, chunk=chunk, shard_axes=shard_axes
        )

    in_shardings = (
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P(shard_axes)),
    )
    return retrieval_step, in_shardings
