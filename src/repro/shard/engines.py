"""Sharded SearchEngine backends: pod-scale DBs behind the same knn_batch.

Both backends consume a ``ShardPlan`` (row partition with per-shard
global-id offsets) and register in the core engine registry, so

    make_engine("sharded_scan", db, p, mesh=mesh)          # device-sharded
    make_engine("sharded_amih", db, p, num_shards=8)       # host-sharded

work unchanged for every caller of the unified API. Both are EXACT: sims
returned are bit-identical to per-query ``linear_scan_knn`` (up to ties
inside one Hamming tuple), including N not divisible by the shard count
and K larger than a shard's row count.

  - "sharded_scan": every shard runs the streaming device top-K
    (``kernels/ops.scan_topk``) over its row slice and contributes its
    local top-``k_fetch`` to a candidate pool — with a mesh, as ONE
    shard_map launch whose O(K)-per-shard partials are all-gathered
    (``sharded_scan_candidates``); without one, as a host loop over
    per-shard device slices. The pooled candidates are re-scored on host
    in exact float64 (``sims_for_ids``) and re-ranked, the same
    preselect-then-rerank contract as LinearScanEngine's pallas path.

  - "sharded_amih": each shard owns an ``AMIHIndex`` over its row slice
    (built with ``id_offset`` so emitted ids are global). Shards are
    probed in sequence; after each, the pooled k-th best cosine becomes
    the next shard's ``stop_below`` bound — a shard stops probing the
    moment its tuple sequence's sim drops below the global k-th
    (``AMIHIndex.knn_batch_bounded``), the cross-shard form of the
    paper's early-termination rule. Per-shard exact top-K lists merge by
    one lexsort into the global top-K.

``EngineStats`` gains the shard view: ``stats.shards`` and one
``stats.per_shard`` dict per shard (rows held, candidates/verifications
contributed, device launches, early stops).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.amih import AMIHIndex, AMIHStats
from ..core.engine import EngineStats, SearchEngine, register_engine
from ..core.linear_scan import sims_for_ids
from ..core.packing import WORD_DTYPE
from ..core.single_table import SearchStats
from .plan import ShardPlan

__all__ = ["ShardedAMIHEngine", "ShardedScanEngine"]


def _resolve_plan(
    db_words: np.ndarray,
    mesh,
    num_shards: Optional[int],
    shard_axes,
    plan: Optional[ShardPlan],
) -> ShardPlan:
    """One plan from whichever knob the caller provided (plan > mesh >
    num_shards > one shard per local device)."""
    n = np.asarray(db_words).shape[0]
    if plan is not None:
        if plan.n != n:
            raise ValueError(f"plan covers n={plan.n}, DB has n={n}")
        return plan
    if mesh is not None:
        return ShardPlan.from_mesh(mesh, n, shard_axes=shard_axes)
    if num_shards is None:
        import jax

        num_shards = max(1, len(jax.devices()))
    return ShardPlan.balanced(n, num_shards)


def _preselect_slack(p: int) -> int:
    # Same float32 selection-boundary slack as LinearScanEngine._topk_slack:
    # distinct Eq. 3 sims stay resolvable in float32 up to p ~ 192; beyond,
    # the slack grows so a collapsed boundary population still fits.
    return 16 + max(0, p - 128) // 4


def _count_per_shard(plan: ShardPlan, gids: np.ndarray) -> List[int]:
    """How many candidate ids fall in each shard's global-id range."""
    edges = np.asarray(plan.starts[1:], dtype=np.int64)
    owner = np.searchsorted(edges, gids, side="right")
    return np.bincount(owner, minlength=plan.num_shards).tolist()


@register_engine
class ShardedScanEngine(SearchEngine):
    """Exhaustive scan over a row-sharded DB: per-shard device top-K
    preselect, O(K)-per-shard gather, exact float64 host rerank."""

    name = "sharded_scan"

    def __init__(self, db_words, p, plan, mesh, chunk):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.plan = plan
        self.mesh = mesh
        self.chunk = chunk
        self.shard_launches = 0
        self._db_dev = None          # mesh mode: padded layout, row-sharded
        self._shard_dev: List[Any] = []   # host mode: per-shard slices

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        mesh=None,
        num_shards: Optional[int] = None,
        shard_axes: Optional[Tuple[str, ...]] = None,
        plan: Optional[ShardPlan] = None,
        chunk: int = 1 << 14,
        **cfg: Any,
    ) -> "ShardedScanEngine":
        if cfg:
            raise TypeError(f"unknown sharded_scan options: {sorted(cfg)}")
        plan = _resolve_plan(db_words, mesh, num_shards, shard_axes, plan)
        return cls(db_words, p, plan, mesh, chunk)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        if k_eff == 0:
            return (
                np.empty((B, 0), np.int64), np.empty((B, 0), np.float64),
                EngineStats(backend=self.name, queries=B,
                            per_query=[SearchStats() for _ in range(B)],
                            shards=self.plan.num_shards),
            )
        from ..kernels import ops

        k_fetch = min(
            self.plan.rows_padded,
            ops.pad_bucket(k_eff + _preselect_slack(self.p), minimum=8),
        )
        if self.mesh is not None:
            pool_sims, pool_gids = self._candidates_mesh(q, k_fetch)
        else:
            pool_sims, pool_gids = self._candidates_host(q, k_fetch)

        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        cand_total = 0
        shard_counts = np.zeros(self.plan.num_shards, dtype=np.int64)
        for i in range(B):
            cand = pool_gids[i][pool_gids[i] >= 0].astype(np.int64)
            cand_total += cand.size
            shard_counts += np.asarray(_count_per_shard(self.plan, cand))
            sub = sims_for_ids(q[i], self.db_words, cand)  # exact float64
            order = np.lexsort((cand, -sub))[:k_eff]
            ids_out[i] = cand[order]
            sims_out[i] = sub[order]
        self.shard_launches += self.plan.num_shards
        per_shard = [
            {
                "shard": s,
                "rows": self.plan.counts[s],
                "candidates": int(shard_counts[s]),
                "launches": 1,
            }
            for s in range(self.plan.num_shards)
        ]
        stats = EngineStats(
            backend=self.name, queries=B,
            per_query=[SearchStats(retrieved=self.n) for _ in range(B)],
            shards=self.plan.num_shards, per_shard=per_shard,
        )
        return ids_out, sims_out, stats

    # ------------------------------------------------------------ mesh mode
    def _candidates_mesh(self, q, k_fetch):
        """One shard_map launch: per-device scan + O(K) all-gather."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..kernels import ops
        from .distributed import sharded_scan_candidates

        if self._db_dev is None:
            axes = self.plan.axis_names or tuple(self.mesh.axis_names)
            self._db_dev = jax.device_put(
                self.plan.padded_layout(self.db_words),
                NamedSharding(self.mesh, P(axes)),
            )
        B = q.shape[0]
        Bp = ops.pad_bucket(B, minimum=8)
        qp = np.zeros((Bp, q.shape[1]), dtype=q.dtype)
        qp[:B] = q
        sims, gids = sharded_scan_candidates(
            self.mesh, jnp.asarray(qp), self._db_dev, self.plan, k_fetch,
            chunk=self.chunk,
        )
        return np.asarray(sims)[:B], np.asarray(gids)[:B]

    # ------------------------------------------------------------ host mode
    def _candidates_host(self, q, k_fetch):
        """No mesh: walk the shards on the default device, same math."""
        import jax.numpy as jnp

        from ..kernels import ops

        if not self._shard_dev:
            self._shard_dev = [
                jnp.asarray(self.db_words[self.plan.shard_slice(s)])
                for s in range(self.plan.num_shards)
            ]
        B = q.shape[0]
        Bp = ops.pad_bucket(B, minimum=8)
        qp = np.zeros((Bp, q.shape[1]), dtype=q.dtype)
        qp[:B] = q
        qj = jnp.asarray(qp)
        sims_parts, gid_parts = [], []
        for s in range(self.plan.num_shards):
            count = self.plan.counts[s]
            if count == 0:
                continue
            sims, ids = ops.scan_topk(
                qj, self._shard_dev[s], min(k_fetch, count),
                chunk=self.chunk, use_pallas=ops.on_tpu(),
            )
            sims = np.asarray(sims)[:B]
            gids = np.asarray(ids)[:B].astype(np.int64)
            gids = np.where(sims > -np.inf, gids + self.plan.starts[s], -1)
            sims_parts.append(sims)
            gid_parts.append(gids)
        return (
            np.concatenate(sims_parts, axis=1),
            np.concatenate(gid_parts, axis=1),
        )


@register_engine
class ShardedAMIHEngine(SearchEngine):
    """AMIH over a row-sharded DB: one shard-local index per slice,
    sequential probing with the pooled k-th cosine as each next shard's
    early-termination bound, exact lexsort merge."""

    name = "sharded_amih"

    def __init__(self, db_words, p, plan, indexes, enumeration_cap):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.plan = plan
        self.indexes = indexes      # [(shard_id, AMIHIndex)] non-empty shards
        self.enumeration_cap = enumeration_cap

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        mesh=None,
        num_shards: Optional[int] = None,
        shard_axes: Optional[Tuple[str, ...]] = None,
        plan: Optional[ShardPlan] = None,
        m: Optional[int] = None,
        verify_backend: str = "numpy",
        enumeration_cap: Optional[int] = None,
        **cfg: Any,
    ) -> "ShardedAMIHEngine":
        if cfg:
            raise TypeError(f"unknown sharded_amih options: {sorted(cfg)}")
        db = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        plan = _resolve_plan(db, mesh, num_shards, shard_axes, plan)
        indexes = []
        for s in range(plan.num_shards):
            if plan.counts[s] == 0:
                continue
            indexes.append((s, AMIHIndex.build(
                db[plan.shard_slice(s)], p, m=m,
                verify_backend=verify_backend, id_offset=plan.starts[s],
            )))
        return cls(db, p, plan, indexes, enumeration_cap)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        per_query = [AMIHStats() for _ in range(B)]
        if k_eff == 0:
            return (
                np.empty((B, 0), np.int64), np.empty((B, 0), np.float64),
                EngineStats(backend=self.name, queries=B,
                            per_query=per_query,
                            shards=self.plan.num_shards),
            )
        per_shard: List[Dict[str, int]] = []
        gid_parts: List[List[np.ndarray]] = [[] for _ in range(B)]
        sim_parts: List[List[np.ndarray]] = [[] for _ in range(B)]
        bounds = np.full(B, -np.inf)

        for s, index in self.indexes:
            local_k = min(k_eff, index.n)
            shard_stats = [AMIHStats() for _ in range(B)]
            launches0 = index.verify_launches
            results = index.knn_batch_bounded(
                q, k_eff, stop_below=bounds, stats=shard_stats,
                enumeration_cap=self.enumeration_cap,
            )
            early_stopped = 0
            for i, (r_ids, r_sims) in enumerate(results):
                if r_ids.size < local_k:
                    early_stopped += 1
                if r_ids.size:
                    gid_parts[i].append(r_ids)
                    sim_parts[i].append(r_sims)
                total = sum(a.size for a in sim_parts[i])
                if total >= k_eff:
                    pool = np.concatenate(sim_parts[i]) if \
                        len(sim_parts[i]) > 1 else sim_parts[i][0]
                    # pooled k-th best cosine: sims strictly below it can
                    # never enter the global top-K of query i
                    bounds[i] = np.partition(pool, total - k_eff)[
                        total - k_eff
                    ]
                self._fold_stats(per_query[i], shard_stats[i])
            agg: Dict[str, int] = {
                "shard": s,
                "rows": index.n,
                "launches": index.verify_launches - launches0,
                "early_stopped": early_stopped,
            }
            for counter in ("probes", "retrieved", "verified",
                            "tuples_processed", "fell_back_to_scan"):
                agg[counter] = sum(
                    int(getattr(st, counter)) for st in shard_stats
                )
            per_shard.append(agg)

        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        for i in range(B):
            gids = np.concatenate(gid_parts[i]) if gid_parts[i] \
                else np.empty(0, dtype=np.int64)
            sims = np.concatenate(sim_parts[i]) if sim_parts[i] \
                else np.empty(0, dtype=np.float64)
            order = np.lexsort((gids, -sims))[:k_eff]
            ids_out[i] = gids[order]
            sims_out[i] = sims[order]
        stats = EngineStats(
            backend=self.name, queries=B, per_query=per_query,
            shards=self.plan.num_shards, per_shard=per_shard,
        )
        return ids_out, sims_out, stats

    @staticmethod
    def _fold_stats(into: AMIHStats, src: AMIHStats) -> None:
        into.probes += src.probes
        into.retrieved += src.retrieved
        into.verified += src.verified
        into.tuples_processed += src.tuples_processed
        into.substring_tuples_probed += src.substring_tuples_probed
        into.max_radius = max(into.max_radius, src.max_radius)
        into.exceeded_rhat |= src.exceeded_rhat
        into.fell_back_to_scan |= src.fell_back_to_scan
