"""Sharded SearchEngine backends: pod-scale DBs behind the same knn_batch.

Both backends consume a ``ShardPlan`` (row partition with per-shard
global-id offsets) and register in the core engine registry, so

    make_engine("sharded_scan", db, p, mesh=mesh)          # device-sharded
    make_engine("sharded_amih", db, p, num_shards=8)       # host-sharded

work unchanged for every caller of the unified API. Both are EXACT: sims
returned are bit-identical to per-query ``linear_scan_knn`` (up to ties
inside one Hamming tuple), including N not divisible by the shard count
and K larger than a shard's row count.

  - "sharded_scan": every shard runs the streaming device top-K
    (``kernels/ops.scan_topk``) over its row slice and contributes its
    local top-``k_fetch`` to a candidate pool — with a mesh, as ONE
    shard_map launch whose O(K)-per-shard partials are all-gathered
    (``sharded_scan_candidates``); without one, as a host loop over
    per-shard device slices. The pooled candidates are re-scored on host
    in exact float64 (``sims_for_ids``) and re-ranked, the same
    preselect-then-rerank contract as LinearScanEngine's pallas path.

  - "sharded_amih": each shard owns an ``AMIHIndex`` over its row slice
    (built with ``id_offset`` so emitted ids are global). Shards are
    probed in sequence; after each, the pooled k-th best cosine becomes
    the next shard's ``stop_below`` bound — a shard stops probing the
    moment its tuple sequence's sim drops below the global k-th
    (``AMIHIndex.knn_batch_bounded``), the cross-shard form of the
    paper's early-termination rule. Per-shard exact top-K lists merge by
    one lexsort into the global top-K.

``EngineStats`` gains the shard view: ``stats.shards`` and one
``stats.per_shard`` dict per shard (rows held, candidates/verifications
contributed, device launches, early stops).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.amih import AMIHIndex, AMIHStats
from ..core.engine import (
    EngineStats,
    SearchEngine,
    probe_cache_snapshot,
    register_engine,
)
from ..core.linear_scan import sims_for_ids
from ..core.packing import WORD_DTYPE
from ..core.single_table import SearchStats
from .plan import ShardPlan

__all__ = ["ShardedAMIHEngine", "ShardedScanEngine"]


def _resolve_plan(
    db_words: np.ndarray,
    mesh,
    num_shards: Optional[int],
    shard_axes,
    plan: Optional[ShardPlan],
    devices=None,
) -> ShardPlan:
    """One PLACED plan from whichever knob the caller provided (plan >
    mesh > num_shards > one shard per local device). Placement: an
    explicit ``devices`` list wins; a mesh-derived plan is already
    placed on its mesh devices; any still-unplaced plan — including a
    caller-built one, notably ``ShardPlan.from_summary`` restores,
    which are always unplaced — round-robins the local devices (a
    single-device host assigns every shard to it — exactly the
    pre-placement layout). A caller plan that already carries devices
    is trusted as-is."""
    n = np.asarray(db_words).shape[0]
    if plan is not None:
        if plan.n != n:
            raise ValueError(f"plan covers n={plan.n}, DB has n={n}")
    elif mesh is not None:
        plan = ShardPlan.from_mesh(mesh, n, shard_axes=shard_axes)
    else:
        if num_shards is None:
            import jax

            num_shards = max(1, len(jax.devices()))
        plan = ShardPlan.balanced(n, num_shards)
    if devices is not None:
        return plan.place(devices)
    if not plan.devices:
        import jax

        plan = plan.place(jax.devices())
    return plan


def _preselect_slack(p: int) -> int:
    # Same float32 selection-boundary slack as LinearScanEngine._topk_slack:
    # distinct Eq. 3 sims stay resolvable in float32 up to p ~ 192; beyond,
    # the slack grows so a collapsed boundary population still fits.
    return 16 + max(0, p - 128) // 4


def _count_per_shard(plan: ShardPlan, gids: np.ndarray) -> List[int]:
    """How many candidate ids fall in each shard's global-id range."""
    edges = np.asarray(plan.starts[1:], dtype=np.int64)
    owner = np.searchsorted(edges, gids, side="right")
    return np.bincount(owner, minlength=plan.num_shards).tolist()


@register_engine
class ShardedScanEngine(SearchEngine):
    """Exhaustive scan over a row-sharded DB: per-shard device top-K
    preselect, O(K)-per-shard gather, exact float64 host rerank."""

    name = "sharded_scan"

    def __init__(self, db_words, p, plan, mesh, chunk):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.plan = plan
        self.mesh = mesh
        self.chunk = chunk
        self.shard_launches = 0
        self._db_dev = None          # mesh mode: padded layout, row-sharded
        self._shard_dev: List[Any] = []   # host mode: per-shard slices

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        mesh=None,
        num_shards: Optional[int] = None,
        shard_axes: Optional[Tuple[str, ...]] = None,
        plan: Optional[ShardPlan] = None,
        chunk: int = 1 << 14,
        devices=None,
        **cfg: Any,
    ) -> "ShardedScanEngine":
        if cfg:
            raise TypeError(f"unknown sharded_scan options: {sorted(cfg)}")
        plan = _resolve_plan(db_words, mesh, num_shards, shard_axes, plan,
                             devices)
        return cls(db_words, p, plan, mesh, chunk)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        if k_eff == 0:
            return (
                np.empty((B, 0), np.int64), np.empty((B, 0), np.float64),
                EngineStats(backend=self.name, queries=B,
                            per_query=[SearchStats() for _ in range(B)],
                            shards=self.plan.num_shards),
            )
        from ..kernels import ops

        k_fetch = min(
            self.plan.rows_padded,
            ops.pad_bucket(k_eff + _preselect_slack(self.p), minimum=8),
        )
        if self.mesh is not None:
            pool_sims, pool_gids = self._candidates_mesh(q, k_fetch)
        else:
            pool_sims, pool_gids = self._candidates_host(q, k_fetch)

        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        cand_total = 0
        shard_counts = np.zeros(self.plan.num_shards, dtype=np.int64)
        for i in range(B):
            cand = pool_gids[i][pool_gids[i] >= 0].astype(np.int64)
            cand_total += cand.size
            shard_counts += np.asarray(_count_per_shard(self.plan, cand))
            sub = sims_for_ids(q[i], self.db_words, cand)  # exact float64
            order = np.lexsort((cand, -sub))[:k_eff]
            ids_out[i] = cand[order]
            sims_out[i] = sub[order]
        self.shard_launches += self.plan.num_shards
        per_shard = [
            {
                "shard": s,
                "rows": self.plan.counts[s],
                "candidates": int(shard_counts[s]),
                "launches": 1,
                "device": str(self.plan.device_for(s)),
            }
            for s in range(self.plan.num_shards)
        ]
        stats = EngineStats(
            backend=self.name, queries=B,
            per_query=[SearchStats(retrieved=self.n) for _ in range(B)],
            shards=self.plan.num_shards, per_shard=per_shard,
        )
        return ids_out, sims_out, stats

    # ------------------------------------------------------------ mesh mode
    def _candidates_mesh(self, q, k_fetch):
        """One shard_map launch: per-device scan + O(K) all-gather."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..kernels import ops
        from .distributed import sharded_scan_candidates

        if self._db_dev is None:
            axes = self.plan.axis_names or tuple(self.mesh.axis_names)
            self._db_dev = jax.device_put(
                self.plan.padded_layout(self.db_words),
                NamedSharding(self.mesh, P(axes)),
            )
        B = q.shape[0]
        Bp = ops.pad_bucket(B, minimum=8)
        qp = np.zeros((Bp, q.shape[1]), dtype=q.dtype)
        qp[:B] = q
        sims, gids = sharded_scan_candidates(
            self.mesh, jnp.asarray(qp), self._db_dev, self.plan, k_fetch,
            chunk=self.chunk,
        )
        return np.asarray(sims)[:B], np.asarray(gids)[:B]

    # ------------------------------------------------------------ host mode
    def _candidates_host(self, q, k_fetch):
        """No mesh: walk the shards as a host loop, each shard's slice
        resident on — and scanned on — its assigned plan device (all the
        same device on a single-device host, the pre-placement layout)."""
        import jax
        import jax.numpy as jnp

        from ..kernels import ops

        if not self._shard_dev:
            self._shard_dev = [
                jax.device_put(
                    self.db_words[self.plan.shard_slice(s)],
                    self.plan.device_for(s),
                )
                if self.plan.device_for(s) is not None
                else jnp.asarray(self.db_words[self.plan.shard_slice(s)])
                for s in range(self.plan.num_shards)
            ]
        B = q.shape[0]
        Bp = ops.pad_bucket(B, minimum=8)
        qp = np.zeros((Bp, q.shape[1]), dtype=q.dtype)
        qp[:B] = q
        qj = jnp.asarray(qp)
        sims_parts, gid_parts = [], []
        for s in range(self.plan.num_shards):
            count = self.plan.counts[s]
            if count == 0:
                continue
            sims, ids = ops.scan_topk(
                qj, self._shard_dev[s], min(k_fetch, count),
                chunk=self.chunk, use_pallas=ops.on_tpu(),
            )
            sims = np.asarray(sims)[:B]
            gids = np.asarray(ids)[:B].astype(np.int64)
            gids = np.where(sims > -np.inf, gids + self.plan.starts[s], -1)
            sims_parts.append(sims)
            gid_parts.append(gids)
        return (
            np.concatenate(sims_parts, axis=1),
            np.concatenate(gid_parts, axis=1),
        )


@register_engine
class ShardedAMIHEngine(SearchEngine):
    """AMIH over a row-sharded DB: one shard-local index per slice,
    sequential probing with the pooled k-th cosine as each next shard's
    early-termination bound, exact lexsort merge.

    Each shard's index is DEVICE-PLACED from the plan's assignment map
    (mesh-derived, an explicit ``devices`` list, or the local devices
    round-robin): its codes upload to — and its grouped candidate
    verification runs on — the shard's own device, so verify memory and
    bandwidth scale with the shard count instead of serializing through
    device 0. Only the O(K) per-shard result lists ever cross back to
    the host merge. ``stats.per_shard[s]["device"]`` records where each
    shard's work landed (``kernels.ops.LAUNCH_COUNTS_BY_DEVICE`` counts
    the launches per device).

    ``probe_workers`` switches shard probing from the sequential chain to
    the pipelined shard pool (repro.pipeline.shardpool): every shard
    probes concurrently — forked worker processes by default (the
    probing loop is too GIL-bound for threads on CPython;
    ``probe_mode="thread"`` selects the pool for free-threaded runtimes)
    — all reading ONE shared monotone per-query bound that every query
    raises the moment it fills its local K, and that ``prime_bound``
    warm-starts with the exact sims of a small deterministic row sample
    before any probing begins (the sequential chain gives shard 0 no
    bound at all). Still exact: the shared bound is always the k-th best
    sim of some subset of real rows, hence a valid lower bound on the
    global k-th (see shardpool.py). The pool is PERSISTENT: workers fork
    once, on the engine's first parallel call, and each later call ships
    its task over the standing worker pipes (``engine.close()`` releases
    them; GC does too).

    ``probe_backend="device"`` builds every shard index with the fused
    device probing walk (see core.probe_device), so the host probe pool
    stands down entirely — no workers ever fork. With ``probe_fused``
    (the default) the engine goes further and collapses the launch count
    to O(devices): the shards resident on each device are stacked into
    one per-device *super index* (concatenated rows + rebuilt CSR, local
    rows mapped back to global ids at extraction), every device's fused
    batch walk is dispatched WITHOUT blocking, and the host only syncs
    at the final O(K) merge — device-parallel probing that overlaps the
    next step's host-side encode in ``pipeline/stream.py``. Since the
    walk is shared, ``stats.per_shard[s]`` records the shared
    ``launch_id`` it participated in, the per-device launch count on the
    device group's LEAD shard, and 0 on the riders — summing
    ``launches`` over shards equals real dispatches, so serving
    dashboards don't over-count.
    """

    name = "sharded_amih"

    # Adaptive stand-down gates: the parallel pool only engages when the
    # host and the call can actually pay for it; everything else runs
    # the sequential chain (identical results — the pool is a schedule,
    # not an algorithm). Instance attributes, so tests/benches force the
    # pool on small fixtures by zeroing them.
    #   MIN_SHARD_ROWS — tiny shards are pure Python overhead (small
    #     buckets, no GIL-releasing bulk NumPy); worker startup plus the
    #     pool's weaker early bounds cost more than concurrency returns.
    #   MIN_CPUS — measured on a 2-HT-sibling host: the probing mix gets
    #     ~1.0x from a second hardware thread while fork/IPC and the
    #     pool's extra unbounded starts are pure cost, so below a real
    #     multicore the pool cannot win.
    #   MIN_BATCH — per-call worker startup (forks in process mode)
    #     amortizes over the batch; a 1-query call pays it all alone.
    PARALLEL_MIN_SHARD_ROWS = 4096
    PARALLEL_MIN_CPUS = 4
    PARALLEL_MIN_BATCH = 8

    def __init__(self, db_words, p, plan, indexes, enumeration_cap,
                 probe_workers: Optional[int] = None,
                 prime_bound: bool = True,
                 probe_mode: str = "auto",
                 probe_backend: str = "host",
                 probe_fused: bool = True):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.plan = plan
        self.indexes = indexes      # [(shard_id, AMIHIndex)] non-empty shards
        self.enumeration_cap = enumeration_cap
        self.probe_workers = probe_workers
        self.prime_bound = prime_bound
        self.probe_mode = probe_mode
        self.probe_backend = probe_backend
        self.probe_fused = probe_fused
        self._fused = None          # per-device super-index groups, lazy
        self._fused_seq = 0         # shared launch-id counter (S6)
        self._pool = None           # PersistentShardPool, forked on first use
        self._closed = False
        # guards _pool/_closed: a knn_batch racing close() must not
        # rebuild (and leak) a fresh worker pool on a closed engine
        self._pool_lock = threading.Lock()

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        mesh=None,
        num_shards: Optional[int] = None,
        shard_axes: Optional[Tuple[str, ...]] = None,
        plan: Optional[ShardPlan] = None,
        m: Optional[int] = None,
        verify_backend: str = "numpy",
        enumeration_cap: Optional[int] = None,
        probe_workers: Optional[int] = None,
        prime_bound: bool = True,
        probe_mode: str = "auto",
        probe_backend: str = "host",
        probe_stream_cap: int = 1 << 16,
        probe_fused: bool = True,
        devices=None,
        **cfg: Any,
    ) -> "ShardedAMIHEngine":
        if cfg:
            raise TypeError(f"unknown sharded_amih options: {sorted(cfg)}")
        db = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        plan = _resolve_plan(db, mesh, num_shards, shard_axes, plan,
                             devices)
        indexes = []
        for s in range(plan.num_shards):
            if plan.counts[s] == 0:
                continue
            # each shard's index is PLACED: its db_dev upload and its
            # grouped-verify launches target the shard's own device, so
            # verification memory/bandwidth scale with the shard count
            indexes.append((s, AMIHIndex.build(
                db[plan.shard_slice(s)], p, m=m,
                verify_backend=verify_backend, id_offset=plan.starts[s],
                device=plan.device_for(s),
                probe_backend=probe_backend,
                probe_stream_cap=probe_stream_cap,
                probe_fused=probe_fused,
            )))
        return cls(db, p, plan, indexes, enumeration_cap,
                   probe_workers, prime_bound, probe_mode, probe_backend,
                   probe_fused)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    def close(self) -> None:
        """Release the persistent probe-worker pool (idempotent; also run
        on GC, so engine churn never leaks forked workers). A closed
        engine still answers ``knn_batch`` — parallel calls fall back to
        the sequential chain instead of re-forking workers."""
        with self._pool_lock:
            self._closed = True
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass   # interpreter shutdown: pipes may already be gone

    def _use_parallel(self, B: int) -> bool:
        import multiprocessing

        # the device probe path runs each shard as one fused launch per
        # z-group — there is no host probing loop left to parallelize,
        # so the worker pool never forks for it
        if self.probe_backend == "device":
            return False
        # mean rows per non-empty shard: robust to one straggler shard
        # in an otherwise-large custom plan (min would stand the pool
        # down) without letting one big shard drag seven tiny ones into
        # worker startup they can't amortize (max would engage it)
        mean_rows = self.n / max(1, len(self.indexes))
        return bool(
            self.probe_workers and self.probe_workers > 1
            and len(self.indexes) > 1
            and B >= self.PARALLEL_MIN_BATCH
            and multiprocessing.cpu_count() >= self.PARALLEL_MIN_CPUS
            and mean_rows >= self.PARALLEL_MIN_SHARD_ROWS
        )

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        per_query = [AMIHStats() for _ in range(B)]
        if k_eff == 0:
            return (
                np.empty((B, 0), np.int64), np.empty((B, 0), np.float64),
                EngineStats(backend=self.name, queries=B,
                            per_query=per_query,
                            shards=self.plan.num_shards),
            )
        fuse_meta: Optional[Dict[int, Dict[str, Any]]] = None
        groups = self._fused_groups()
        if groups is not None:
            shard_out, fuse_meta = self._probe_device_fused(q, k_eff, groups)
        elif self._use_parallel(B):
            shard_out = self._probe_parallel(q, k_eff)
        else:
            shard_out = self._probe_sequential(q, k_eff)

        per_shard, gid_parts, sim_parts = self._fold_shard_out(
            shard_out, fuse_meta, per_query, B, k_eff
        )
        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        for i in range(B):
            gids = np.concatenate(gid_parts[i]) if gid_parts[i] \
                else np.empty(0, dtype=np.int64)
            sims = np.concatenate(sim_parts[i]) if sim_parts[i] \
                else np.empty(0, dtype=np.float64)
            order = np.lexsort((gids, -sims))[:k_eff]
            ids_out[i] = gids[order]
            sims_out[i] = sims[order]
        stats = EngineStats(
            backend=self.name, queries=B, per_query=per_query,
            shards=self.plan.num_shards, per_shard=per_shard,
            cache_info=probe_cache_snapshot(),
        )
        return ids_out, sims_out, stats

    def knn_batch_bounded(self, q_words, k, stop_below, on_done=None):
        """``knn_batch`` pruned by an external LIVE per-query floor — the
        engine-level form of ``AMIHIndex.knn_batch_bounded``, built for
        the cross-host tier (repro.cluster): each worker host runs its
        slice under the cluster-wide k-th-cosine floor, so a query whose
        global top-K already lives on other hosts stops probing here
        early. Results are RAGGED — a per-query ``(ids, sims)`` list
        holding this host's rows with sim >= the floor, possibly fewer
        than k when the floor pruned locally — plus the same
        ``EngineStats`` as ``knn_batch``.

        ``stop_below`` must be a float64 (B,) array; its entries may
        only ever RISE and must stay valid lower bounds on each query's
        global k-th cosine. The sequential chain re-reads it live (a
        remote raise prunes mid-shard) and raises it monotonically with
        the local pooled k-th; the fused-device and parallel-pool paths
        snapshot it at dispatch (a raise landing mid-flight costs time,
        never correctness) and raise it at the merge. ``on_done(qi, ids,
        sims)`` fires whenever query ``qi`` fills a local K (mid-probe
        on the sequential chain, at the merge everywhere) — the cluster
        worker publishes its local k-th through it."""
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        per_query = [AMIHStats() for _ in range(B)]
        if k_eff == 0:
            empty = (np.empty(0, np.int64), np.empty(0, np.float64))
            return [empty for _ in range(B)], EngineStats(
                backend=self.name, queries=B, per_query=per_query,
                shards=self.plan.num_shards,
            )
        floor = np.asarray(stop_below)
        if floor.dtype != np.float64 or floor.shape != (B,):
            raise ValueError(
                f"stop_below must be float64 of shape ({B},), got "
                f"{floor.dtype} {floor.shape} — the live no-copy "
                f"contract (see AMIHIndex.knn_batch_bounded)"
            )
        fuse_meta: Optional[Dict[int, Dict[str, Any]]] = None
        groups = self._fused_groups()
        if groups is not None:
            shard_out, fuse_meta = self._probe_device_fused(
                q, k_eff, groups, floor=floor
            )
        elif self._use_parallel(B):
            shard_out = self._probe_parallel(q, k_eff, floor=floor)
        else:
            shard_out = self._probe_sequential(
                q, k_eff, bounds=floor, on_done=on_done
            )
        per_shard, gid_parts, sim_parts = self._fold_shard_out(
            shard_out, fuse_meta, per_query, B, k_eff
        )
        results: List[Tuple[np.ndarray, np.ndarray]] = []
        for i in range(B):
            gids = np.concatenate(gid_parts[i]) if gid_parts[i] \
                else np.empty(0, dtype=np.int64)
            sims = np.concatenate(sim_parts[i]) if sim_parts[i] \
                else np.empty(0, dtype=np.float64)
            order = np.lexsort((gids, -sims))[:k_eff]
            ids_i, sims_i = gids[order], sims[order]
            results.append((ids_i, sims_i))
            if sims_i.size >= k_eff:
                kth = float(sims_i[-1])
                if kth > floor[i]:
                    floor[i] = kth
                if on_done is not None:
                    on_done(i, ids_i, sims_i)
        stats = EngineStats(
            backend=self.name, queries=B, per_query=per_query,
            shards=self.plan.num_shards, per_shard=per_shard,
            cache_info=probe_cache_snapshot(),
        )
        return results, stats

    def _fold_shard_out(self, shard_out, fuse_meta, per_query, B, k_eff):
        """Fold per-shard probe output in shard-id order regardless of
        probing order, so merged stats and results are deterministic
        either way. Returns (per_shard aggregates, per-query gid parts,
        per-query sim parts)."""
        per_shard: List[Dict[str, int]] = []
        gid_parts: List[List[np.ndarray]] = [[] for _ in range(B)]
        sim_parts: List[List[np.ndarray]] = [[] for _ in range(B)]
        for s, index in self.indexes:
            results, shard_stats, launches = shard_out[s]
            local_k = min(k_eff, index.n)
            early_stopped = 0
            for i, (r_ids, r_sims) in enumerate(results):
                if r_ids.size < local_k:
                    early_stopped += 1
                if r_ids.size:
                    gid_parts[i].append(r_ids)
                    sim_parts[i].append(r_sims)
                self._fold_stats(per_query[i], shard_stats[i])
            agg: Dict[str, int] = {
                "shard": s,
                "rows": index.n,
                # measured where the verifies ran (forked workers'
                # index counters never reach the parent's objects)
                "launches": launches,
                "early_stopped": early_stopped,
                "device": str(index.device),
                "probe_backend": index.probe_backend,
            }
            for counter in ("probes", "retrieved", "verified",
                            "tuples_processed", "fell_back_to_scan"):
                agg[counter] = sum(
                    int(getattr(st, counter)) for st in shard_stats
                )
            if fuse_meta is not None:
                # fused device path: every shard of a device group shares
                # one launch id; only the group's lead shard carries the
                # launch count and device-level counters, so summing
                # ``launches`` across shards equals real dispatches
                agg.update(fuse_meta.get(s, {}))
            per_shard.append(agg)
        return per_shard, gid_parts, sim_parts

    def _fused_groups(self):
        """Per-device super-index groups for the fused device path,
        built lazily on first use and cached for the engine lifetime.

        Returns None — and the caller falls back to the sequential
        chain — unless every shard index runs ``probe_backend="device"``
        with ``probe_fused`` and all shards agree on (m, stream cap), so
        a mixed or per-shard-tuned layout never silently changes shape.

        Each group stacks the shards resident on ONE device: a
        single-shard group reuses that shard's index outright; a
        multi-shard group builds a hidden *super index* over the
        concatenated row slices (local ids, ``id_offset=0``) with a
        ``row_to_gid`` map and shard ``edges`` for attribution. Because
        the plan hands out contiguous ascending row ranges in shard
        order, concat-row order equals global-id order within the
        device, so extraction order — hence the final lexsort merge —
        is bit-identical to the sequential per-shard path."""
        if (
            self.probe_backend != "device"
            or not self.probe_fused
            or not self.indexes
        ):
            return None
        if self._fused is not None:
            return self._fused
        from ..kernels import ops

        if (
            len({ix.m for _, ix in self.indexes}) > 1
            or len({ix.probe_stream_cap for _, ix in self.indexes}) > 1
            or not all(ix.probe_fused for _, ix in self.indexes)
            or not all(
                ix.probe_backend == "device" for _, ix in self.indexes
            )
        ):
            return None
        by_dev: Dict[str, Dict[str, Any]] = {}
        order: List[Dict[str, Any]] = []
        for s, ix in self.indexes:
            dkey = ops.device_key(ix.device)
            g = by_dev.get(dkey)
            if g is None:
                g = {"dkey": dkey, "device": ix.device, "shards": []}
                by_dev[dkey] = g
                order.append(g)
            g["shards"].append((s, ix))
        for g in order:
            shards = g["shards"]
            if len(shards) == 1:
                g["super"] = shards[0][1]
                g["row_to_gid"] = None
            else:
                db = np.concatenate([ix.db_words for _, ix in shards])
                g["super"] = AMIHIndex.build(
                    db, self.p, m=shards[0][1].m,
                    device=g["device"], probe_backend="device",
                    probe_stream_cap=shards[0][1].probe_stream_cap,
                )
                g["row_to_gid"] = np.concatenate([
                    np.arange(ix.n, dtype=np.int64) + ix.id_offset
                    for _, ix in shards
                ])
            g["edges"] = np.cumsum(
                [ix.n for _, ix in shards]
            ).astype(np.int64)
        self._fused = order
        return order

    def _probe_device_fused(self, q, k_eff, groups, floor=None):
        """One fused walk launch per DEVICE: dispatch every device group
        back-to-back without blocking, then resolve them in turn — the
        host only syncs per device at extraction time, so all devices
        probe concurrently. ``prime_bound`` warm-starts every group with
        the exact k-th sim of a deterministic row sample (each group is
        probed independently, so no cross-shard bound chaining exists to
        lean on); an external ``floor`` (the cluster-wide bound) is
        SNAPSHOTTED at dispatch and max-folded in. Returns (shard_out,
        fuse_meta): per-shard result lists split out of each device's
        super index, stats and launch counts attributed to the group's
        lead shard (S6)."""
        from ..core import probe_device
        from ..pipeline.shardpool import prime_ids

        B = q.shape[0]
        bounds = None
        if self.prime_bound:
            sample = prime_ids(self.n, k_eff)
            if sample.size >= k_eff:
                cut = sample.size - k_eff
                bounds = np.empty(B, dtype=np.float64)
                for i in range(B):
                    sims_i = sims_for_ids(q[i], self.db_words, sample)
                    bounds[i] = np.partition(sims_i, cut)[cut]
        if floor is not None:
            snap = np.array(floor, dtype=np.float64, copy=True)
            bounds = snap if bounds is None else np.maximum(bounds, snap)
        pend = []
        for g in groups:
            sup = g["super"]
            pend.append((
                sup.verify_launches,
                probe_device.dispatch_groups_device(
                    sup, q, min(k_eff, sup.n), stop_below=bounds
                ),
            ))
        shard_out: Dict[int, Tuple[list, list, int]] = {}
        fuse_meta: Dict[int, Dict[str, Any]] = {}
        for g, (l0, pending) in zip(groups, pend):
            sup = g["super"]
            dstats = [AMIHStats() for _ in range(B)]
            states = probe_device.resolve_groups_device(
                sup, pending, dstats
            )
            launches = sup.verify_launches - l0
            shards = g["shards"]
            lead_ix = shards[0][1]
            if len(shards) > 1:
                # the hidden super index did the probing; surface its
                # launches on the lead shard's index so process-wide
                # counters that sum engine.indexes stay truthful
                lead_ix.verify_launches += launches
            self._fused_seq += 1
            lid = f"fused:{g['dkey']}#{self._fused_seq}"
            res_by: List[List[Any]] = [[None] * B for _ in shards]
            for st in states:           # states arrive qi-ordered
                rows = st.out_ids
                sims = np.asarray(st.out_sims, dtype=np.float64)
                if g["row_to_gid"] is None:
                    owner = np.zeros(rows.size, dtype=np.int64)
                    gids = rows + lead_ix.id_offset
                else:
                    owner = np.searchsorted(g["edges"], rows, side="right")
                    gids = g["row_to_gid"][rows]
                for j in range(len(shards)):
                    sel = owner == j
                    res_by[j][st.qi] = (gids[sel], sims[sel])
            for j, (s, _ix) in enumerate(shards):
                stats_j = dstats if j == 0 else [
                    AMIHStats() for _ in range(B)
                ]
                shard_out[s] = (res_by[j], stats_j,
                                launches if j == 0 else 0)
                fuse_meta[s] = {
                    "launch_id": lid,
                    "fused_shards": len(shards),
                }
        return shard_out, fuse_meta

    def _probe_sequential(self, q, k_eff, bounds=None, on_done=None):
        """PR 3's chain: shards probed one after another, each next shard
        bounded by the pooled k-th cosine of everything seen so far.
        ``bounds`` may be a caller-owned LIVE float64 (B,) array (the
        cluster-wide floor): each shard's bounded search re-reads it per
        tuple step, and the chain's pooled-k-th writes are MONOTONE
        raises — a concurrently-raised remote value is never lowered."""
        B = q.shape[0]
        shard_out: Dict[int, Tuple[list, list, int]] = {}
        sim_parts: List[List[np.ndarray]] = [[] for _ in range(B)]
        if bounds is None:
            bounds = np.full(B, -np.inf)
        for s, index in self.indexes:
            shard_stats = [AMIHStats() for _ in range(B)]
            launches0 = index.verify_launches
            results = index.knn_batch_bounded(
                q, k_eff, stop_below=bounds, stats=shard_stats,
                enumeration_cap=self.enumeration_cap, on_done=on_done,
            )
            for i, (r_ids, r_sims) in enumerate(results):
                if r_ids.size:
                    sim_parts[i].append(r_sims)
                total = sum(a.size for a in sim_parts[i])
                if total >= k_eff:
                    pool = np.concatenate(sim_parts[i]) if \
                        len(sim_parts[i]) > 1 else sim_parts[i][0]
                    # pooled k-th best cosine: sims strictly below it can
                    # never enter the global top-K of query i
                    b = np.partition(pool, total - k_eff)[total - k_eff]
                    if b > bounds[i]:
                        bounds[i] = b
            shard_out[s] = (results, shard_stats,
                            index.verify_launches - launches0)
        return shard_out

    def _probe_pool(self):
        """The engine's PersistentShardPool, built once: workers fork on
        the first parallel call and persist for the engine lifetime
        (``close()`` releases them). Returns None on a closed engine —
        the caller falls back to the sequential chain rather than
        re-forking workers nothing will ever release."""
        with self._pool_lock:
            if self._closed:
                return None
            if self._pool is None:
                from ..pipeline.shardpool import (
                    PersistentShardPool,
                    resolve_probe_mode,
                )

                mode = resolve_probe_mode(self.probe_mode)
                if mode == "process" and any(
                    ix.verify_backend == "pallas" for _, ix in self.indexes
                ):
                    # a fork-child of a jax-initialized parent must never
                    # dispatch jax ops (deadlock risk); device
                    # verification also releases the GIL, so threads are
                    # the right pool for the mesh-resident verify path
                    mode = "thread"
                self._pool = PersistentShardPool(
                    self.indexes, AMIHStats,
                    max_workers=self.probe_workers, mode=mode,
                )
            return self._pool

    def _probe_parallel(self, q, k_eff, floor=None):
        """Pipelined shard pool: all shards probe concurrently under one
        shared monotone bound, warm-started from a row sample (and from
        a SNAPSHOT of the external cluster ``floor``, when given). The
        pool is persistent — forked once per engine lifetime, each call
        ships its task over the standing worker pipes."""
        from ..pipeline.shardpool import SharedBound, prime_ids

        pool = self._probe_pool()
        if pool is None:               # engine closed: no new workers
            return self._probe_sequential(q, k_eff, bounds=floor)
        B = q.shape[0]
        shared = SharedBound(B, k_eff)
        if self.prime_bound:
            sample = prime_ids(self.n, k_eff)
            for i in range(B):
                shared.offer(i, sample, sims_for_ids(
                    q[i], self.db_words, sample
                ))
        if floor is not None:
            for i in range(B):
                f = float(floor[i])
                if f > -np.inf:
                    shared.raise_to(i, f)
        try:
            return pool.probe(
                q, k_eff, shared, enumeration_cap=self.enumeration_cap
            )
        except RuntimeError:
            if pool._closed:           # close() won the race mid-call:
                return self._probe_sequential(q, k_eff)
            raise                      # a genuinely broken pool

    @staticmethod
    def _fold_stats(into: AMIHStats, src: AMIHStats) -> None:
        into.probes += src.probes
        into.retrieved += src.retrieved
        into.verified += src.verified
        into.tuples_processed += src.tuples_processed
        into.substring_tuples_probed += src.substring_tuples_probed
        into.max_radius = max(into.max_radius, src.max_radius)
        into.exceeded_rhat |= src.exceeded_rhat
        into.fell_back_to_scan |= src.fell_back_to_scan
