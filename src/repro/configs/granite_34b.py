"""granite-34b [dense]: deep MQA code model (llama-arch).

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152, head_dim=128.
[arXiv:2405.04324; hf]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, q_chunk=16, kv_chunk=16,
    )
