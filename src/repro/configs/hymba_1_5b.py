"""hymba-1.5b [hybrid]: parallel attention + Mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16,
head_dim=64; SSM branch: 25 heads x 64 = 1600 inner width. Sliding-window
attention (2048) in the attention branch enables long_500k decode with a
ring-buffer KV cache. [arXiv:2411.13676; hf] Meta-tokens and the paper's
per-head fusion are simplified to learned per-channel branch gates
(recorded in DESIGN.md).
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=64,
    sliding_window=2048,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=8, ssm_heads=4, ssm_head_dim=16,
        sliding_window=32, q_chunk=16, kv_chunk=16,
    )
