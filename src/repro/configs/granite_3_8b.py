"""granite-3-8b [dense]: GQA decoder.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155, head_dim=128.
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, q_chunk=16, kv_chunk=16,
    )
