"""mamba2-1.3b [ssm]: attention-free SSD (state-space duality).

48L d_model=2048, d_inner=4096 (expand 2), 64 SSM heads x 64, ssm_state=128,
no MLP (d_ff=0), vocab=50280. [arXiv:2405.21060; unverified]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=0.0,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_heads=4, ssm_head_dim=16,
    )
