"""Assigned-architecture registry: one module per architecture.

``get_config(name)`` returns the exact published configuration;
``get_tiny(name)`` returns the reduced same-family config used by CPU smoke
tests (full configs are exercised only by the allocation-free dry-run).
"""

from __future__ import annotations

import importlib
from typing import List

from ..models.common import ArchConfig

ARCH_IDS: List[str] = [
    "llava_next_34b",
    "kimi_k2_1t_a32b",
    "arctic_480b",
    "whisper_tiny",
    "granite_3_8b",
    "llama3_8b",
    "granite_34b",
    "gemma_2b",
    "hymba_1_5b",
    "mamba2_1_3b",
]

# canonical dashed ids from the assignment -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCH_IDS:
        raise KeyError(
            f"unknown architecture {name!r}; available: {sorted(ALIASES)}"
        )
    return importlib.import_module(f".{name}", __name__)


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_tiny(name: str) -> ArchConfig:
    return _module(name).tiny()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
