"""whisper-tiny [audio]: encoder-decoder, conv frontend stubbed.

4L (enc) + 4L (dec), d_model=384, 6H (MHA kv=6), d_ff=1536, vocab=51865,
encoder_seq=1500 (30 s of mel frames after the conv stem, which is the
assignment-mandated stub: input_specs() provides frame embeddings).
[arXiv:2212.04356; unverified]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    rope_theta=0.0,            # sinusoidal positions
    encoder_seq=1500,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, encoder_seq=24,
        q_chunk=16, kv_chunk=16,
    )
