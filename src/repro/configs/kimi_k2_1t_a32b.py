"""kimi-k2-1t-a32b [moe]: trillion-param MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048, MoE 384 experts top-8,
vocab=163840, 1 leading dense layer + 1 shared expert (modeled as the
dense-residual FFN), head_dim=112. [arXiv:2501.kimi2; unverified]
bf16 params: 1T params do not fit 512 x 16 GB in f32.
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=50_000.0,
    n_experts=384,
    experts_per_token=8,
    first_k_dense=1,
    moe_dense_residual_ff=2048,   # shared expert
    capacity_factor=1.25,
    param_dtype="bfloat16",
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, experts_per_token=2,
        first_k_dense=1, moe_dense_residual_ff=64,
        param_dtype="float32", q_chunk=16, kv_chunk=16,
    )
