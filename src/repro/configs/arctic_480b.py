"""arctic-480b [moe]: dense-MoE hybrid, 128 experts top-2 + dense residual.

35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128e top-2, vocab=32000,
head_dim=128. [hf:Snowflake/snowflake-arctic-base; hf]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual_ff=4864,   # arctic's parallel dense FFN
    capacity_factor=1.25,
    param_dtype="bfloat16",
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, n_experts=8, experts_per_token=2,
        moe_dense_residual_ff=96, param_dtype="float32",
        q_chunk=16, kv_chunk=16,
    )
