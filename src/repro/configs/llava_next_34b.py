"""llava-next-34b [vlm]: Yi-34B-class backbone + anyres vision frontend (stub).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, head_dim=128.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] — backbone only; the
anyres tiling / CLIP tower is stubbed: input_specs() provides precomputed
patch embeddings (vision_tokens per sequence) fused before the text tokens.
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    vision_tokens=576,          # one base-resolution tile (stub for anyres)
    param_dtype="bfloat16",
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, vision_tokens=8,
        param_dtype="float32", q_chunk=16, kv_chunk=16,
    )
