"""Beyond-paper optimized execution profiles (§Perf winners).

``optimized_overrides(arch)`` returns the ArchConfig overrides that won the
hillclimb for each architecture; ``optimized_opt_rules()`` returns the
ZeRO-2-style optimizer-state sharding rules (K5). The baseline (published
config, default rules) stays the default everywhere — profiles are opt-in:

    python -m repro.launch.dryrun --all --profile optimized ...

Provenance of each knob is the §Perf log in EXPERIMENTS.md:
  K1  gather-based MoE dispatch (code-level, always on)
  K4  blocked cross-entropy        -> ce_chunk for 100k+ vocabs
  K5  ZeRO-2 moment sharding       -> opt rules embed->data
  L1  larger attention chunks      -> q_chunk/kv_chunk
  L3  TP head padding              -> pad_heads_to_multiple=16
"""

from __future__ import annotations

from typing import Dict

from ..models.sharding import DEFAULT_RULES

_BIG_VOCAB = 100_000

_PER_ARCH: Dict[str, Dict] = {
    "llava_next_34b": {"pad_heads_to_multiple": 16, "q_chunk": 4096,
                       "kv_chunk": 8192},
    "arctic_480b": {"pad_heads_to_multiple": 16, "q_chunk": 4096,
                    "kv_chunk": 8192},
    "kimi_k2_1t_a32b": {"q_chunk": 4096, "kv_chunk": 8192},
    "granite_3_8b": {"q_chunk": 4096, "kv_chunk": 8192},
    "granite_34b": {"q_chunk": 4096, "kv_chunk": 8192},
    "llama3_8b": {"q_chunk": 4096, "kv_chunk": 8192},
    "gemma_2b": {"q_chunk": 4096, "kv_chunk": 8192},
    # 25 heads / kv 5: TP head padding needs lcm(16,5)=80 heads (>3x) — not
    # worth the distortion; the chunk lever alone gives 2.85x (§Perf H2)
    "hymba_1_5b": {"q_chunk": 4096, "kv_chunk": 4096},
    "mamba2_1_3b": {},    # attention-free
    "whisper_tiny": {},   # 6-head MHA on a 384-wide model: leave exact
}


def optimized_overrides(arch: str) -> Dict:
    from . import ALIASES, get_config

    arch = ALIASES.get(arch, arch)
    over = dict(_PER_ARCH.get(arch, {}))
    cfg = get_config(arch)
    if cfg.vocab_size >= _BIG_VOCAB:
        over.setdefault("ce_chunk", 8192)
    return over


def optimized_opt_rules() -> Dict:
    """ZeRO-2: optimizer moments additionally sharded over the data axes
    on their embed dim (K5: kimi-k2 args 605 -> 151 GiB/device)."""
    rules = dict(DEFAULT_RULES)
    rules["embed"] = ("data",)
    return rules
