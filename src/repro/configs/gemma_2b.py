"""gemma-2b [dense]: GeGLU, head_dim=256 (> d_model/heads), MQA, tied embeds.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000, head_dim=256.
[arXiv:2403.08295; hf]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=256, q_chunk=16, kv_chunk=16,
    )
