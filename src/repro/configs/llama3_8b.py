"""llama3-8b [dense]: GQA + 128k vocab (embedding-sharding stress).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, head_dim=128.
[arXiv:2407.21783; unverified]
"""

from ..models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500_000.0,
)


def tiny() -> ArchConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, q_chunk=16, kv_chunk=16,
    )
