"""Structural parser for XLA optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every while-loop body
ONCE — a 61-layer ``lax.scan`` stack is undercounted ~61x, which would make
every roofline number garbage. XLA *does* annotate each while op with
``backend_config={"known_trip_count":{"n":...}}`` in optimized HLO, so this
module rebuilds costs structurally:

  1. split the module into computations,
  2. resolve every op's output shape (operands are ``%name`` references),
  3. walk the call graph from ENTRY, multiplying by while trip counts,
  4. accumulate, per computation multiplicity:
       - matmul FLOPs from ``dot``/``convolution`` ops
         (2 x prod(output) x prod(contracted lhs dims)),
       - an HBM-traffic model: for every top-level op that is not free
         (parameter/constant/tuple/get-tuple-element/bitcast/...), bytes =
         operand bytes + output bytes. Fusion internals are excluded — a
         fusion op is one read-inputs/write-outputs kernel, exactly the
         roofline model of fused execution,
       - collective bytes by op kind (all-reduce / all-gather /
         reduce-scatter / all-to-all / collective-permute), operand sizes.

Shapes in optimized SPMD HLO are PER-DEVICE shards, so every number this
parser emits is per-device; analysis.py turns them into aggregate terms.

The parser is validated against cost_analysis() on scan-free modules (where
cost_analysis is correct) in tests/test_roofline.py.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCosts", "parse_hlo_costs"]

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e8m0fnu": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "tuple": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

# ops that cost nothing (aliasing / metadata / control)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "rng-get-and-update-state",
    "opt-barrier", "domain", "add-dependency",
}
# ops whose cost is their callees' (recursed), not the op line itself
_CONTROL_OPS = {"while", "conditional", "call", "async-start", "async-done"}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# named_scope tags planted by the model code (models/layers.py,
# models/blocks.py, models/ssm.py, optim/adamw.py)
_SCOPE_TAGS = (
    "flash_attn", "decode_attn", "moe", "mlp", "ssd", "adamw", "ce_loss",
)


@dataclass
class _Op:
    name: str
    kind: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operand_names: List[str]
    attrs: str                       # raw trailing attribute text


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)


@dataclass
class HloCosts:
    """Per-device costs of one compiled module (trip-count scaled)."""

    flops: float = 0.0                       # matmul/conv FLOPs
    hbm_bytes: float = 0.0                   # modeled HBM traffic
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_ops: Dict[str, int] = field(default_factory=dict)
    n_whiles: int = 0
    trip_counts: List[int] = field(default_factory=list)
    dot_flops_by_meta: Dict[str, float] = field(default_factory=dict)
    # HBM bytes bucketed by named_scope tag found in op metadata
    # (flash_attn / moe / mlp / ssd / adamw / <other>)
    hbm_bytes_by_scope: Dict[str, float] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _shape_bytes(dtype: str, dims: Tuple[int, ...]) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims:
        n *= d
    return n * b


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((m.group(1), dims))
    return out


def _balanced(text: str, start: int) -> int:
    """Index just past the paren group opening at ``start`` ('(')."""
    depth = 0
    for i in range(start, len(text)):
        c = text[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


_OP_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str) -> Optional[_Op]:
    m = _OP_LINE_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # output shape: tuple '(...)' or single token
    if rest.startswith("("):
        end = _balanced(rest, 0)
        out_txt = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        out_txt = rest[:sp]
        rest = rest[sp + 1:]
    out_shapes = _parse_shapes(out_txt)
    km = re.match(r"([\w\-]+)\(", rest)
    if km is None:
        return None
    kind = km.group(1)
    args_end = _balanced(rest, km.end() - 1)
    args_txt = rest[km.end(): args_end - 1]
    attrs = rest[args_end:]
    operands = re.findall(r"%([\w.\-]+)", args_txt)
    return _Op(name, kind, out_shapes, operands, attrs)


def _split_computations(text: str) -> Tuple[List[_Computation], str]:
    """Parse all computations; returns (computations, entry_name)."""
    comps: List[_Computation] = []
    entry = ""
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        hdr = re.match(
            r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped
        )
        if hdr and not line.startswith(" " * 2):
            cur = _Computation(name=hdr.group(2))
            comps.append(cur)
            if hdr.group(1):
                entry = cur.name
            continue
        if stripped == "}":
            # computation end (op lines are indented; braces in op lines
            # never sit alone on a line)
            continue
        if cur is not None and "%" in stripped and "=" in stripped:
            op = _parse_op_line(line)
            if op is not None:
                cur.ops.append(op)
    return comps, entry


def _trip_count(op: _Op) -> int:
    m = re.search(r'"known_trip_count":\{"n":"?(\d+)"?\}', op.attrs)
    if m:
        return int(m.group(1))
    return 1


def _callee(op: _Op, key: str) -> Optional[str]:
    m = re.search(key + r"=%([\w.\-]+)", op.attrs)
    return m.group(1) if m else None


def _dot_flops(
    op: _Op, shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]]
) -> float:
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
    lhs = shapes.get(op.operand_names[0]) if op.operand_names else None
    contracted = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if lhs and m and m.group(1):
        lhs_dims = lhs[0][1]
        for idx in (int(x) for x in m.group(1).split(",")):
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * out_elems * contracted


def _conv_flops(
    op: _Op, shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]]
) -> float:
    # 2 * output elements * (kernel spatial x input channels)
    out_elems = 1
    for _, dims in op.out_shapes:
        for d in dims:
            out_elems *= d
    rhs = shapes.get(op.operand_names[1]) if len(op.operand_names) > 1 else None
    k = 1
    if rhs:
        for d in rhs[0][1]:
            k *= d
        # divide by output-feature dim (approx: kernel = spatial*in_c*out_c)
        out_c = rhs[0][1][-1] if rhs[0][1] else 1
        k = max(1, k // max(out_c, 1))
    return 2.0 * out_elems * k


def parse_hlo_costs(hlo_text: str) -> HloCosts:
    comps, entry = _split_computations(hlo_text)
    by_name = {c.name: c for c in comps}

    # pass 1: global op-name -> output shapes (names are unique module-wide)
    shapes: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
    for c in comps:
        for op in c.ops:
            shapes[op.name] = op.out_shapes

    # pass 2: computation multiplicities from the call graph
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    # breadth-first; while/call/conditional create edges
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = by_name.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            edges: List[Tuple[str, float]] = []
            if op.kind == "while":
                tc = _trip_count(op)
                body = _callee(op, "body")
                cond = _callee(op, "condition")
                if body:
                    edges.append((body, m * tc))
                if cond:
                    edges.append((cond, m * (tc + 1)))
            elif op.kind == "conditional":
                for br in re.findall(
                    r"(?:branch_computations=\{([^}]*)\}|true_computation=%([\w.\-]+)|false_computation=%([\w.\-]+))",
                    op.attrs,
                ):
                    for g in br:
                        if not g:
                            continue
                        for nm in re.findall(r"%?([\w.\-]+)", g):
                            edges.append((nm, m))
            elif op.kind == "call":
                to = _callee(op, "to_apply")
                if to:
                    edges.append((to, m))
            for tgt, tm in edges:
                if tgt in mult:
                    mult[tgt] += tm
                else:
                    mult[tgt] = tm
                    order.append(tgt)

    costs = HloCosts()
    for c in comps:
        m = mult.get(c.name)
        if m is None:
            continue  # fusion body / reduce applier: not HBM-visible
        for op in c.ops:
            if op.kind == "while":
                costs.n_whiles += 1
                costs.trip_counts.append(_trip_count(op))
            if op.kind in _FREE_OPS or op.kind in _CONTROL_OPS:
                continue
            out_bytes = sum(_shape_bytes(t, d) for t, d in op.out_shapes)
            in_bytes = 0
            for nm in op.operand_names:
                for t, d in shapes.get(nm, []):
                    in_bytes += _shape_bytes(t, d)
            op_bytes = m * (out_bytes + in_bytes)
            costs.hbm_bytes += op_bytes
            scope = "other"
            meta = re.search(r'op_name="([^"]*)"', op.attrs)
            if meta:
                path = meta.group(1)
                for tag in _SCOPE_TAGS:
                    if tag in path:
                        scope = tag
                        break
            costs.hbm_bytes_by_scope[scope] = (
                costs.hbm_bytes_by_scope.get(scope, 0.0) + op_bytes
            )

            base = op.kind.replace("-start", "")
            if base in _COLLECTIVES:
                costs.collective_bytes[base] = (
                    costs.collective_bytes.get(base, 0.0) + m * in_bytes
                )
                costs.collective_ops[base] = (
                    costs.collective_ops.get(base, 0) + 1
                )
            elif op.kind == "dot":
                f = m * _dot_flops(op, shapes)
                costs.flops += f
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                key = meta.group(1) if meta else op.name
                costs.dot_flops_by_meta[key] = (
                    costs.dot_flops_by_meta.get(key, 0.0) + f
                )
            elif op.kind == "convolution":
                costs.flops += m * _conv_flops(op, shapes)
    return costs
