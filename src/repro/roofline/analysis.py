"""Three-term roofline from a compiled dry-run artifact.

    compute_s    = agg_FLOPs  / (chips * peak_flops)
    memory_s     = agg_bytes  / (chips * hbm_bw)
    collective_s = agg_coll_bytes / (chips * link_bw)

The parser (hlo_parse) yields PER-DEVICE numbers (SPMD shapes are shards);
aggregate = per_device * chips, so each term reduces to
per_device_quantity / per_chip_bandwidth — reported both ways for clarity.

Hardware model (TPU v5e-class, assignment constants):
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, 50 GB/s/link ICI.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from .hlo_parse import HloCosts, parse_hlo_costs
from .model_flops import model_flops
from ..models.common import ArchConfig, ShapeConfig

__all__ = ["HW", "RooflineReport", "analyze"]


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # B/s per chip
    link_bw: float = 50e9             # B/s per ICI link


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities (from the SPMD program)
    device_flops: float
    device_hbm_bytes: float
    device_collective_bytes: float
    collective_breakdown: Dict[str, float]
    # the three terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops: float
    hlo_total_flops: float            # aggregate over chips
    useful_ratio: float               # model_flops / hlo_total_flops
    # memory feasibility (from compiled.memory_analysis)
    bytes_per_device: float
    fits: bool
    # context
    n_whiles: int = 0
    note: str = ""
    hbm_bytes_by_scope: Dict[str, float] = None
    # L2 substitution: memory term with the parsed flash_attn scope
    # replaced by the fused Pallas kernel's analytic HBM traffic
    memory_s_fused_attn: float = 0.0
    dominant_fused_attn: str = ""

    @property
    def step_s(self) -> float:
        """Roofline-optimistic step time (terms fully overlapped)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound_fraction(self) -> Dict[str, float]:
        t = self.step_s
        if t <= 0:
            return {}
        return {
            "compute": self.compute_s / t,
            "memory": self.memory_s / t,
            "collective": self.collective_s / t,
        }

    def to_json(self) -> str:
        d = asdict(self)
        d["step_s"] = self.step_s
        return json.dumps(d)


def analyze(
    cfg: ArchConfig,
    shape: ShapeConfig,
    mesh_name: str,
    chips: int,
    hlo_text: str,
    bytes_per_device: float,
    hbm_per_chip: float = 16e9,       # v5e: 16 GB
    hw: HW = HW(),
    note: str = "",
    costs: Optional[HloCosts] = None,
) -> RooflineReport:
    if costs is None:
        costs = parse_hlo_costs(hlo_text)
    mf = model_flops(cfg, shape)
    agg_flops = costs.flops * chips
    compute_s = costs.flops / hw.peak_flops          # == agg/(chips*peak)
    memory_s = costs.hbm_bytes / hw.hbm_bw
    collective_s = costs.total_collective_bytes / hw.link_bw
    terms = {
        "compute": compute_s,
        "memory": memory_s,
        "collective": collective_s,
    }
    dominant = max(terms, key=terms.get)
    # fused-attention substitution (kernels/flash_attention.py): replace
    # the parsed flash_attn scope bytes by the kernel's analytic traffic
    from .model_flops import flash_io_bytes_per_device

    fused_io = flash_io_bytes_per_device(cfg, shape)
    if fused_io > 0:
        scope_attn = costs.hbm_bytes_by_scope.get(
            "flash_attn", 0.0
        ) + costs.hbm_bytes_by_scope.get("decode_attn", 0.0)
        fused_bytes = costs.hbm_bytes - scope_attn + fused_io
    else:  # kernel not applicable (train bwd unfused / no attention)
        fused_bytes = costs.hbm_bytes
    memory_s_fused = fused_bytes / hw.hbm_bw
    terms_fused = dict(terms, memory=memory_s_fused)
    dominant_fused = max(terms_fused, key=terms_fused.get)
    return RooflineReport(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        device_flops=costs.flops,
        device_hbm_bytes=costs.hbm_bytes,
        device_collective_bytes=costs.total_collective_bytes,
        collective_breakdown=dict(costs.collective_bytes),
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        hlo_total_flops=agg_flops,
        useful_ratio=mf / agg_flops if agg_flops else 0.0,
        bytes_per_device=bytes_per_device,
        fits=bytes_per_device <= hbm_per_chip,
        n_whiles=costs.n_whiles,
        note=note,
        hbm_bytes_by_scope=dict(costs.hbm_bytes_by_scope),
        memory_s_fused_attn=memory_s_fused,
        dominant_fused_attn=dominant_fused,
    )
