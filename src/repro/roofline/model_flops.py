"""Analytic MODEL_FLOPS per (architecture, shape) — the 'useful compute'
yardstick the roofline report compares against compiled HLO FLOPs.

Conventions (assignment-mandated):
  train:    6 * N * D      (N = params; MoE: active params per token)
  prefill:  2 * N * D
  decode:   2 * N * B per emitted token, plus the KV-cache attention term
            4 * B * S_ctx * Hq * Dh per attention layer (score + value),
            or the O(1) SSD state term for ssm/hybrid.
D = global_batch * seq_len tokens.
"""

from __future__ import annotations

from ..models.common import ArchConfig, ShapeConfig

__all__ = ["model_flops"]


def _decode_attn_flops(cfg: ArchConfig, B: int, S: int) -> float:
    """Per-token attention-over-cache FLOPs across layers."""
    if not cfg.has_attention:
        return 0.0
    ctx = S
    if cfg.family == "hybrid" and cfg.sliding_window:
        ctx = min(S, cfg.sliding_window)
    per_layer = 4.0 * B * ctx * cfg.n_heads * cfg.head_dim_
    return per_layer * cfg.n_layers


def _decode_ssm_flops(cfg: ArchConfig, B: int) -> float:
    if not cfg.has_ssm:
        return 0.0
    H, P, N = cfg.ssm_heads_, cfg.ssm_head_dim, cfg.ssm_state
    # state update + output contraction per token per layer
    per_layer = B * (2.0 * H * P * N + 2.0 * H * P * N)
    return per_layer * cfg.n_layers


def flash_io_bytes_per_device(
    cfg: ArchConfig,
    shape: ShapeConfig,
    dp: int = 16,
    tp: int = 16,
    q_blk: int = 512,
) -> float:
    """Per-device HBM bytes of the fused flash-attention kernel
    (kernels/flash_attention.py) for one step — the L2 substitution the
    roofline applies to the parsed ``flash_attn`` scope.

    Traffic model (flash-v2): q and o move once; k/v stream once per
    q-block row of the grid; causal masking halves the live kv tiles.

    Covered kinds:
      prefill — flash forward kernel (q+o once, k/v per q-row, causal half)
      decode  — flash-DECODE kernel (``valid_len``): ONE pass over the
                valid KV cache per layer + tiny q/o
    Train returns 0 (not substituted): the kernel is forward-only; its
    backward recomputes through the pure-JAX oracle, re-materializing
    scores, so substituting fused traffic into train cells would lie.
    """
    if not cfg.has_attention or shape.kind == "train":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    b_loc = B // dp if B % dp == 0 else B
    hq_all = cfg.n_heads_padded
    hq = hq_all // tp if hq_all % tp == 0 else hq_all
    hkv = cfg.n_kv_heads // tp if cfg.n_kv_heads % tp == 0 else cfg.n_kv_heads
    d = cfg.head_dim_
    bpe = 2  # bf16 on the TPU target
    if shape.kind == "decode":
        ctx = S
        if cfg.family == "hybrid" and cfg.sliding_window:
            ctx = min(S, cfg.sliding_window)  # ring buffer cache
        kv = 2 * b_loc * ctx * hkv * d * bpe       # one fused cache pass
        qo = 2 * b_loc * 1 * hq * d * bpe
        return (kv + qo) * cfg.n_layers
    nq = -(-S // q_blk)
    causal_frac = 0.5
    qo = 2 * b_loc * S * hq * d * bpe
    kv = 2 * b_loc * S * hkv * d * bpe * nq * causal_frac
    return (qo + kv) * cfg.n_layers


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * N * B * S
    if shape.kind == "prefill":
        return 2.0 * N * B * S
    # decode: one token against an S-token cache
    return (
        2.0 * N * B
        + _decode_attn_flops(cfg, B, S)
        + _decode_ssm_flops(cfg, B)
    )
