"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

``hlo_parse``   structural parser of optimized HLO text: per-computation op
                costs, while-loop trip-count scaling, collective byte
                accounting.
``analysis``    the three roofline terms + dominant-bottleneck report.
``model_flops`` analytic MODEL_FLOPS (6ND / 2ND / decode) per architecture.
"""

from .analysis import HW, RooflineReport, analyze
from .hlo_parse import HloCosts, parse_hlo_costs
from .model_flops import model_flops

__all__ = [
    "HW",
    "HloCosts",
    "RooflineReport",
    "analyze",
    "model_flops",
    "parse_hlo_costs",
]
