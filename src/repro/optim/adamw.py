"""AdamW with cosine schedule, global-norm clipping, and optional
block-quantized (int8) second moments — the memory-side distributed trick
that lets trillion-parameter MoE optimizer state fit the pod
(f32 moments for kimi-k2: 2 x 4 TB; int8 + per-block scales: ~1.06 TB).

Pure-pytree implementation (no optax dependency): states mirror the param
tree so the same sharding rules apply leaf-by-leaf.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 200
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantized_moments: bool = False   # int8 second moments (block=128)
    moment_block: int = 128


class QuantMoment(NamedTuple):
    """int8 payload + per-block f32 scales (flat layout + pad)."""

    q: jax.Array       # (padded_size,) int8
    scale: jax.Array   # (padded_size / block,) f32


def lr_at(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


# ---------------------------------------------------------- quantization
def _quant(x: jax.Array, block: int) -> QuantMoment:
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]).astype(jnp.int8)
    return QuantMoment(q=q.reshape(-1), scale=scale)


def _dequant(qm: QuantMoment, shape, block: int) -> jax.Array:
    blocks = qm.q.reshape(-1, block).astype(jnp.float32)
    flat = (blocks * qm.scale[:, None]).reshape(-1)
    return flat[: math.prod(shape)].reshape(shape)


# The second moment is non-negative with a huge dynamic range; quantizing
# sqrt(nu) (8-bit-Adam style) halves the log-range, so the int8 grid error
# lands on the Adam denominator roughly linearly instead of quadratically.
def _quant_nu(x: jax.Array, block: int) -> QuantMoment:
    return _quant(jnp.sqrt(jnp.maximum(x, 0.0)), block)


def _dequant_nu(qm: QuantMoment, shape, block: int) -> jax.Array:
    r = _dequant(qm, shape, block)
    return r * r


# ------------------------------------------------------------- optimizer
def init_state(cfg: OptimConfig, params):
    def leaf(p):
        # mu and nu must be DISTINCT buffers: the train step donates the
        # whole state and XLA rejects donating one buffer twice.
        if cfg.quantized_moments:
            return {
                "mu": _quant(jnp.zeros(p.shape, jnp.float32), cfg.moment_block),
                "nu": _quant(jnp.zeros(p.shape, jnp.float32), cfg.moment_block),
            }
        return {
            "mu": jnp.zeros(p.shape, jnp.float32),
            "nu": jnp.zeros(p.shape, jnp.float32),
        }

    return {
        "step": jnp.zeros((), jnp.int32),
        "moments": jax.tree.map(
            leaf, params, is_leaf=lambda x: hasattr(x, "shape")
        ),
    }


def state_specs(cfg: OptimConfig, param_specs_tree):
    """ShapeDtypeStructs of the optimizer state (for the dry-run)."""

    def leaf(p):
        if cfg.quantized_moments:
            size = math.prod(p.shape)
            padded = size + ((-size) % cfg.moment_block)
            qm = QuantMoment(
                q=jax.ShapeDtypeStruct((padded,), jnp.int8),
                scale=jax.ShapeDtypeStruct(
                    (padded // cfg.moment_block,), jnp.float32
                ),
            )
            return {"mu": qm, "nu": qm}
        f = jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return {"mu": f, "nu": f}

    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "moments": jax.tree.map(
            leaf, param_specs_tree,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        ),
    }


def global_norm(grads) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )


def apply_updates(cfg: OptimConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    with jax.named_scope("adamw"):
        return _apply_updates_impl(cfg, params, grads, state)


def _apply_updates_impl(cfg: OptimConfig, params, grads, state):
    step = state["step"]
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def leaf(p, g, m):
        g = g.astype(jnp.float32) * scale
        if cfg.quantized_moments:
            mu_f = _dequant(m["mu"], p.shape, cfg.moment_block)
            mu_f = b1 * mu_f + (1 - b1) * g
            nu_f = _dequant_nu(m["nu"], p.shape, cfg.moment_block)
            nu_f = b2 * nu_f + (1 - b2) * g * g
            mu_store = _quant(mu_f, cfg.moment_block)
            nu_store = _quant_nu(nu_f, cfg.moment_block)
        else:
            mu_f = b1 * m["mu"] + (1 - b1) * g
            nu_f = b2 * m["nu"] + (1 - b2) * g * g
            mu_store, nu_store = mu_f, nu_f
        upd = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, {"mu": mu_store, "nu": nu_store}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(state["moments"])
    out = [leaf(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_moments = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_state = {"step": step + 1, "moments": new_moments}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
