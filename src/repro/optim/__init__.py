"""Optimizer substrate: AdamW (+ int8 moments), schedules, compression."""

from .adamw import OptimConfig, apply_updates, init_state, lr_at, state_specs
from .compression import (
    apply_error_feedback,
    compressed_psum_mean,
    dequantize_block_int8,
    quantize_block_int8,
    zeros_like_residuals,
)

__all__ = [
    "OptimConfig",
    "apply_error_feedback",
    "apply_updates",
    "compressed_psum_mean",
    "dequantize_block_int8",
    "init_state",
    "lr_at",
    "quantize_block_int8",
    "state_specs",
    "zeros_like_residuals",
]
