"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradient all-reduce with error feedback (EF-SGD
style): each step the local gradient plus the carried quantization residual
is block-quantized to int8, summed across the data axes (the int8 payloads
are dequantized per-shard before the sum — the collective itself moves
~4x fewer bytes when XLA keeps the payload in int8 form; we express the
math and let GSPMD schedule it), and the quantization error is carried to
the next step. Error feedback keeps the *accumulated* bias bounded so
convergence matches uncompressed SGD/Adam to first order.

Used by train.step when ``TrainConfig.grad_compression='int8'``; tests
verify (a) error feedback cancels bias over repeated steps and (b) the
compressed all-reduce path matches the exact mean within quantization
tolerance on 8 fake devices.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .. import jax_compat

__all__ = [
    "quantize_block_int8",
    "dequantize_block_int8",
    "compressed_psum_mean",
    "apply_error_feedback",
]


def quantize_block_int8(x: jax.Array, block: int = 256):
    """(..., ) f32 -> (int8 payload, f32 per-block scales, orig shape)."""
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-30)[:, None]).astype(jnp.int8)
    return q, scale


def dequantize_block_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def apply_error_feedback(
    grad: jax.Array, residual: jax.Array, block: int = 256
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (grad + residual); return (q, scale, new_residual)."""
    target = grad.astype(jnp.float32) + residual
    q, scale = quantize_block_int8(target, block)
    recon = dequantize_block_int8(q, scale, target.shape)
    return q, scale, target - recon


def compressed_psum_mean(
    grads: Any, residuals: Any, axis_names: Tuple[str, ...], block: int = 256
):
    """Inside shard_map: int8-compressed mean-all-reduce with error feedback.

    grads/residuals: matching pytrees of f32 leaves (local values).
    Returns (mean_grads, new_residuals).
    """

    def leaf(g, r):
        q, scale, new_r = apply_error_feedback(g, r, block)
        recon = dequantize_block_int8(q, scale, g.shape)
        total = recon
        for ax in axis_names:
            total = jax.lax.psum(total, ax)
        n = 1
        for ax in axis_names:
            n *= jax_compat.axis_size(ax)
        return total / n, new_r

    out = jax.tree.map(leaf, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_res


def zeros_like_residuals(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
