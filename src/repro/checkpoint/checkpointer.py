"""Atomic + async checkpointing with elastic (re-mesh) restore.

Layout (one directory per step, atomically renamed into place):

    <dir>/step_00000100/
        manifest.json     tree structure, leaf dtypes/shapes, user metadata
        arrays.npz        one entry per leaf (key = flattened path)

Writes go to ``step_<n>.tmp.<pid>`` and are ``os.rename``d (atomic on
POSIX) only after fsync — a crash mid-write never corrupts the latest
checkpoint, and ``latest_step`` only ever sees complete directories.

Checkpoints are *logical*: every leaf is saved as a full (unsharded) host
array. Restore therefore works onto ANY mesh/device count — the caller
re-applies shardings afterwards (`jax.device_put(tree, shardings)`), which
is what makes elastic restarts (N devices -> M devices) exact.

``Checkpointer`` adds async saves (background thread; ``wait()`` joins),
retention (keep last k), and bit-exact save/restore of optimizer + data
iterator state alongside params.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from .. import jax_compat

__all__ = ["Checkpointer", "latest_step", "restore", "save"]

_PREFIX = "step_"


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _tree_structure_repr(tree) -> str:
    return str(jax.tree.structure(tree))


def save(
    directory: str,
    step: int,
    tree: Any,
    metadata: Optional[Dict[str, Any]] = None,
) -> str:
    """Atomically write one checkpoint. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"{_PREFIX}{step:08d}")
    tmp = f"{final}.tmp.{os.getpid()}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    try:
        leaves_with_path = jax_compat.tree_flatten_with_path(tree)[0]
        arrays: Dict[str, np.ndarray] = {}
        manifest_leaves: List[Dict[str, Any]] = []
        for path, leaf in leaves_with_path:
            key = _leaf_key(path)
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            manifest_leaves.append(
                {"key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        manifest = {
            "step": step,
            "format": 1,
            "treedef": _tree_structure_repr(tree),
            "leaves": manifest_leaves,
            "metadata": metadata or {},
            "written_at": time.time(),
        }
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(directory: str) -> Optional[int]:
    """Largest complete checkpoint step, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith(_PREFIX) and ".tmp." not in name:
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name[len(_PREFIX):]))
    return max(steps) if steps else None


def restore(
    directory: str,
    template: Any,
    step: Optional[int] = None,
) -> Tuple[Any, Dict[str, Any]]:
    """Restore a checkpoint into the structure of ``template``.

    ``template`` supplies the pytree structure (its leaves may be arrays or
    ShapeDtypeStructs — only the structure and leaf order are used). Shapes
    are validated against the stored manifest. Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"{_PREFIX}{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path, treedef = jax_compat.tree_flatten_with_path(template)
    stored = {l["key"]: l for l in manifest["leaves"]}
    out = []
    for p, leaf in leaves_with_path:
        key = _leaf_key(p)
        if key not in stored:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = data[key]
        # numpy has no native bfloat16: npz round-trips it as V2 raw bytes;
        # re-view using the manifest's dtype string (ml_dtypes-registered)
        want_dtype = stored[key]["dtype"]
        if str(arr.dtype) != want_dtype:
            arr = arr.view(np.dtype(want_dtype))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"leaf {key!r}: stored shape {arr.shape} != template {want}"
            )
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    return tree, manifest["metadata"]


class Checkpointer:
    """Async checkpoint manager with retention.

    save() snapshots to host synchronously (cheap) and writes on a
    background thread; wait() joins outstanding writes. keep=k retains the
    newest k checkpoints (older ones are pruned after a successful write).
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- public
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if not self.async_save:
            save(self.directory, step, host_tree, metadata)
            self._prune()
            return

        def work():
            try:
                save(self.directory, step, host_tree, metadata)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, template: Any, step: Optional[int] = None):
        self.wait()
        return restore(self.directory, template, step)

    def latest_step(self) -> Optional[int]:
        self.wait()
        return latest_step(self.directory)

    # ------------------------------------------------------------ private
    def _prune(self):
        if not self.keep:
            return
        steps = sorted(
            int(n[len(_PREFIX):])
            for n in os.listdir(self.directory)
            if n.startswith(_PREFIX) and ".tmp." not in n
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"{_PREFIX}{s:08d}"),
                ignore_errors=True,
            )
