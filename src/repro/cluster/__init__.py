"""Cross-host serving tier: coordinator/worker cluster over TCP frames.

The first layer of the system that spans more than one process tree.
One coordinator fans query batches out to per-host workers — each
running the existing ``sharded_amih``/``sharded_scan`` engines over its
slice of a host-partitioned ``ShardPlan`` — over a length-prefixed TCP
transport (framed numpy + JSON headers, stdlib only), merges the O(K)
per-host exact result planes with the same lexsort the single-host
engines use, and broadcasts the monotone per-query k-th-cosine floor
between hosts so each host's probing stops early against results found
anywhere in the cluster. Results are bit-identical to single-host
``sharded_amih`` and to per-query ``linear_scan_knn``.

Modules:

  - ``transport``   — framing: MAGIC + uint32 + JSON header + raw numpy
  - ``worker``      — one host's engine behind a frame loop
  - ``coordinator`` — fan-out, bound rebroadcast, merge; ClusterEngine
                      (registered as backend ``"cluster"``)
  - ``local``       — spawn-based localhost fleet (tests/benches/smoke)
  - ``launch``      — ``python -m repro.cluster.launch`` CLI
  - ``smoke``       — ``python -m repro.cluster.smoke`` exactness canary

Entry points: ``make_engine("cluster", db_words, p, hosts=2, ...)``, or
``RetrievalConfig(cluster=True, hosts=N)`` one level up (serving), or
the launcher for a real multi-host deployment. See docs/cluster.md for
the wire protocol and the bound-broadcast exactness argument.
"""

from .coordinator import (
    ClusterCoordinator,
    ClusterDegradedError,
    ClusterEngine,
    ClusterError,
    RemoteSearchError,
    RequestTimeoutError,
    WorkerDiedError,
)
from .local import LocalCluster
from .transport import FrameError, pack_ragged, recv_frame, send_frame, \
    unpack_ragged
from .worker import WorkerServer, serve

__all__ = [
    "ClusterCoordinator",
    "ClusterDegradedError",
    "ClusterEngine",
    "ClusterError",
    "FrameError",
    "LocalCluster",
    "RemoteSearchError",
    "RequestTimeoutError",
    "WorkerDiedError",
    "WorkerServer",
    "pack_ragged",
    "recv_frame",
    "send_frame",
    "serve",
    "unpack_ragged",
]
