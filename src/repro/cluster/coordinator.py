"""Cluster coordinator: fan out, bound broadcast, exact O(K) merge.

The coordinator owns one TCP connection per worker host. A ``knn_batch``
call becomes one ``search`` frame to every live worker (packed queries +
the primed per-query floor); while workers probe, their ``bound`` frames
— each a query's local k-th cosine, a valid lower bound on the global
k-th — fold monotonically into the request's global floor and are
REBROADCAST to the other workers, which apply them to the live
``stop_below`` array mid-probe. This is ``SharedBound`` generalized from
one process's shared memory to sockets: bounds only ever rise, so a
late, lost, or reordered update yields a weaker-but-valid bound — it
costs probing time, never correctness (docs/cluster.md spells out the
argument).

Each worker returns its host-local exact top-<=k as O(K) ragged planes;
the union across hosts always contains every row of the true global
top-K (a host only withholds rows strictly below a valid global bound),
so the same lexsort used inside the single-host engines —
``np.lexsort((gids, -sims))[:k]`` — produces results bit-identical to
single-host ``sharded_amih`` and to per-query ``linear_scan_knn``.

Failure semantics: heartbeats and per-request timeouts wrap every wait.
A worker that dies mid-request (EOF, reset, stale heartbeat, timeout)
fails THAT request with ``WorkerDiedError`` — its rows are gone, so
pretending with a partial merge would break exactness — and permanently
degrades the cluster: later calls fail fast with
``ClusterDegradedError`` instead of hanging a serving drain (the
streaming tier surfaces both through its ticket futures).

``ClusterEngine`` (backend name ``"cluster"``) wraps all of it behind
the standard ``SearchEngine`` API: ``build`` host-partitions one
``ShardPlan``, ships each worker its row slab + sub-plan summary, and
— when no worker addresses are given — spawns a localhost worker fleet
(repro.cluster.local) so the full wire protocol runs on one machine.
"""

from __future__ import annotations

import socket
import threading
import time
from dataclasses import fields as dc_fields
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.amih import AMIHStats
from ..core.engine import EngineStats, SearchEngine, register_engine
from ..core.linear_scan import sims_for_ids
from ..core.packing import WORD_DTYPE
from ..core.single_table import SearchStats
from ..obs import trace as _obs
from ..pipeline.shardpool import prime_ids
from ..shard.plan import ShardPlan
from .transport import FrameError, recv_frame, send_frame, unpack_ragged
from .worker import WORKER_BACKENDS, stats_from_wire

__all__ = [
    "ClusterCoordinator",
    "ClusterDegradedError",
    "ClusterEngine",
    "ClusterError",
    "RemoteSearchError",
    "RequestTimeoutError",
    "WorkerDiedError",
]


class ClusterError(RuntimeError):
    """Base for every cluster-tier failure."""


class WorkerDiedError(ClusterError):
    """A worker connection dropped (or went silent) mid-request."""


class ClusterDegradedError(ClusterError):
    """The cluster has lost a worker's rows: exact answers are
    impossible, so every call fails fast until rebuilt."""


class RequestTimeoutError(ClusterError):
    """A request exceeded its per-request deadline."""


class RemoteSearchError(ClusterError):
    """A worker's search raised; its message travelled back."""


class _WorkerHandle:
    """Coordinator-side state for one worker connection."""

    def __init__(self, host: int, addr: Tuple[str, int],
                 sock: socket.socket):
        self.host = host
        self.addr = addr
        self.sock = sock
        self.send_lock = threading.Lock()
        self.alive = True
        self.info: Dict[str, Any] = {}
        self.last_seen = time.monotonic()
        self.bound_frames = 0        # bound updates received from it
        self.reader: Optional[threading.Thread] = None
        # cross-host clock calibration: the last ping's (seq, send-time
        # in perf_counter us) and the offset estimated from its pong —
        # worker_perf_counter_us - coordinator_perf_counter_us, so
        # shifting worker span timestamps by -offset lands them on the
        # coordinator timeline (~0 for localhost fleets: one kernel
        # clock)
        self.ping_sent: Optional[Tuple[int, float]] = None
        self.clock_offset_us = 0.0

    def send(self, kind, meta=None, arrays=None) -> None:
        send_frame(self.sock, kind, meta, arrays, lock=self.send_lock)


class _Request:
    """One in-flight fan-out: per-host result slots + the live floor."""

    def __init__(self, req: int, B: int, hosts: Sequence[int],
                 floor: np.ndarray):
        self.req = req
        self.B = B
        self.expected = set(hosts)
        self.floor = floor
        self.t0 = time.monotonic()
        self.t0_us = _obs.now_us()   # same instant on the span clock
        # host -> (ids planes, sims planes, EngineStats, rpc seconds)
        self.results: Dict[int, Tuple[list, list, EngineStats, float]] = {}
        self.error: Optional[ClusterError] = None

    def settled(self) -> bool:
        return self.error is not None or \
            self.expected <= set(self.results)


class ClusterCoordinator:
    """Request fan-out/merge over a fixed set of worker handles."""

    def __init__(
        self,
        handles: List[_WorkerHandle],
        plan: ShardPlan,
        request_timeout: float = 120.0,
        heartbeat: float = 2.0,
    ):
        self.handles = handles
        self.plan = plan
        self.request_timeout = request_timeout
        self.heartbeat = heartbeat
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._current: Optional[_Request] = None
        self._seq = 0
        self._ping_seq = 0
        self._closed = False
        for h in self.handles:
            # the handle's last_seen was stamped at socket-connect time,
            # and build (slab transfer + engine construction) can take
            # minutes — restart the staleness clock NOW, or the first
            # heartbeat check would mark every worker dead before a
            # single ping went out
            h.last_seen = time.monotonic()
            h.reader = threading.Thread(
                target=self._reader, args=(h,), daemon=True
            )
            h.reader.start()
        self._beater = threading.Thread(
            target=self._heartbeat_loop, daemon=True
        )
        self._beater.start()

    # ---------------------------------------------------------- liveness
    def _mark_dead(self, h: _WorkerHandle) -> None:
        with self._cond:
            if not h.alive:
                return
            h.alive = False
            cur = self._current
            if cur is not None and h.host in cur.expected \
                    and cur.error is None:
                cur.error = WorkerDiedError(
                    f"worker {h.host} at {h.addr[0]}:{h.addr[1]} died "
                    f"mid-request {cur.req}"
                )
            self._cond.notify_all()
        # shutdown BEFORE close: close() alone neither sends FIN nor
        # unblocks a reader parked in recv on this socket (the in-flight
        # syscall pins the kernel socket), so the worker would never see
        # EOF and our reader thread would never exit
        try:
            h.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            h.sock.close()
        except OSError:
            pass

    def _heartbeat_loop(self) -> None:
        while not self._closed:
            time.sleep(self.heartbeat)
            if self._closed:
                return
            self._ping_seq += 1
            now = time.monotonic()
            for h in self.handles:
                if not h.alive:
                    continue
                # the worker's reader answers pings even while a search
                # runs, so silence across several beats means it's gone
                if now - h.last_seen > 4 * self.heartbeat:
                    self._mark_dead(h)
                    continue
                try:
                    h.ping_sent = (self._ping_seq, _obs.now_us())
                    h.send("ping", {"seq": self._ping_seq})
                except OSError:
                    self._mark_dead(h)

    # ------------------------------------------------------ reader thread
    def _reader(self, h: _WorkerHandle) -> None:
        try:
            while True:
                kind, meta, arrays = recv_frame(h.sock)
                h.last_seen = time.monotonic()
                if kind == "result":
                    self._on_result(h, meta, arrays)
                elif kind == "bound":
                    self._on_bound(h, meta, arrays)
                elif kind == "pong":
                    # midpoint clock-offset estimate: the worker stamped
                    # its perf_counter into the pong, and (send + recv)/2
                    # approximates the coordinator time of that stamp
                    # (symmetric-RTT assumption; error is bounded by
                    # RTT/2, far below the millisecond spans we draw)
                    ts = meta.get("ts")
                    if ts is not None and h.ping_sent is not None and \
                            int(meta.get("seq", -1)) == h.ping_sent[0]:
                        t_recv = _obs.now_us()
                        h.clock_offset_us = \
                            float(ts) - (h.ping_sent[1] + t_recv) / 2.0
                elif kind == "error":
                    with self._cond:
                        cur = self._current
                        if cur is not None and \
                                int(meta.get("req", -1)) == cur.req and \
                                cur.error is None:
                            cur.error = RemoteSearchError(
                                f"worker {h.host}: "
                                f"{meta.get('message', 'unknown')}"
                            )
                            self._cond.notify_all()
                else:
                    raise FrameError(f"unexpected frame {kind!r}")
        except Exception:   # noqa: BLE001
            # not just FrameError/OSError: a well-framed but corrupt
            # payload (bad ragged lengths, unexpected stats fields, …)
            # must also kill the handle IMMEDIATELY — otherwise the
            # in-flight request would sit out the full request_timeout
            # with a reader that is already gone
            pass
        finally:
            self._mark_dead(h)

    def _on_result(self, h, meta, arrays) -> None:
        elapsed = None
        with self._cond:
            cur = self._current
            if cur is None or int(meta["req"]) != cur.req:
                return   # stale result from an abandoned request
            elapsed = time.monotonic() - cur.t0
            ids = unpack_ragged(
                np.array(arrays["ids"], copy=True), arrays["lens"]
            )
            sims = unpack_ragged(
                np.array(arrays["sims"], copy=True), arrays["lens"]
            )
            cur.results[h.host] = (
                ids, sims, stats_from_wire(meta.get("stats", {})), elapsed
            )
            tr = _obs.current()
            if tr.enabled:
                # one rpc span per host (send -> result landed), plus the
                # worker's own spans shifted onto the coordinator clock
                tr.record("cluster.rpc", cur.t0_us,
                          cur.t0_us + elapsed * 1e6, cat="cluster",
                          host=h.host, req=cur.req)
                spans = meta.get("spans")
                if spans:
                    tr.ingest(spans, shift_us=h.clock_offset_us)
            self._cond.notify_all()

    def _on_bound(self, h, meta, arrays) -> None:
        """Fold a worker's bound rows into the request floor; rebroadcast
        entries that actually raised it to every OTHER live worker."""
        h.bound_frames += 1
        qi = np.asarray(arrays["qi"], dtype=np.int64)
        val = np.asarray(arrays["val"], dtype=np.float64)
        raised_qi: List[int] = []
        raised_val: List[float] = []
        with self._lock:
            cur = self._current
            if cur is None or int(meta.get("req", -1)) != cur.req:
                return   # late bound: only ever a lost optimization
            for j in range(qi.shape[0]):
                i, v = int(qi[j]), float(val[j])
                if 0 <= i < cur.B and v > cur.floor[i]:
                    cur.floor[i] = v
                    raised_qi.append(i)
                    raised_val.append(v)
            req = cur.req
        if not raised_qi:
            return
        payload = {
            "qi": np.asarray(raised_qi, dtype=np.int64),
            "val": np.asarray(raised_val, dtype=np.float64),
        }
        for peer in self.handles:
            if peer is h or not peer.alive:
                continue
            try:
                peer.send("bound", {"req": req}, payload)
            except OSError:
                self._mark_dead(peer)

    # ------------------------------------------------------------ request
    def alive_hosts(self) -> List[int]:
        return [h.host for h in self.handles if h.alive]

    def search(
        self, q: np.ndarray, k: int, floor: np.ndarray
    ) -> Tuple[Dict[int, Tuple[list, list, EngineStats, float]],
               np.ndarray]:
        """Fan one batch out to every worker and collect all per-host
        planes (raises on death/timeout/remote error — never a partial
        merge). Returns ({host: (ids, sims, stats, rpc_s)}, floor)."""
        B = q.shape[0]
        with self._cond:
            if self._closed:
                raise ClusterError("coordinator is closed")
            dead = [h for h in self.handles if not h.alive]
            if dead:
                raise ClusterDegradedError(
                    f"cluster degraded: worker(s) "
                    f"{[h.host for h in dead]} are gone; exact answers "
                    f"need every host's rows"
                )
            self._seq += 1
            cur = _Request(self._seq, B, [h.host for h in self.handles],
                           floor)
            self._current = cur
        tr = _obs.current()
        try:
            for h in self.handles:
                try:
                    smeta = {"req": cur.req, "k": k}
                    if tr.enabled:
                        # propagate the trace id so worker spans come
                        # back under the same distributed trace; the
                        # host tag keeps per-worker timelines apart
                        smeta["trace"] = {
                            "id": tr.trace_id, "host": f"host{h.host}",
                        }
                    h.send("search", smeta, {"q": q, "floor": floor})
                except OSError:
                    self._mark_dead(h)
            deadline = cur.t0 + self.request_timeout
            timed_out: List[_WorkerHandle] = []
            with self._cond:
                while not cur.settled():
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        missing = sorted(cur.expected - set(cur.results))
                        cur.error = RequestTimeoutError(
                            f"request {cur.req} timed out after "
                            f"{self.request_timeout:.0f}s waiting on "
                            f"worker(s) {missing}"
                        )
                        break
                    self._cond.wait(remaining)
                if isinstance(cur.error, RequestTimeoutError):
                    # a silent worker is an unusable worker: degrade
                    # rather than racing its late result next call
                    timed_out = [h for h in self.handles
                                 if h.alive and h.host not in cur.results]
            # _mark_dead takes the condition lock itself (and closing the
            # socket unblocks the reader thread), so it runs outside —
            # flipping alive in place would leave the reader parked in
            # recv_frame and the connection lingering until close()
            for h in timed_out:
                self._mark_dead(h)
            with self._cond:
                if cur.error is not None:
                    raise cur.error
                if tr.enabled:
                    tr.record("cluster.search", cur.t0_us, _obs.now_us(),
                              cat="cluster", req=cur.req, B=B, k=k,
                              hosts=len(cur.expected))
                return cur.results, cur.floor
        finally:
            with self._cond:
                self._current = None

    def close(self) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        for h in self.handles:
            if h.alive:
                try:
                    h.send("close")
                except OSError:
                    pass
            try:
                h.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                h.sock.close()
            except OSError:
                pass
            h.alive = False
        for h in self.handles:
            if h.reader is not None:
                h.reader.join(timeout=5.0)


# --------------------------------------------------------------- engine
def _fold_counters(dst, src) -> None:
    """Sum/max/or ``src``'s counters into ``dst`` across the fields they
    share (AMIHStats is a superset of SearchStats)."""
    for f in dc_fields(dst):
        if not hasattr(src, f.name):
            continue
        v = getattr(src, f.name)
        if isinstance(v, (bool, np.bool_)):
            setattr(dst, f.name, bool(getattr(dst, f.name)) | bool(v))
        elif f.name == "max_radius":
            setattr(dst, f.name, max(getattr(dst, f.name), int(v)))
        elif isinstance(v, (int, np.integer)):
            setattr(dst, f.name, getattr(dst, f.name) + int(v))


@register_engine
class ClusterEngine(SearchEngine):
    """Cross-host serving tier behind the standard engine API.

    ``build`` balances one ``ShardPlan`` over the DB, splits it with
    ``host_partition(hosts)``, and gives every worker its row slab plus
    its sub-plan ``summary()`` — the whole layout contract crosses the
    wire as one JSON dict. Workers run the existing ``inner_backend``
    engine (``sharded_amih`` by default; ``sharded_scan`` for the
    exhaustive tier) with ``inner_cfg`` forwarded verbatim, so every
    single-host knob (``m``, ``probe_backend``, ``verify_backend``, …)
    applies per host unchanged.

    With no ``workers`` address list, a localhost fleet is spawned
    (repro.cluster.local) and torn down by ``close()`` — the same wire
    protocol, one machine. ``prime_bound`` warm-starts every request's
    floor with the exact k-th sim of a deterministic row sample before
    any worker probes (the cross-host analog of the shard pool's
    priming), and the sampled rows themselves stay in the merge pool —
    every floor a worker prunes against is justified by >= k rows that
    are present at the merge, exactly like the shard pool keeps its
    bound-generating rows. That invariant is what makes the tier immune
    to the float64 tie-group edge: exactly-tied probing tuples can
    round 1 ulp apart, so a worker's strictly-below stop may fire
    mid-tie-group and drop rows AT the floor — harmless, because the
    justifying rows supply any ties the top-k needs.
    """

    name = "cluster"

    def __init__(self, db_words, p, plan, coordinator, local_fleet,
                 prime_bound: bool):
        self.db_words = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        self.p = p
        self.plan = plan
        self.coordinator = coordinator
        self._fleet = local_fleet
        self.prime_bound = prime_bound
        # the wire protocol carries one search per worker at a time, so
        # concurrent knn_batch callers (e.g. the streaming loop's
        # pipelined search stage) queue here instead of erroring with
        # "worker busy"
        self._serial = threading.Lock()

    @classmethod
    def build(
        cls,
        db_words: np.ndarray,
        p: int,
        hosts: int = 2,
        workers: Optional[Sequence[Tuple[str, int]]] = None,
        inner_backend: str = "sharded_amih",
        num_shards: Optional[int] = None,
        plan: Optional[ShardPlan] = None,
        prime_bound: bool = True,
        request_timeout: float = 120.0,
        heartbeat: float = 2.0,
        build_timeout: float = 300.0,
        **inner_cfg: Any,
    ) -> "ClusterEngine":
        if inner_backend not in WORKER_BACKENDS:
            raise ValueError(
                f"inner_backend must be one of {WORKER_BACKENDS}, "
                f"got {inner_backend!r}"
            )
        db = np.ascontiguousarray(db_words, dtype=WORD_DTYPE)
        n = db.shape[0]
        if workers is not None:
            hosts = len(workers)
        if plan is None:
            plan = ShardPlan.balanced(n, num_shards or hosts)
        elif plan.n != n:
            raise ValueError(f"plan covers n={plan.n}, DB has n={n}")
        sub_plans = plan.host_partition(hosts)
        fleet = None
        if workers is None:
            from .local import LocalCluster

            fleet = LocalCluster(hosts)
            workers = fleet.addresses
        handles: List[_WorkerHandle] = []
        try:
            for h, (addr, sub) in enumerate(zip(workers, sub_plans)):
                addr = (str(addr[0]), int(addr[1]))
                sock = socket.create_connection(addr, timeout=build_timeout)
                sock.settimeout(None)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                hd = _WorkerHandle(h, addr, sock)
                handles.append(hd)
                slab = db[sub.base : sub.base + sub.n]
                hd.send("build", {
                    "host": h, "p": p, "backend": inner_backend,
                    "plan": sub.summary(), "cfg": dict(inner_cfg),
                }, {"db": slab})
            for hd in handles:
                kind, meta, _ = recv_frame(hd.sock, timeout=build_timeout)
                if kind != "ready":
                    raise ClusterError(
                        f"worker {hd.host} sent {kind!r} instead of "
                        f"ready: {meta.get('message', '')}"
                    )
                hd.info = meta
        except (OSError, FrameError) as e:
            for hd in handles:
                try:
                    hd.sock.close()
                except OSError:
                    pass
            if fleet is not None:
                fleet.close()
            raise ClusterError(f"cluster build failed: {e}") from e
        coord = ClusterCoordinator(
            handles, plan, request_timeout=request_timeout,
            heartbeat=heartbeat,
        )
        return cls(db, p, plan, coord, fleet, prime_bound)

    @property
    def n(self) -> int:
        return self.db_words.shape[0]

    @property
    def hosts(self) -> int:
        return len(self.coordinator.handles)

    def knn_batch(self, q_words, k):
        q = self._check_queries(q_words, self.p)
        B = q.shape[0]
        k_eff = min(k, self.n)
        if k_eff == 0:
            return (
                np.empty((B, 0), np.int64), np.empty((B, 0), np.float64),
                EngineStats(backend=self.name, queries=B,
                            per_query=[SearchStats() for _ in range(B)],
                            shards=self.plan.num_shards),
            )
        with _obs.current().span("engine.knn_batch", cat="engine",
                                 backend=self.name, B=B, k=k_eff):
            return self._knn_batch_traced(q, B, k_eff)

    def _knn_batch_traced(self, q, B, k_eff):
        floor = np.full(B, -np.inf)
        primed: Optional[List[Tuple[np.ndarray, np.ndarray]]] = None
        if self.prime_bound:
            sample = prime_ids(self.n, k_eff)
            if sample.size >= k_eff:
                # keep the per-query top-k of the sample: workers prune
                # strictly below the floor, but exactly-tied probing
                # tuples can round 1 ulp apart, so a worker may still
                # drop rows AT the floor — the rows that justify the
                # floor must therefore sit in the merge pool themselves
                # (same invariant as the shard pool's candidate pool)
                cut = sample.size - k_eff
                primed = []
                for i in range(B):
                    sims_i = sims_for_ids(q[i], self.db_words, sample)
                    top = np.argpartition(sims_i, cut)[cut:]
                    floor[i] = sims_i[top].min()
                    primed.append((
                        sample[top].astype(np.int64, copy=False),
                        sims_i[top],
                    ))
        with self._serial:
            by_host, _ = self.coordinator.search(q, k_eff, floor)

        tr = _obs.current()
        t_merge = _obs.now_us() if tr.enabled else 0.0
        ids_out = np.empty((B, k_eff), dtype=np.int64)
        sims_out = np.empty((B, k_eff), dtype=np.float64)
        order_hosts = sorted(by_host)
        for i in range(B):
            planes = [by_host[h][0][i] for h in order_hosts]
            splanes = [by_host[h][1][i] for h in order_hosts]
            if primed is not None:
                planes.append(primed[i][0])
                splanes.append(primed[i][1])
            gids = np.concatenate(planes).astype(np.int64, copy=False)
            sims = np.concatenate(splanes)
            if primed is not None:
                # primed rows overlap host-returned rows; one id's sim
                # is bitwise-equal on every path, so keep first
                gids, first = np.unique(gids, return_index=True)
                sims = sims[first]
            if gids.size < k_eff:
                raise ClusterError(
                    f"query {i}: union of host planes holds "
                    f"{gids.size} < k={k_eff} rows — a worker violated "
                    f"the bound contract"
                )
            order = np.lexsort((gids, -sims))[:k_eff]
            ids_out[i] = gids[order]
            sims_out[i] = sims[order]
        if tr.enabled:
            tr.record("cluster.merge", t_merge, _obs.now_us(),
                      cat="cluster", B=B, hosts=len(order_hosts))

        per_query: List[object] = []
        host_rows = [by_host[h][2].per_query for h in order_hosts]
        for i in range(B):
            rows = [pq[i] for pq in host_rows if i < len(pq)
                    and pq[i] is not None]
            kind = AMIHStats if any(
                isinstance(r, AMIHStats) for r in rows
            ) else SearchStats
            agg = kind()
            for r in rows:
                _fold_counters(agg, r)
            per_query.append(agg)

        per_shard: List[Dict[str, Any]] = []
        per_host: List[Dict[str, Any]] = []
        for h in order_hosts:
            _ids, _sims, st, rpc_s = by_host[h]
            hd = self.coordinator.handles[h]
            for row in st.per_shard:
                per_shard.append({**row, "cluster_host": h})
            entry: Dict[str, Any] = {
                "host": h,
                "addr": f"{hd.addr[0]}:{hd.addr[1]}",
                "rows": int(hd.info.get("n", 0)),
                "shards": st.shards,
                "rpc_ms": round(rpc_s * 1e3, 3),
                "bound_frames": hd.bound_frames,
                "per_shard": st.per_shard,
                "cache_info": st.cache_info,
            }
            for counter in ("launches", "probes", "retrieved", "verified",
                            "tuples_processed", "early_stopped",
                            "fell_back_to_scan"):
                entry[counter] = sum(
                    int(row.get(counter, 0)) for row in st.per_shard
                )
            per_host.append(entry)
        per_shard.sort(key=lambda r: r.get("shard", 0))

        stats = EngineStats(
            backend=self.name, queries=B, per_query=per_query,
            shards=self.plan.num_shards, per_shard=per_shard,
            per_host=per_host,
        )
        return ids_out, sims_out, stats

    def close(self) -> None:
        """Tear the cluster down: close every worker connection, then
        (for a spawned localhost fleet) terminate the worker processes.
        Idempotent; GC-safe."""
        self.coordinator.close()
        if self._fleet is not None:
            self._fleet.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass   # interpreter shutdown
