"""Cluster worker: one host's slice of the DB behind a TCP frame loop.

A worker is a tiny server around the EXISTING single-host engines: it
accepts one coordinator connection, receives a ``build`` frame (its
host-partitioned ``ShardPlan`` sub-plan summary + its local row slab),
constructs a ``sharded_amih``/``sharded_scan`` engine over the slab via
``make_engine`` — sub-plan ``starts`` are global ids, so every result
the engine emits is already DB-wide — and then answers ``search``
frames until the connection drops.

Concurrency model (two threads per connection while a search runs):

  - the READER loop keeps consuming frames during a search: ``ping``
    gets an immediate ``pong`` (liveness is never blocked behind
    probing), and ``bound`` frames — the cluster-wide k-th-cosine floor
    raised by OTHER hosts — are written monotonically into the live
    ``stop_below`` array the running search re-reads per tuple step, so
    a remote raise prunes local probing mid-flight.
  - the SEARCH thread runs ``engine.knn_batch_bounded`` and publishes
    bounds back out through its ``on_done`` hook: the moment a query
    fills k results locally, its local k-th (the k-th best exact sim of
    k real rows — a valid global lower bound) goes to the coordinator
    as a ``bound`` frame. Publishing is gated on the REQUESTED k, not
    the local ``min(k, n_local)``: a host holding fewer than k rows has
    no valid global k-th to offer and stays silent.

Failure semantics: a coordinator disconnect (EOF, reset, bad frame)
raises the active search's floor to +inf — probing collapses within a
few tuple steps and the result is discarded — then the worker loops
back to ``accept`` for the next coordinator. A search that raises
ships an ``error`` frame instead of a result, so the coordinator fails
that request's tickets instead of timing out.
"""

from __future__ import annotations

import socket
import threading
from dataclasses import asdict
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.amih import AMIHStats
from ..core.engine import EngineStats, make_engine
from ..core.single_table import SearchStats
from ..obs import trace as _obs
from ..shard.plan import ShardPlan
from .transport import FrameError, pack_ragged, recv_frame, send_frame

__all__ = ["WorkerServer", "serve", "stats_to_wire", "stats_from_wire"]

#: engines a worker will build; anything else in a ``build`` frame is a
#: protocol error (the cluster tier serves row-sharded backends only).
WORKER_BACKENDS = ("sharded_amih", "sharded_scan")


# ------------------------------------------------------- stats over JSON
def stats_to_wire(st: EngineStats) -> Dict[str, Any]:
    """EngineStats -> JSON-serializable dict. Per-query counter objects
    travel as plain dicts tagged with their dataclass; ``per_shard`` and
    ``cache_info`` are JSON already."""
    return {
        "backend": st.backend,
        "queries": st.queries,
        "shards": st.shards,
        "per_shard": st.per_shard,
        "cache_info": st.cache_info,
        "per_query": [
            None if s is None else {
                "_kind": type(s).__name__, **asdict(s)
            }
            for s in st.per_query
        ],
    }


def stats_from_wire(d: Dict[str, Any]) -> EngineStats:
    """Inverse of ``stats_to_wire`` (per-query rows come back as real
    AMIHStats/SearchStats objects, so ``aggregate()`` works on the
    coordinator exactly as it does host-side)."""
    per_query: List[Optional[object]] = []
    for row in d.get("per_query", []):
        if row is None:
            per_query.append(None)
            continue
        row = dict(row)
        kind = row.pop("_kind", "AMIHStats")
        cls = AMIHStats if kind == "AMIHStats" else SearchStats
        per_query.append(cls(**row))
    return EngineStats(
        backend=d.get("backend", ""),
        queries=int(d.get("queries", 0)),
        per_query=per_query,
        shards=int(d.get("shards", 0)),
        per_shard=list(d.get("per_shard", [])),
        cache_info=dict(d.get("cache_info", {})),
    )


class WorkerServer:
    """One worker host's frame loop; ``serve_forever`` blocks."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._srv = socket.create_server((host, port))
        self.addr = self._srv.getsockname()[:2]
        self._shutdown = False

    def close(self) -> None:
        self._shutdown = True
        try:
            self._srv.close()
        except OSError:
            pass

    def serve_forever(self) -> None:
        """Accept coordinators one at a time until ``close`` (a worker
        serves exactly one coordinator; a replacement coordinator simply
        reconnects after the old one drops)."""
        while not self._shutdown:
            try:
                conn, _ = self._srv.accept()
            except OSError:
                break   # listener closed
            try:
                self._serve_conn(conn)
            finally:
                try:
                    conn.close()
                except OSError:
                    pass

    # ------------------------------------------------------- one session
    def _serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        send_lock = threading.Lock()
        dead = threading.Event()
        engine = None
        host_id = -1
        k_req = 0
        active: Dict[int, np.ndarray] = {}   # req id -> live floor array
        searcher: Optional[threading.Thread] = None
        try:
            while not self._shutdown:
                kind, meta, arrays = recv_frame(conn)
                if kind == "build":
                    if meta["backend"] not in WORKER_BACKENDS:
                        raise FrameError(
                            f"worker refuses backend {meta['backend']!r}"
                        )
                    plan = ShardPlan.from_summary(meta["plan"])
                    # detach the slab from the frame buffer before the
                    # engine keeps a reference to it
                    db = np.array(arrays["db"], copy=True)
                    engine = make_engine(
                        meta["backend"], db, int(meta["p"]), plan=plan,
                        **meta.get("cfg", {}),
                    )
                    host_id = int(meta.get("host", -1))
                    send_frame(conn, "ready", {
                        "host": host_id, "n": engine.n,
                        "shards": plan.num_shards,
                    }, lock=send_lock)
                elif kind == "search":
                    if engine is None:
                        raise FrameError("search before build")
                    if searcher is not None and searcher.is_alive():
                        # the previous search's result frame lands a hair
                        # before its thread exits, and a serialized
                        # coordinator may fire the next request inside
                        # that window — give the thread a beat to finish
                        # before calling the protocol broken
                        searcher.join(timeout=2.0)
                    if searcher is not None and searcher.is_alive():
                        send_frame(conn, "error", {
                            "req": meta["req"],
                            "message": "worker busy: search in flight",
                        }, lock=send_lock)
                        continue
                    req = int(meta["req"])
                    k_req = int(meta["k"])
                    floor = np.array(
                        arrays["floor"], dtype=np.float64, copy=True
                    )
                    active.clear()
                    active[req] = floor
                    q = np.array(arrays["q"], copy=True)
                    searcher = threading.Thread(
                        target=self._run_search,
                        args=(conn, send_lock, engine, req, q, k_req,
                              floor, dead, meta.get("trace")),
                        daemon=True,
                    )
                    searcher.start()
                elif kind == "bound":
                    floor = active.get(int(meta.get("req", -1)))
                    if floor is None:
                        continue   # stale: a late bound only costs time
                    qi, val = arrays["qi"], arrays["val"]
                    for j in range(qi.shape[0]):
                        i, v = int(qi[j]), float(val[j])
                        if 0 <= i < floor.shape[0] and v > floor[i]:
                            floor[i] = v
                elif kind == "ping":
                    # ts is this worker's perf_counter in microseconds —
                    # the coordinator pairs it with the ping's send/recv
                    # times to estimate the cross-host clock offset
                    send_frame(conn, "pong", {
                        "seq": meta.get("seq", 0), "ts": _obs.now_us(),
                    }, lock=send_lock)
                elif kind == "close":
                    break
                else:
                    raise FrameError(f"unknown frame kind {kind!r}")
        except (FrameError, OSError):
            pass   # coordinator gone: fall through to cleanup
        except Exception:   # noqa: BLE001
            # well-framed but malformed content (missing meta key, bad
            # plan/cfg fed to make_engine, …) tears down THIS connection
            # — the documented failure unit — and the server re-accepts;
            # it must never kill the worker process
            pass
        finally:
            dead.set()
            # collapse any in-flight search: +inf floor prunes every
            # remaining tuple step, so the thread exits promptly
            for floor in active.values():
                floor[:] = np.inf
            if searcher is not None:
                searcher.join(timeout=30.0)
            if engine is not None:
                engine.close()

    @staticmethod
    def _run_search(conn, send_lock, engine, req, q, k_req, floor, dead,
                    trace_meta=None):
        B = q.shape[0]
        sent = np.full(B, -np.inf)
        # the coordinator's trace id rides the search frame's optional
        # "trace" meta; install a per-request tracer process-wide so the
        # engine/amih/kernel span sites below this thread all record into
        # it (one search runs at a time per worker), then ship the spans
        # back inside the result frame
        tracer = prev_tracer = None
        if trace_meta:
            tracer = _obs.Tracer(
                enabled=True,
                host=str(trace_meta.get("host", "worker")),
                trace_id=trace_meta.get("id"),
            )
            prev_tracer = _obs.set_tracer(tracer)

        def publish(qi: int, _ids, sims) -> None:
            # only a k-th best of >= k_req REAL rows is a valid global
            # lower bound; a short local fill stays private
            if dead.is_set() or sims.size < k_req:
                return
            kth = float(sims[-1])
            if kth > sent[qi]:
                sent[qi] = kth
                try:
                    send_frame(conn, "bound", {"req": req}, {
                        "qi": np.array([qi], dtype=np.int64),
                        "val": np.array([kth], dtype=np.float64),
                    }, lock=send_lock)
                except OSError:
                    dead.set()

        try:
            if hasattr(engine, "knn_batch_bounded"):
                results, st = engine.knn_batch_bounded(
                    q, k_req, floor, on_done=publish
                )
            else:   # exhaustive backends have no bounded path: full k
                ids, sims, st = engine.knn_batch(q, k_req)
                results = [(ids[i], sims[i]) for i in range(B)]
            ids_flat, lens = pack_ragged(
                [r[0] for r in results], dtype=np.int64
            )
            sims_flat, _ = pack_ragged(
                [r[1] for r in results], dtype=np.float64
            )
            meta_out = {"req": req, "stats": stats_to_wire(st)}
            if tracer is not None:
                meta_out["spans"] = tracer.drain()
            if not dead.is_set():
                send_frame(conn, "result", meta_out,
                           {"ids": ids_flat, "sims": sims_flat,
                            "lens": lens},
                           lock=send_lock)
        except Exception as e:                # noqa: BLE001
            if not dead.is_set():
                try:
                    send_frame(conn, "error", {
                        "req": req,
                        "message": f"{type(e).__name__}: {e}",
                    }, lock=send_lock)
                except OSError:
                    pass
        finally:
            if tracer is not None:
                _obs.set_tracer(prev_tracer)


def serve(host: str = "127.0.0.1", port: int = 0, announce=None) -> None:
    """Entry point for worker processes: bind (port 0 = ephemeral),
    report the bound ``(host, port)`` through ``announce`` (a
    multiprocessing pipe end) when given — the localhost harness reads
    it — and serve until killed."""
    srv = WorkerServer(host, port)
    if announce is not None:
        announce.send(srv.addr)
        announce.close()
    srv.serve_forever()
