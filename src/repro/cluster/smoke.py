"""Cluster smoke canary: coordinator + 2 localhost workers, exact vs scan.

``python -m repro.cluster.smoke`` spawns a 2-worker loopback fleet over
a small synthetic DB, runs one mixed batch through the full wire
protocol (build frames, fan-out, bound broadcast, merge), and asserts
the merged results are exactly ``linear_scan_knn``'s — ids and float64
sims both. Exits non-zero on any mismatch; wired into scripts/verify.sh
next to the pipeline smoke so the cross-host tier cannot silently rot.

Small on purpose: the DB is a few thousand rows so the whole canary —
including two spawned interpreters importing jax — stays in tens of
seconds on CPU.
"""

from __future__ import annotations

import sys
import time

import numpy as np


def run(n: int = 4096, p: int = 64, B: int = 8, k: int = 10,
        hosts: int = 2, num_shards: int = 4, seed: int = 0) -> int:
    from repro.core.engine import make_engine
    from repro.core.linear_scan import linear_scan_knn
    from repro.core.packing import pack_bits

    rng = np.random.default_rng(seed)
    db_words = pack_bits(rng.integers(0, 2, size=(n, p), dtype=np.uint8))
    q_words = pack_bits(rng.integers(0, 2, size=(B, p), dtype=np.uint8))

    from repro.core.linear_scan import sims_for_ids

    t0 = time.perf_counter()
    engine = make_engine("cluster", db_words, p, hosts=hosts,
                         num_shards=num_shards)
    t_build = time.perf_counter() - t0
    try:
        t0 = time.perf_counter()
        ids, sims, stats = engine.knn_batch(q_words, k)
        t_search = time.perf_counter() - t0
        bad = 0
        for i in range(B):
            # the repo-wide exactness convention (see tests/test_shard):
            # sims bit-identical to the scan; emitted ids distinct and
            # really carrying those sims (tie order inside one Hamming
            # tuple is the only permitted difference)
            _ref_ids, ref_sims = linear_scan_knn(q_words[i], db_words, k)
            ok = (
                np.array_equal(sims[i], ref_sims)
                and np.unique(ids[i]).size == k
                and np.array_equal(
                    sims_for_ids(q_words[i], db_words, ids[i]), sims[i]
                )
            )
            if not ok:
                bad += 1
                print(f"MISMATCH query {i}:\n  got  {sims[i]}\n"
                      f"  want {ref_sims}", file=sys.stderr)
        hosts_seen = [h["host"] for h in stats.per_host]
        rpc = [h["rpc_ms"] for h in stats.per_host]
        print(
            f"cluster smoke: n={n} p={p} B={B} k={k} hosts={hosts} "
            f"shards={num_shards} build={t_build:.1f}s "
            f"search={t_search * 1e3:.0f}ms per_host={hosts_seen} "
            f"rpc_ms={rpc}"
        )
        if bad:
            print(f"FAIL: {bad}/{B} queries mismatched", file=sys.stderr)
            return 1
        if len(stats.per_host) != hosts:
            print(f"FAIL: expected {hosts} per_host entries, got "
                  f"{len(stats.per_host)}", file=sys.stderr)
            return 1
        print("PASS: cluster merge bit-identical to linear_scan_knn")
        return 0
    finally:
        engine.close()


if __name__ == "__main__":
    sys.exit(run())
