"""Localhost worker fleet: the real wire protocol on one machine.

``LocalCluster(hosts)`` spawns one worker PROCESS per host on loopback
ephemeral ports and reports their addresses, so tests, the smoke canary,
and ``bench_serving --hosts`` exercise the exact coordinator/worker
protocol — framing, bound broadcast, heartbeats, death handling — with
no second machine.

The ``spawn`` start method is deliberate and load-bearing: each worker
must be a FRESH interpreter because the parent has usually initialized
jax (a fork-child of a jax-initialized process must never dispatch jax
ops), and because a real deployment's workers are independent processes
too — fork would quietly share page-cache state the wire protocol is
supposed to carry. Workers announce their bound ``(host, port)`` back
over a pipe before serving.

``kill_worker(i)`` SIGKILLs one worker — the failure-injection hook the
killed-worker tests use; ``close()`` terminates the fleet (idempotent).
"""

from __future__ import annotations

import multiprocessing as mp
from typing import List, Tuple

__all__ = ["LocalCluster"]


def _worker_main(announce) -> None:
    # runs in the spawned interpreter; imports resolve there
    from repro.cluster.worker import serve

    serve(host="127.0.0.1", port=0, announce=announce)


class LocalCluster:
    """``hosts`` spawned loopback workers; ``addresses[i]`` is worker
    ``i``'s ``(host, port)``."""

    def __init__(self, hosts: int, start_timeout: float = 120.0):
        if hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {hosts}")
        ctx = mp.get_context("spawn")
        self.procs: List[mp.Process] = []
        self.addresses: List[Tuple[str, int]] = []
        pipes = []
        try:
            for _ in range(hosts):
                parent, child = ctx.Pipe()
                proc = ctx.Process(
                    target=_worker_main, args=(child,), daemon=True
                )
                proc.start()
                child.close()
                self.procs.append(proc)
                pipes.append(parent)
            for i, parent in enumerate(pipes):
                if not parent.poll(start_timeout):
                    raise RuntimeError(
                        f"worker {i} did not announce its address "
                        f"within {start_timeout:.0f}s"
                    )
                self.addresses.append(tuple(parent.recv()))
                parent.close()
        except BaseException:
            self.close()
            raise

    def kill_worker(self, i: int) -> None:
        """SIGKILL worker ``i`` — no shutdown handshake, the coordinator
        sees a raw connection drop. Failure-injection hook for tests."""
        self.procs[i].kill()
        self.procs[i].join(timeout=10.0)

    def close(self) -> None:
        for proc in self.procs:
            if proc.is_alive():
                proc.terminate()
        for proc in self.procs:
            proc.join(timeout=10.0)
            if proc.is_alive():
                proc.kill()
                proc.join(timeout=5.0)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass   # interpreter shutdown
