"""One-command cluster launcher.

Worker hosts run:

    python -m repro.cluster.launch --role worker --port 9377

which binds the frame loop and waits; all layout flows from the
coordinator's ``build`` frame (each worker's host-partitioned
``ShardPlan.summary()`` plus its row slab), so worker invocations are
identical on every host — the MaxText multi-VM shape: one config, N
hosts, one command per host.

The coordinator host runs:

    python -m repro.cluster.launch --role coordinator \\
        --workers hostA:9377,hostB:9377 --data codes.npy --p 256 \\
        --queries 64 --k 10

which loads (or synthesizes) the packed code DB, balances a plan over
``--num-shards``, ships every worker its slice, answers ``--queries``
random queries through the cluster, and prints per-host attribution.
With ``--hosts N`` and no ``--workers``, a localhost fleet is spawned
instead — the quickest way to see the whole tier run on one machine.
``--check`` verifies every answer against ``linear_scan_knn`` exactly.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Tuple


def _parse_workers(spec: str) -> List[Tuple[str, int]]:
    out = []
    for part in spec.split(","):
        host, _, port = part.strip().rpartition(":")
        if not host or not port.isdigit():
            raise SystemExit(f"bad worker address {part!r} (want host:port)")
        out.append((host, int(port)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.cluster.launch",
        description="Run one role of the cross-host serving tier.",
    )
    ap.add_argument("--role", required=True,
                    choices=("coordinator", "worker"))
    # worker flags
    ap.add_argument("--bind", default="0.0.0.0",
                    help="worker: interface to listen on")
    ap.add_argument("--port", type=int, default=9377,
                    help="worker: listening port (0 = ephemeral)")
    # coordinator flags
    ap.add_argument("--workers", default=None,
                    help="coordinator: comma-separated host:port list")
    ap.add_argument("--hosts", type=int, default=2,
                    help="coordinator: spawn N localhost workers when "
                         "no --workers list is given")
    ap.add_argument("--data", default=None,
                    help="coordinator: .npy of packed (n, W) uint32 codes")
    ap.add_argument("--p", type=int, default=64,
                    help="coordinator: code length in bits")
    ap.add_argument("--synthetic", type=int, default=20000,
                    help="coordinator: synthetic DB rows when no --data")
    ap.add_argument("--num-shards", type=int, default=None,
                    help="coordinator: total shards (default: one/host)")
    ap.add_argument("--backend", default="sharded_amih",
                    choices=("sharded_amih", "sharded_scan"),
                    help="coordinator: per-worker engine")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="coordinator: verify vs linear_scan_knn")
    args = ap.parse_args(argv)

    if args.role == "worker":
        from .worker import WorkerServer

        srv = WorkerServer(args.bind, args.port)
        print(f"worker listening on {srv.addr[0]}:{srv.addr[1]}",
              flush=True)
        srv.serve_forever()
        return 0

    import numpy as np

    from ..core.engine import make_engine
    from ..core.linear_scan import linear_scan_knn
    from ..core.packing import pack_bits

    rng = np.random.default_rng(args.seed)
    if args.data:
        db_words = np.load(args.data)
        if db_words.ndim != 2:
            raise SystemExit(f"--data must be a packed (n, W) array, "
                             f"got shape {db_words.shape}")
    else:
        db_words = pack_bits(rng.integers(
            0, 2, size=(args.synthetic, args.p), dtype=np.uint8
        ))
    q_words = pack_bits(rng.integers(
        0, 2, size=(args.queries, args.p), dtype=np.uint8
    ))
    workers = _parse_workers(args.workers) if args.workers else None
    engine = make_engine(
        "cluster", db_words, args.p,
        hosts=args.hosts, workers=workers,
        inner_backend=args.backend, num_shards=args.num_shards,
    )
    try:
        t0 = time.perf_counter()
        ids, sims, stats = engine.knn_batch(q_words, args.k)
        dt = time.perf_counter() - t0
        print(f"answered {args.queries} queries (k={args.k}) over "
              f"{engine.n} rows x {engine.hosts} hosts in "
              f"{dt * 1e3:.1f}ms")
        print(json.dumps(stats.per_host, indent=2, default=str))
        if args.check:
            from ..core.linear_scan import sims_for_ids

            for i in range(args.queries):
                _ref_ids, ref_sims = linear_scan_knn(
                    q_words[i], db_words, args.k
                )
                # sims bit-identical; ids distinct and carrying those
                # sims (tie order inside a Hamming tuple may differ)
                if not (np.array_equal(sims[i], ref_sims)
                        and np.unique(ids[i]).size == sims[i].size
                        and np.array_equal(
                            sims_for_ids(q_words[i], db_words, ids[i]),
                            sims[i])):
                    print(f"MISMATCH on query {i}", file=sys.stderr)
                    return 1
            print("check: all queries exact vs linear_scan_knn")
        return 0
    finally:
        engine.close()


if __name__ == "__main__":
    sys.exit(main())
