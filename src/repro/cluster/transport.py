"""Length-prefixed TCP framing for the cross-host serving tier.

One frame = MAGIC, a big-endian uint32 header length, a JSON header, and
the raw bytes of zero or more C-contiguous numpy arrays back to back:

    +------+-----------+----------------+---------------------------+
    | AMRP | hdr_len   | JSON header    | array 0 bytes | array 1 … |
    +------+-----------+----------------+---------------------------+

The header carries the frame ``kind`` (the protocol verb — see
docs/cluster.md for the full verb table), any JSON-serializable ``meta``
fields, and an ``arrays`` list of ``{name, dtype, shape}`` descriptors
in payload order — enough to slice every array back out of the payload
without pickling anything. stdlib + numpy only: ``socket``, ``struct``
and ``json`` are the whole dependency surface.

Reads loop until the requested byte count arrives (TCP is a byte
stream; short reads are normal) and raise ``FrameError`` on EOF
mid-frame, oversized declarations, or a bad magic — a coordinator
treats any of those as the peer being gone. Writes go through
``sendall`` under the caller's per-socket lock, so heartbeat, bound,
and result frames from different threads never interleave mid-frame.

Ragged per-query planes (the bounded search returns a different row
count per query) travel as a (concatenated values, per-query lengths)
pair — ``pack_ragged``/``unpack_ragged``.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FrameError",
    "MAGIC",
    "pack_ragged",
    "recv_exact",
    "recv_frame",
    "send_frame",
    "unpack_ragged",
]

MAGIC = b"AMRP"

# Fail-fast guards against a corrupt or hostile length prefix: a real
# header is a few KB of JSON; a real payload is query words + O(K)
# result planes. Way above both, way below an allocation bomb.
MAX_HEADER = 1 << 24       # 16 MiB
MAX_PAYLOAD = 1 << 31      # 2 GiB

_LEN = struct.Struct(">I")

# dtypes the protocol ships; anything else is a programming error on the
# sending side, caught before bytes hit the wire.
_WIRE_DTYPES = frozenset({
    "uint8", "uint32", "uint64", "int32", "int64", "float32", "float64",
})


class FrameError(ConnectionError):
    """The peer vanished mid-frame or sent bytes that are not a frame."""


def recv_exact(sock: socket.socket, nbytes: int) -> bytearray:
    """Read exactly ``nbytes`` (looping over partial reads). Raises
    FrameError on EOF before the count is met — a half-delivered frame
    means the peer died, never a recoverable state. Returns a bytearray
    so numpy views over it are writable."""
    buf = bytearray(nbytes)
    view = memoryview(buf)
    got = 0
    while got < nbytes:
        r = sock.recv_into(view[got:], nbytes - got)
        if r == 0:
            raise FrameError(
                f"connection closed mid-frame ({got}/{nbytes} bytes)"
            )
        got += r
    return buf


def send_frame(
    sock: socket.socket,
    kind: str,
    meta: Optional[Dict[str, Any]] = None,
    arrays: Optional[Dict[str, np.ndarray]] = None,
    lock=None,
) -> None:
    """Serialize and send one frame. ``arrays`` values are forced
    C-contiguous; dtypes outside the wire set raise before any byte is
    sent. ``lock`` (a threading.Lock) spans the whole write so frames
    from concurrent senders (heartbeat vs bound vs result threads)
    never interleave."""
    header: Dict[str, Any] = {"kind": kind}
    if meta:
        header.update(meta)
    chunks: List[bytes] = []
    descr: List[Dict[str, Any]] = []
    for name, arr in (arrays or {}).items():
        arr = np.ascontiguousarray(arr)
        if str(arr.dtype) not in _WIRE_DTYPES:
            raise ValueError(
                f"array {name!r} has non-wire dtype {arr.dtype}"
            )
        descr.append({
            "name": name,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        })
        chunks.append(arr.tobytes())
    header["arrays"] = descr
    hdr = json.dumps(header, separators=(",", ":")).encode()
    if len(hdr) > MAX_HEADER:
        raise ValueError(f"header too large: {len(hdr)} bytes")
    payload = b"".join(chunks)
    frame = MAGIC + _LEN.pack(len(hdr)) + hdr + payload
    if lock is not None:
        with lock:
            sock.sendall(frame)
    else:
        sock.sendall(frame)


def recv_frame(
    sock: socket.socket, timeout: Optional[float] = None
) -> Tuple[str, Dict[str, Any], Dict[str, np.ndarray]]:
    """Receive one frame -> (kind, meta, arrays). ``timeout`` bounds the
    wait for the frame's FIRST byte (socket.timeout propagates to the
    caller); once a frame has started arriving, the remainder is read
    without a deadline — a peer that stalls mid-frame is caught by the
    heartbeat layer, not here."""
    if timeout is not None:
        sock.settimeout(timeout)
    try:
        magic = recv_exact(sock, len(MAGIC))
    finally:
        if timeout is not None:
            sock.settimeout(None)
    if bytes(magic) != MAGIC:
        raise FrameError(f"bad frame magic {bytes(magic)!r}")
    (hdr_len,) = _LEN.unpack(recv_exact(sock, _LEN.size))
    if hdr_len > MAX_HEADER:
        raise FrameError(f"declared header of {hdr_len} bytes")
    try:
        header = json.loads(bytes(recv_exact(sock, hdr_len)))
    except ValueError as e:
        raise FrameError(f"undecodable frame header: {e}") from None
    descr = header.pop("arrays", [])
    total = 0
    for d in descr:
        if str(d["dtype"]) not in _WIRE_DTYPES:
            raise FrameError(f"non-wire dtype {d['dtype']!r} declared")
        try:
            dims = [int(x) for x in d["shape"]]
        except (TypeError, ValueError) as e:
            raise FrameError(f"undecodable shape declared: {e}") from None
        if any(x < 0 for x in dims):
            # a negative dim makes np.prod negative, which would slip
            # under MAX_PAYLOAD and reach np.frombuffer as a bad count
            raise FrameError(f"negative dimension in declared shape {dims}")
        d["shape"] = dims
        total += int(np.prod(dims, dtype=np.int64)) * \
            np.dtype(d["dtype"]).itemsize
    if total > MAX_PAYLOAD:
        raise FrameError(f"declared payload of {total} bytes")
    payload = recv_exact(sock, total) if total else bytearray()
    arrays: Dict[str, np.ndarray] = {}
    off = 0
    for d in descr:
        dt = np.dtype(d["dtype"])
        shape = tuple(int(x) for x in d["shape"])
        size = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
        arrays[d["name"]] = np.frombuffer(
            payload, dtype=dt, count=int(np.prod(shape, dtype=np.int64)),
            offset=off,
        ).reshape(shape)
        off += size
    kind = header.pop("kind", "")
    return kind, header, arrays


# ---------------------------------------------------------- ragged planes
def pack_ragged(
    planes: Sequence[np.ndarray], dtype=None
) -> Tuple[np.ndarray, np.ndarray]:
    """Per-query ragged arrays -> (concatenated values, int64 lengths).
    The inverse of ``unpack_ragged``; an all-empty list round-trips to
    a (0,) values array."""
    lens = np.array([p.shape[0] for p in planes], dtype=np.int64)
    if planes:
        flat = np.concatenate([np.asarray(p) for p in planes])
    else:
        flat = np.empty(0, dtype=dtype or np.float64)
    if dtype is not None:
        flat = flat.astype(dtype, copy=False)
    return flat, lens


def unpack_ragged(
    flat: np.ndarray, lens: np.ndarray
) -> List[np.ndarray]:
    """(values, lengths) -> per-query list; validates that the lengths
    consume the values array exactly."""
    lens = np.asarray(lens, dtype=np.int64)
    if int(lens.sum()) != flat.shape[0]:
        raise FrameError(
            f"ragged lengths sum to {int(lens.sum())}, "
            f"payload has {flat.shape[0]} values"
        )
    out: List[np.ndarray] = []
    off = 0
    for ln in lens:
        out.append(flat[off : off + int(ln)])
        off += int(ln)
    return out
