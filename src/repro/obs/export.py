"""Chrome trace-event JSON export + JSONL metrics dump.

``write_chrome_trace`` turns a span list (or a live Tracer) into the
Chrome trace-event format Perfetto and chrome://tracing load directly:
one complete ("ph": "X") event per span with µs timestamps, processes
keyed by span ``host`` tag (so a merged cross-host trace renders as
one process lane per host), and ``process_name`` metadata events
labelling each lane. ``load_chrome_trace`` is the validating loader
the benches and the report CLI share — it raises ``ValueError`` on
anything Perfetto would reject.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Union

from .metrics import REGISTRY, MetricsRegistry
from .trace import Tracer

__all__ = [
    "chrome_trace_doc",
    "load_chrome_trace",
    "write_chrome_trace",
    "write_metrics_jsonl",
]


def chrome_trace_doc(
    spans: Sequence[Dict[str, Any]],
    trace_id: Optional[str] = None,
) -> Dict[str, Any]:
    """Span dicts -> Chrome trace-event document (a plain dict)."""
    # One synthetic pid per host tag: Perfetto renders each as its own
    # process track, which is exactly the mental model for a cluster
    # trace (coordinator lane + one lane per worker host).
    hosts: Dict[str, int] = {}
    events: List[Dict[str, Any]] = []
    for s in spans:
        host = str(s.get("host", "local"))
        pid = hosts.get(host)
        if pid is None:
            pid = hosts[host] = len(hosts) + 1
            events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": host},
            })
        ev: Dict[str, Any] = {
            "name": str(s.get("name", "?")),
            "cat": str(s.get("cat", "span")),
            "ph": "X",
            "ts": float(s.get("ts", 0.0)),
            "dur": float(s.get("dur", 0.0)),
            "pid": pid,
            "tid": int(s.get("tid", 0)),
        }
        args = dict(s.get("args") or {})
        if s.get("trace"):
            args["trace"] = s["trace"]
        if args:
            ev["args"] = args
        events.append(ev)
    doc: Dict[str, Any] = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
    }
    if trace_id:
        doc["metadata"] = {"trace_id": trace_id}
    return doc


def write_chrome_trace(
    spans_or_tracer: Union[Tracer, Sequence[Dict[str, Any]]],
    path: str,
) -> int:
    """Write a Perfetto-loadable trace file; returns the span count."""
    if isinstance(spans_or_tracer, Tracer):
        spans = spans_or_tracer.snapshot()
        trace_id = spans_or_tracer.trace_id
    else:
        spans = list(spans_or_tracer)
        trace_id = next(
            (s.get("trace") for s in spans if s.get("trace")), None
        )
    doc = chrome_trace_doc(spans, trace_id=trace_id)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(spans)


def load_chrome_trace(path: str) -> Dict[str, Any]:
    """Load + validate a trace file. Raises ValueError on anything that
    is not a well-formed Chrome trace-event document."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(
        doc.get("traceEvents"), list
    ):
        raise ValueError(f"{path}: not a Chrome trace-event document")
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            raise ValueError(f"{path}: malformed trace event {ev!r}")
        if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
            raise ValueError(
                f"{path}: complete event without ts/dur: {ev!r}"
            )
    return doc


def write_metrics_jsonl(
    path: str, registry: MetricsRegistry = REGISTRY
) -> None:
    """Dump the registry snapshot as one JSON object per line."""
    registry.dump_jsonl(path)
