"""Zero-dependency tracing + metrics for the AMIH serving stack.

Three stdlib-only modules (numpy never enters the picture, so fork
children and spawned cluster workers can import this package without
dragging jax in):

  - ``trace``   — monotonic-clock spans with thread-local nesting, a
                  sampling knob, and a cheap no-op path when disabled.
  - ``metrics`` — a process-wide registry of counters and bounded
                  histograms with percentile snapshots; the unified
                  surface behind ``ops.LAUNCH_COUNTS``, the probing
                  cache stats, and the serving ``LatencyTracker``.
  - ``export``  — Chrome trace-event JSON (Perfetto-loadable) plus a
                  JSONL metrics dump; ``python -m repro.obs.report``
                  summarizes a trace file into a per-stage breakdown.

Tracing is OFF by default: every instrumentation site checks one
attribute (``Tracer.enabled``) and falls through. Spans observe, never
reorder — enabling tracing cannot change search results.
"""

from .metrics import Counter, Histogram, MetricsRegistry, REGISTRY
from .trace import (
    NOOP_SPAN,
    Tracer,
    current,
    disable,
    enable,
    now_us,
    set_tracer,
)

__all__ = [
    "Counter",
    "Histogram",
    "MetricsRegistry",
    "NOOP_SPAN",
    "REGISTRY",
    "Tracer",
    "current",
    "disable",
    "enable",
    "now_us",
    "set_tracer",
]
