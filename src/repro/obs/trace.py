"""Monotonic-clock spans with thread-local nesting and a no-op fast path.

A span is a plain dict — ``{"name", "cat", "ts", "dur", "pid", "tid",
"host", "trace", "args"}`` with ``ts``/``dur`` in microseconds on the
``time.perf_counter`` clock — so spans cross fork pipes and the AMRP
wire as JSON without a serialization layer. On Linux ``perf_counter``
is CLOCK_MONOTONIC, which is system-wide: spans recorded in fork
children and spawned localhost workers land on the same timeline as
the parent without adjustment. Cross-host spans are shifted by the
coordinator's ping/pong clock-offset estimate at merge time
(``Tracer.ingest``).

Instrumentation contract: every hot-path site fetches the process
tracer once (``current()``) and checks ``.enabled`` — a single
attribute read — before touching the clock. The inner AMIH loop uses
explicit ``if tr.enabled:`` guards around ``now_us()``/``record()``;
colder sites use the ``span()`` context manager, which returns a
shared no-op object when tracing is off.

Sampling: ``sample`` is a probability applied when a TOP-LEVEL span
opens on a thread; the decision is inherited by every nested span, so
a sampled-out subtree vanishes whole and nesting invariants survive.
``record()`` (used for dispatch→resolve pairs whose endpoints live in
different call sites) bypasses the stack and is kept whenever tracing
is enabled.
"""

from __future__ import annotations

import os
import random
import threading
import time
import uuid
from typing import Any, Dict, List, Optional

__all__ = [
    "NOOP_SPAN",
    "Tracer",
    "current",
    "disable",
    "enable",
    "new_trace_id",
    "now_us",
    "set_tracer",
]


def now_us() -> float:
    """Microseconds on the monotonic perf_counter clock."""
    return time.perf_counter() * 1e6


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


class _NoopSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _SpanCtx:
    """One live span; append-on-exit so children land before parents
    only by end time (Perfetto nests by interval containment)."""

    __slots__ = ("_tr", "name", "cat", "args", "_t0", "_keep", "_depth")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]], keep: bool, depth: int):
        self._tr = tr
        self.name = name
        self.cat = cat
        self.args = args
        self._keep = keep
        self._depth = depth
        self._t0 = 0.0

    def __enter__(self):
        self._t0 = now_us()
        return self

    def __exit__(self, *exc):
        t1 = now_us()
        tls = self._tr._tls
        tls.stack.pop()
        if self._keep:
            self._tr.record(self.name, self._t0, t1, cat=self.cat,
                            depth=self._depth, **(self.args or {}))
        return False


class Tracer:
    """Bounded process-wide span sink.

    ``enabled`` is the only attribute the hot path reads when tracing
    is off. ``max_spans`` bounds memory (and the size of span payloads
    shipped over pipes and result frames); overflow increments
    ``dropped`` instead of growing the buffer.
    """

    def __init__(self, enabled: bool = False, sample: float = 1.0,
                 host: str = "local", trace_id: Optional[str] = None,
                 max_spans: int = 262144):
        self.enabled = bool(enabled)
        self.sample = float(sample)
        self.host = str(host)
        self.trace_id = trace_id or new_trace_id()
        self.max_spans = int(max_spans)
        self.dropped = 0
        self._spans: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._rng = random.Random()

    # ------------------------------------------------------------ spans
    def span(self, name: str, cat: str = "span",
             **args: Any):
        """Context manager for a nested span. No-op when disabled or
        when the enclosing top-level span was sampled out."""
        if not self.enabled:
            return NOOP_SPAN
        tls = self._tls
        stack = getattr(tls, "stack", None)
        if stack is None:
            stack = tls.stack = []
        if stack:
            keep = stack[-1]
        elif self.sample >= 1.0:
            keep = True
        else:
            keep = self._rng.random() < self.sample
        stack.append(keep)
        return _SpanCtx(self, name, cat, args or None, keep,
                        len(stack) - 1)

    def record(self, name: str, t0_us: float, t1_us: float,
               cat: str = "span", **args: Any) -> None:
        """Append a completed span from explicit timestamps (dispatch →
        resolve pairs measure their endpoints manually)."""
        if not self.enabled:
            return
        span = {
            "name": name,
            "cat": cat,
            "ts": float(t0_us),
            "dur": max(0.0, float(t1_us) - float(t0_us)),
            "pid": os.getpid(),
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "host": self.host,
            "trace": self.trace_id,
        }
        if args:
            span["args"] = args
        with self._lock:
            if len(self._spans) >= self.max_spans:
                self.dropped += 1
            else:
                self._spans.append(span)

    # --------------------------------------------------------- plumbing
    def ingest(self, spans, shift_us: float = 0.0,
               host: Optional[str] = None) -> None:
        """Fold spans recorded elsewhere (fork child, remote worker)
        into this tracer's buffer, shifting their clock by ``shift_us``
        (the coordinator's offset estimate; 0 for same-machine spans)."""
        if not spans:
            return
        with self._lock:
            for s in spans:
                if len(self._spans) >= self.max_spans:
                    self.dropped += len(spans)
                    break
                s = dict(s)
                if shift_us:
                    s["ts"] = float(s.get("ts", 0.0)) - float(shift_us)
                if host is not None:
                    s.setdefault("host", host)
                s["trace"] = self.trace_id
                self._spans.append(s)

    def snapshot(self) -> List[Dict[str, Any]]:
        """Copy of the span buffer (non-destructive)."""
        with self._lock:
            return [dict(s) for s in self._spans]

    def drain(self) -> List[Dict[str, Any]]:
        """Return and clear the span buffer."""
        with self._lock:
            out, self._spans = self._spans, []
            return out

    def clear(self) -> None:
        with self._lock:
            self._spans = []
            self.dropped = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)


# A permanently-disabled tracer is the default: instrumentation sites
# pay one attribute read per call until someone installs a live one.
_ACTIVE = Tracer(enabled=False)
_ACTIVE_LOCK = threading.Lock()


def current() -> Tracer:
    """The process-wide active tracer (disabled unless installed)."""
    return _ACTIVE


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process tracer; returns the previous
    one so callers can restore it."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, tracer
    return prev


def enable(sample: float = 1.0, host: str = "local",
           trace_id: Optional[str] = None, max_spans: int = 262144) -> Tracer:
    """Install and return a fresh enabled tracer."""
    tr = Tracer(enabled=True, sample=sample, host=host,
                trace_id=trace_id, max_spans=max_spans)
    set_tracer(tr)
    return tr


def disable() -> Tracer:
    """Install a disabled tracer; returns the previous (possibly live)
    tracer so its spans can still be exported."""
    return set_tracer(Tracer(enabled=False))
