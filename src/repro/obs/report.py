"""Per-stage breakdown of a Chrome trace file.

    python -m repro.obs.report trace.json [--min-hosts N] [--min-stages N]

Groups complete events by span name, prints count / total / mean /
share-of-wall per stage plus the host lanes found, and exits nonzero
if the file is not a valid trace or the ``--min-*`` floors are unmet —
which is exactly what the verify.sh trace smoke asserts.
"""

from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List

from .export import load_chrome_trace

__all__ = ["main", "summarize"]


def summarize(doc: Dict[str, Any]) -> Dict[str, Any]:
    """Trace document -> {wall_ms, hosts, stages: {name: {...}}}."""
    pid_host: Dict[int, str] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            pid_host[int(ev.get("pid", 0))] = str(
                (ev.get("args") or {}).get("name", ev.get("pid"))
            )
    stages: Dict[str, Dict[str, Any]] = {}
    hosts = set()
    t_min, t_max = float("inf"), float("-inf")
    for ev in doc["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        ts, dur = float(ev["ts"]), float(ev["dur"])
        t_min = min(t_min, ts)
        t_max = max(t_max, ts + dur)
        host = pid_host.get(int(ev.get("pid", 0)), str(ev.get("pid", "?")))
        hosts.add(host)
        st = stages.setdefault(ev["name"], {
            "count": 0, "total_ms": 0.0, "hosts": set(),
        })
        st["count"] += 1
        st["total_ms"] += dur / 1000.0
        st["hosts"].add(host)
    wall_ms = 0.0 if t_max < t_min else (t_max - t_min) / 1000.0
    for st in stages.values():
        st["mean_ms"] = st["total_ms"] / max(1, st["count"])
        st["hosts"] = sorted(st["hosts"])
    return {"wall_ms": wall_ms, "hosts": sorted(hosts), "stages": stages}


def _print_summary(summary: Dict[str, Any]) -> None:
    wall = summary["wall_ms"]
    hosts: List[str] = summary["hosts"]
    stages = summary["stages"]
    print(f"trace wall time: {wall:.3f} ms across "
          f"{len(hosts)} host(s): {', '.join(hosts)}")
    if not stages:
        print("no spans.")
        return
    name_w = max(len(n) for n in stages)
    hdr = (f"{'stage':<{name_w}}  {'count':>7}  {'total ms':>10}  "
           f"{'mean ms':>9}  {'% wall':>7}  hosts")
    print(hdr)
    print("-" * len(hdr))
    for name in sorted(stages, key=lambda n: -stages[n]["total_ms"]):
        st = stages[name]
        share = 100.0 * st["total_ms"] / wall if wall > 0 else 0.0
        print(f"{name:<{name_w}}  {st['count']:>7}  "
              f"{st['total_ms']:>10.3f}  {st['mean_ms']:>9.3f}  "
              f"{share:>6.1f}%  {len(st['hosts'])}")
    total = sum(st["total_ms"] for st in stages.values())
    print(f"summed stage time: {total:.3f} ms "
          f"(> wall is normal: spans nest and hosts overlap)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Per-stage time breakdown of a Chrome trace file.",
    )
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--min-hosts", type=int, default=0,
                    help="fail unless spans from at least N hosts")
    ap.add_argument("--min-stages", type=int, default=0,
                    help="fail unless at least N distinct span names")
    args = ap.parse_args(argv)
    try:
        doc = load_chrome_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    summary = summarize(doc)
    _print_summary(summary)
    if len(summary["hosts"]) < args.min_hosts:
        print(f"error: spans from {len(summary['hosts'])} host(s), "
              f"need >= {args.min_hosts}", file=sys.stderr)
        return 1
    if len(summary["stages"]) < args.min_stages:
        print(f"error: {len(summary['stages'])} distinct stage(s), "
              f"need >= {args.min_stages}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
