"""Process-wide metrics registry: counters + bounded histograms.

One named surface replaces the scattered ad-hoc counters that grew up
with the stack: ``ops.LAUNCH_COUNTS`` bumps land here under
``launches.*``, the probing/schedule cache hit rates under ``cache.*``,
and the serving tier's rolling latency window under ``serve.*`` (the
``LatencyTracker`` in ``pipeline/stream.py`` is now a thin wrapper over
``Histogram``). Pure stdlib — percentiles are nearest-rank over a
bounded sample window, no numpy.

Thread safety: every mutation takes the instrument's own lock; the
registry lock only guards name → instrument creation, so two threads
bumping different counters never contend.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional

__all__ = ["Counter", "Histogram", "MetricsRegistry", "REGISTRY"]


class Counter:
    """Monotonic (well, add-anything) integer counter."""

    __slots__ = ("_value", "_lock")

    def __init__(self) -> None:
        self._value = 0
        self._lock = threading.Lock()

    def add(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, n: int) -> None:
        with self._lock:
            self._value = int(n)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


def _percentile(sorted_samples: List[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (q in [0,1])."""
    n = len(sorted_samples)
    idx = min(n - 1, max(0, int(round(q * (n - 1)))))
    return sorted_samples[idx]


class Histogram:
    """Bounded rolling window of samples with percentile snapshots.

    Keeps the most recent ``window`` samples (older ones age out, so a
    long-running server reports RECENT latency, not lifetime latency)
    plus lifetime count/sum so totals survive the trim.
    """

    __slots__ = ("window", "_samples", "_count", "_sum", "_max", "_lock")

    def __init__(self, window: int = 4096) -> None:
        self.window = int(window)
        self._samples: List[float] = []
        self._count = 0
        self._sum = 0.0
        self._max = float("-inf")
        self._lock = threading.Lock()

    def record(self, value: float, count: int = 1) -> None:
        """Add ``value`` (``count`` duplicate samples at once mirrors
        LatencyTracker's batch-amortized recording)."""
        v = float(value)
        with self._lock:
            self._samples.extend([v] * count)
            extra = len(self._samples) - self.window
            if extra > 0:
                del self._samples[:extra]
            self._count += count
            self._sum += v * count
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def snapshot(self) -> Dict[str, float]:
        """{} when no samples yet; else nearest-rank p50/p99 over the
        window plus window mean, lifetime count, and lifetime max."""
        with self._lock:
            if not self._samples:
                return {}
            srt = sorted(self._samples)
            return {
                "p50": round(_percentile(srt, 0.50), 3),
                "p99": round(_percentile(srt, 0.99), 3),
                "mean": round(sum(srt) / len(srt), 3),
                "max": round(self._max, 3),
                "count": self._count,
            }


class MetricsRegistry:
    """Name → Counter/Histogram, created on first touch."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------- instruments
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def histogram(self, name: str, window: int = 4096) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(window))
        return h

    # ------------------------------------------------------------ reads
    def value(self, name: str) -> int:
        """Counter value; 0 for a counter that was never bumped."""
        c = self._counters.get(name)
        return 0 if c is None else c.value

    def values(self, prefix: str = "") -> Dict[str, int]:
        """All counter values whose name starts with ``prefix``."""
        with self._lock:
            names = [n for n in self._counters if n.startswith(prefix)]
        return {n: self._counters[n].value for n in names}

    def snapshot(self) -> Dict[str, Any]:
        """Flat dict of every counter value and histogram snapshot."""
        out: Dict[str, Any] = {}
        with self._lock:
            counters = list(self._counters.items())
            histograms = list(self._histograms.items())
        for name, c in counters:
            out[name] = c.value
        for name, h in histograms:
            snap = h.snapshot()
            if snap:
                out[name] = snap
        return out

    def dump_jsonl(self, path: str) -> None:
        """One JSON line per metric — greppable, appendable."""
        snap = self.snapshot()
        with open(path, "w") as f:
            for name in sorted(snap):
                f.write(json.dumps({"metric": name, "value": snap[name]})
                        + "\n")

    def reset(self, prefix: Optional[str] = None) -> None:
        """Zero counters and drop histograms (tests; ``prefix`` scopes
        the reset)."""
        with self._lock:
            for name, c in self._counters.items():
                if prefix is None or name.startswith(prefix):
                    c.set(0)
            if prefix is None:
                self._histograms.clear()
            else:
                for name in [n for n in self._histograms
                             if n.startswith(prefix)]:
                    del self._histograms[name]


#: The process-wide registry every instrumented layer writes to.
REGISTRY = MetricsRegistry()
