"""Traced end-to-end cluster smoke: ``python -m repro.obs.smoke``.

Builds a 2-localhost-worker cluster engine over a small random DB with
tracing enabled, runs one ``knn_batch``, asserts the results are
bit-identical to ``linear_scan_knn`` (tracing observes, never reorders),
and writes one Chrome-trace JSON containing coordinator RPC spans and
per-worker probe/verify spans under a single trace id. ``verify.sh``
runs this and then ``repro.obs.report`` over the output with host/stage
floors — the cheapest proof that the distributed-trace plumbing (AMRP
``trace`` meta out, ``spans`` meta back, clock-offset ingest) works.

Needs a real spawned-process fleet, so it must run as a module (the
multiprocessing spawn start method re-imports ``__main__``).
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

from . import trace as _obs
from .export import load_chrome_trace, write_chrome_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="traced 2-worker cluster search smoke"
    )
    ap.add_argument("--out", default="obs_smoke_trace.json",
                    help="Chrome trace output path")
    ap.add_argument("--n", type=int, default=2000, help="DB rows")
    ap.add_argument("--p", type=int, default=64, help="code bits")
    ap.add_argument("--batch", type=int, default=8, help="queries")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--probe-backend", default="host",
                    choices=("host", "device"),
                    help="worker probe backend (host + pallas verify "
                         "covers the amih.* AND launch.* span families; "
                         "device trades the amih.probe spans for fused "
                         "device-probe launch spans)")
    ap.add_argument("--verify-backend", default="pallas",
                    choices=("numpy", "pallas"),
                    help="worker verify backend (pallas: grouped verify "
                         "device launches appear in the trace)")
    args = ap.parse_args(argv)

    from ..core.engine import make_engine
    from ..core.linear_scan import linear_scan_knn, sims_for_ids
    from ..core.packing import pack_bits

    rng = np.random.default_rng(0)
    db = pack_bits(rng.integers(0, 2, (args.n, args.p), dtype=np.uint8))
    q = pack_bits(
        rng.integers(0, 2, (args.batch, args.p), dtype=np.uint8)
    )

    tracer = _obs.Tracer(enabled=True, host="coordinator")
    eng = make_engine(
        "cluster", db, args.p, hosts=2, num_shards=2,
        probe_backend=args.probe_backend,
        verify_backend=args.verify_backend, tracer=tracer,
    )
    try:
        ids, sims, _ = eng.knn_batch(q, args.k)
    finally:
        eng.close()

    # same exactness contract as repro.cluster.smoke: sims bit-identical
    # to the scan, ids distinct and really carrying those sims (id order
    # inside one exact-sim tie may differ)
    for i in range(args.batch):
        _ref_ids, ref_sims = linear_scan_knn(q[i], db, args.k)
        ok = (
            np.array_equal(sims[i], ref_sims)
            and np.unique(ids[i]).size == ids[i].size
            and np.array_equal(sims_for_ids(q[i], db, ids[i]), sims[i])
        )
        if not ok:
            print(f"FAIL: traced cluster query {i} differs from "
                  f"linear scan", file=sys.stderr)
            return 1

    n_spans = write_chrome_trace(tracer, args.out)
    load_chrome_trace(args.out)   # must be Perfetto-loadable JSON
    spans = tracer.snapshot()
    hosts = sorted({s["host"] for s in spans})
    stages = sorted({s["name"] for s in spans})
    print(f"wrote {args.out}: {n_spans} spans, "
          f"{len(hosts)} hosts {hosts}, {len(stages)} stages")
    if len(hosts) < 3:   # coordinator + 2 workers
        print(f"FAIL: expected spans from coordinator + 2 workers, "
              f"got hosts {hosts}", file=sys.stderr)
        return 1
    if not any(s["name"].startswith("launch.") for s in spans):
        print("FAIL: no device-launch span in trace", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
