"""Shared benchmark utilities: timing, dataset prep, CSV emission."""

from __future__ import annotations

import csv
import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import AMIHIndex, pack_bits
from repro.data import synthetic_binary_codes, synthetic_queries

ART_DIR = os.environ.get("REPRO_BENCH_DIR", "artifacts/bench")


def timer(fn: Callable, *args, repeat: int = 3, **kw) -> float:
    """Median wall seconds of fn(*args)."""
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def write_csv(name: str, rows: List[Dict], field_order=None):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name)
    if not rows:
        return path
    fields = field_order or list(rows[0].keys())
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path


def make_db(n: int, p: int, seed: int = 0, mode: str = "clustered"):
    bits = synthetic_binary_codes(n, p, seed=seed, mode=mode)
    return bits, pack_bits(bits)


def make_queries(db_bits: np.ndarray, nq: int, seed: int = 1):
    qbits = synthetic_queries(db_bits, nq, seed=seed)
    return qbits, pack_bits(qbits)
