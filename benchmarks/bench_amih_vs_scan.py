"""Paper Fig. 5 + Table 2: AMIH vs linear scan, 64/128-bit, K in {1,10,100}.

The paper sweeps SIFT-1B/TRC2 up to 10^9 items on a 256 GB machine; this
container sweeps synthetic AQBC-like clustered codes up to 10^6 (env
REPRO_BENCH_MAX_N overrides) and validates the paper's *claims*:
query time growing ~sqrt(n) for AMIH vs linearly for scan, speedups
growing with n into orders of magnitude.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import AMIHIndex, linear_scan_knn

from .common import make_db, make_queries, timer, write_csv


def run(max_n: int | None = None, nq: int = 20):
    max_n = max_n or int(os.environ.get("REPRO_BENCH_MAX_N", 1_000_000))
    sizes = [n for n in (10_000, 100_000, 1_000_000, 10_000_000) if n <= max_n]
    rows = []
    for p in (64, 128):
        for n in sizes:
            db_bits, db = make_db(n, p, seed=0)
            _, qs = make_queries(db_bits, nq, seed=1)
            t_build0 = time.perf_counter()
            idx = AMIHIndex.build(db, p)
            t_build = time.perf_counter() - t_build0
            for K in (1, 10, 100):
                t_amih = np.median([
                    timer(idx.knn, q, K, repeat=1) for q in qs
                ])
                t_scan = np.median([
                    timer(linear_scan_knn, q, db, K, repeat=1) for q in qs
                ])
                rows.append({
                    "p": p, "n": n, "K": K, "m_tables": idx.m,
                    "amih_ms": round(t_amih * 1e3, 4),
                    "scan_ms": round(t_scan * 1e3, 4),
                    "speedup": round(t_scan / max(t_amih, 1e-9), 2),
                    "index_build_s": round(t_build, 3),
                })
                print(
                    f"p={p} n={n:>9} K={K:>3} m={idx.m} "
                    f"amih={rows[-1]['amih_ms']:.3f}ms "
                    f"scan={rows[-1]['scan_ms']:.3f}ms "
                    f"speedup={rows[-1]['speedup']}x"
                )
    path = write_csv("amih_vs_scan.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
