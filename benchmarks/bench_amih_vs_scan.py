"""Paper Fig. 5 + Table 2 through the unified SearchEngine: AMIH vs
linear scan, with a batch-size axis (the serving shape).

For every (p, n, K, batch) cell the same workload is timed three ways:

  - engine "amih", batched ``knn_batch`` (probing-sequence sharing),
  - the seed-style single-query loop (``AMIHIndex.knn`` per query), and
  - engine "linear_scan" (batched exhaustive baseline).

The paper sweeps SIFT-1B/TRC2 up to 10^9 items on a 256 GB machine; this
container sweeps synthetic AQBC-like clustered codes (env
REPRO_BENCH_MAX_N / --max-n override the ceiling) and validates the
paper's *claims*: query time growing ~sqrt(n) for AMIH vs linearly for
scan, speedups growing with n into orders of magnitude, and batched
probing amortizing the per-query overhead.

A ``--shards`` axis times the pod-scale backends ("sharded_scan" /
"sharded_amih", repro.shard) over host-mode ShardPlan layouts at each
shard count (default 1 vs 8), so the perf trajectory covers the sharded
cells too.

A ``--probe-backend`` axis times every amih / sharded_amih cell under
both probing walks — "host" (the reference Python walk) and "device"
(the fused batch walk, ONE launch per knn_batch call with every z-group
stacked in; repro.core.probe_device) — and each row records which one
answered it, so scripts/bench_check.py gates host-vs-host and
device-vs-device separately. Device rows also record the launch economy
(walk/scan launches per sweep, ``launches_per_batch``), which
bench_check gates against the committed baseline.

Emits artifacts/bench/amih_vs_scan.csv plus a machine-readable
BENCH_engine.json at the repo root (per-backend, per-batch-size,
per-shard-count latency/probes/verifications) so future PRs have a perf
trajectory.

Run:  PYTHONPATH=src python benchmarks/bench_amih_vs_scan.py --batch 64
"""

from __future__ import annotations

import argparse
import contextlib
import gc
import json
import os
import sys
import time

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
if __package__ in (None, ""):  # run as a script: fix up both import roots
    sys.path.insert(0, _HERE)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    from common import make_db, make_queries, write_csv
else:
    from .common import make_db, make_queries, write_csv

from repro.core import make_engine
from repro.core.probing import probing_cache_clear

BENCH_JSON = os.path.join(_ROOT, "BENCH_engine.json")


REPEATS = 3  # best-of; host timing at sub-ms/query is noisy, and a
             # single transient (GC, scheduler) can poison a 2-sample min


@contextlib.contextmanager
def _gc_paused():
    """Collect outside the timed region, then keep the collector off
    inside it. A long sweep keeps every engine/db/jit cache alive, so a
    gen-2 collection grows to tens of ms — and a cell timed as ONE
    fused-launch call per sweep can't dodge a pause by best-of-REPEATS
    the way a many-small-calls cell does. Timing with the collector
    paused measures the algorithm for both shapes alike."""
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def _verify_launches(engine) -> int:
    """Grouped-verify dispatches so far: the single index's counter, or
    the per-shard sum for the sharded AMIH backend."""
    index = getattr(engine, "index", None)
    if index is not None:
        return getattr(index, "verify_launches", 0)
    return sum(
        ix.verify_launches for _, ix in getattr(engine, "indexes", [])
    )


def _probe_launch_counts():
    """(walk, scan) device probe launch counters so far, read from the
    metrics registry (repro.obs.metrics — the counters exist as 0 even
    before jax/the device probe path was ever imported)."""
    from repro.obs.metrics import REGISTRY

    return (REGISTRY.value("launches.device_probe"),
            REGISTRY.value("launches.device_probe_scan"))


def _time_batched(engine, qs, k, batch):
    """Best-of-REPEATS wall seconds + aggregated stats for all queries,
    batch at a time (first repeat warms caches, as serving would).
    ``verify_launches`` and the walk/scan probe-launch counters are
    per-sweep (one pass over all queries); ``launches_per_batch`` is the
    launch-economy number bench_check gates on — fused probing keeps it
    O(1) per knn_batch call no matter how many z-groups a batch mixes."""
    best, totals = float("inf"), {}
    cache_info = {}
    launches0 = _verify_launches(engine)
    walk0, scan0 = _probe_launch_counts()
    for _ in range(REPEATS):
        with _gc_paused():
            t0 = time.perf_counter()
            totals = {"probes": 0, "verified": 0, "fell_back_to_scan": 0}
            for lo in range(0, len(qs), batch):
                _, _, stats = engine.knn_batch(qs[lo : lo + batch], k)
                agg = stats.aggregate()
                for key in totals:
                    totals[key] += agg.get(key, 0)
                cache_info = getattr(stats, "cache_info", {}) or cache_info
            best = min(best, time.perf_counter() - t0)
    launches = _verify_launches(engine) - launches0
    walk1, scan1 = _probe_launch_counts()
    totals["verify_launches"] = launches // REPEATS
    totals["walk_launches"] = (walk1 - walk0) // REPEATS
    totals["scan_launches"] = (scan1 - scan0) // REPEATS
    calls = max(1, -(-len(qs) // batch))   # knn_batch calls per sweep
    totals["launches_per_batch"] = round(
        totals["walk_launches"] / calls, 4
    )
    totals["cache_info"] = cache_info
    return best, totals


def _capture_trace(engine, qs, k, out_path):
    """One traced repetition OUTSIDE the timed reps: the timed sweeps run
    with tracing disabled (a span site costs one attribute check), then
    this single extra call records every span layer and writes a
    Perfetto-loadable Chrome trace — validated by reading it back."""
    from repro.obs import trace as _obs
    from repro.obs.export import load_chrome_trace, write_chrome_trace

    tracer = _obs.Tracer(enabled=True, host="bench")
    prev = _obs.set_tracer(tracer)
    try:
        engine.knn_batch(qs, k)
    finally:
        _obs.set_tracer(prev)
    n_spans = write_chrome_trace(tracer, out_path)
    load_chrome_trace(out_path)   # raises unless Perfetto-loadable
    print(f"wrote {out_path} ({n_spans} spans, traced rep untimed)")


def _time_seed_loop(index, qs, k):
    """The pre-engine shape: one AMIHIndex.knn call per query, with the
    probing sequence re-enumerated every call (clearing the cache matches
    the seed implementation, which had no cross-query reuse)."""
    best = float("inf")
    for _ in range(REPEATS):
        with _gc_paused():
            t0 = time.perf_counter()
            for q in qs:
                probing_cache_clear()
                index.knn(q, k)
            best = min(best, time.perf_counter() - t0)
    return best


def run(max_n: int | None = None, nq: int = 64, batches=(1, 8, 64),
        ps=(64, 128), ks=(1, 10, 100), out_json: str | None = None,
        sizes=None, csv_name: str = "amih_vs_scan.csv",
        shards=(1, 8), probe_backends=("host", "device"),
        trace_out: str | None = None):
    max_n = max_n or int(os.environ.get("REPRO_BENCH_MAX_N", 1_000_000))
    if sizes is None:
        sizes = [n for n in (10_000, 100_000, 1_000_000, 10_000_000)
                 if n <= max_n]
    else:  # explicit sizes (bench_check retries a narrowed workload)
        sizes = [n for n in sizes if n <= max_n]
    rows = []

    def emit(backend, p, n, K, batch, n_shards, t, totals, *,
             m_tables=0, t_seed=None, t_scan=None, t_build=0.0,
             devices=None, probe_backend="host"):
        t_ref = t_scan if t_scan is not None else t
        rows.append({
            "backend": backend, "p": p, "n": n, "K": K,
            "batch": batch, "shards": n_shards, "queries": nq,
            "m_tables": m_tables,
            # which probing walk answered the cell: "host" (reference
            # Python walk) or "device" (fused batch walk, one launch per
            # knn_batch call). bench_check keys cells on it, so the two
            # backends gate against their own baselines.
            "probe_backend": probe_backend,
            # distinct placement devices the shards landed on (sharded
            # backends; 1 on a single-device host). bench_check excludes
            # a cell from the gate when this changed between runs.
            "devices": devices,
            "total_s": round(t, 6),
            "ms_per_query": round(1e3 * t / nq, 4),
            "qps": round(nq / max(t, 1e-9), 2),
            "probes": totals.get("probes", 0),
            "verified": totals.get("verified", 0),
            "verify_launches": totals.get("verify_launches", 0),
            # launch economy (device probe path; 0 on host cells): walk /
            # scan-fallback dispatches per sweep and the per-knn_batch
            # walk-launch rate bench_check gates on — O(1) per batch with
            # fused probing, O(z-groups) without
            "walk_launches": totals.get("walk_launches", 0),
            "scan_launches": totals.get("scan_launches", 0),
            "launches_per_batch": totals.get("launches_per_batch", 0),
            # shared-cache effectiveness after the sweep (S1): probing
            # sequence + device schedule hit/miss lifetime counters
            "probing_hits": totals.get("cache_info", {}).get(
                "probing_hits", 0),
            "probing_misses": totals.get("cache_info", {}).get(
                "probing_misses", 0),
            "schedule_hits": totals.get("cache_info", {}).get(
                "schedule_hits", 0),
            "schedule_misses": totals.get("cache_info", {}).get(
                "schedule_misses", 0),
            "fell_back_to_scan": totals.get("fell_back_to_scan", 0),
            "seed_loop_ms_per_query":
                "" if t_seed is None else round(1e3 * t_seed / nq, 4),
            "speedup_vs_seed_loop":
                "" if t_seed is None
                else round(t_seed / max(t, 1e-9), 3),
            "scan_ms_per_query": round(1e3 * t_ref / nq, 4),
            "speedup_vs_scan": round(t_ref / max(t, 1e-9), 2),
            "index_build_s": round(t_build, 3),
        })
        return rows[-1]

    for p in ps:
        for n in sizes:
            db_bits, db = make_db(n, p, seed=0)
            _, qs = make_queries(db_bits, nq, seed=1)
            # query_cache_size=0: the bench measures probing, and its
            # repeated sweeps over one query set would otherwise time the
            # hot-query LRU instead of the algorithm.
            engines, builds = {}, {}
            for pb in probe_backends:
                t_build0 = time.perf_counter()
                engines[pb] = make_engine(
                    "amih", db, p, query_cache_size=0, probe_backend=pb
                )
                builds[pb] = time.perf_counter() - t_build0
            scan = make_engine("linear_scan", db, p)
            ref = engines.get("host", engines[probe_backends[0]])
            if trace_out is not None:
                # once, on the first (smallest) cell — the trace shows
                # the span taxonomy, not the perf numbers
                _capture_trace(ref, qs[: min(len(qs), 8)], ks[0],
                               trace_out)
                trace_out = None
            for K in ks:
                t_seed = _time_seed_loop(ref.index, qs, K)
                t_scan, _ = _time_batched(scan, qs, K, max(batches))
                for pb in probe_backends:
                    for batch in batches:
                        t_amih, totals = _time_batched(
                            engines[pb], qs, K, batch
                        )
                        r = emit("amih", p, n, K, batch, 1, t_amih,
                                 totals, m_tables=ref.index.m,
                                 t_seed=t_seed, t_scan=t_scan,
                                 t_build=builds[pb], probe_backend=pb)
                        print(
                            f"p={p} n={n:>9} K={K:>3} B={batch:>3} "
                            f"amih[{pb}]={r['ms_per_query']:.3f}ms/q "
                            f"seed_loop={r['seed_loop_ms_per_query']:.3f}"
                            f"ms/q scan={r['scan_ms_per_query']:.3f}ms/q "
                            f"({r['speedup_vs_scan']}x)"
                        )
                emit("linear_scan", p, n, K, max(batches), 1, t_scan,
                     {"verified": n * nq}, t_scan=t_scan)
            # sharded cells: the pod-scale backends over S host shards
            # (S=1 is the degenerate single-shard layout; the multi-device
            # mesh path is exercised by tests/test_shard.py)
            for S in shards:
                if S > n:
                    continue
                sh_scan = make_engine("sharded_scan", db, p, num_shards=S)
                sh_amihs = {
                    pb: make_engine("sharded_amih", db, p, num_shards=S,
                                    probe_backend=pb)
                    for pb in probe_backends
                }
                any_sh = next(iter(sh_amihs.values()))
                n_dev = len({str(d) for d in any_sh.plan.devices}) or 1
                for K in ks:
                    t_s, tot_s = _time_batched(sh_scan, qs, K, max(batches))
                    emit("sharded_scan", p, n, K, max(batches), S, t_s,
                         tot_s, devices=n_dev)
                    for pb in probe_backends:
                        t_a, tot_a = _time_batched(
                            sh_amihs[pb], qs, K, max(batches)
                        )
                        r = emit("sharded_amih", p, n, K, max(batches), S,
                                 t_a, tot_a, devices=n_dev,
                                 probe_backend=pb)
                        print(
                            f"p={p} n={n:>9} K={K:>3} S={S:>2} "
                            f"sharded_amih[{pb}]="
                            f"{r['ms_per_query']:.3f}ms/q "
                            f"sharded_scan={1e3 * t_s / nq:.3f}ms/q"
                        )
    path = write_csv(csv_name, rows)
    payload = {
        "bench": "engine",
        "workload": {
            "sizes": sizes, "ps": list(ps), "ks": list(ks),
            "batches": list(batches), "queries": nq,
            "shards": list(shards),
            "probe_backends": list(probe_backends),
            "codes": "synthetic clustered (AQBC-like)",
        },
        "rows": rows,
    }
    out_json = out_json or BENCH_JSON
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")
    print(f"wrote {out_json}")
    return rows


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    def positive_int(v):
        iv = int(v)
        if iv < 1:
            raise argparse.ArgumentTypeError(f"must be >= 1, got {v}")
        return iv

    ap.add_argument("--batch", type=positive_int, nargs="+",
                    default=[1, 8, 64],
                    help="batch sizes for knn_batch (axis of the sweep)")
    ap.add_argument("--shards", type=positive_int, nargs="+",
                    default=[1, 8],
                    help="shard counts for the sharded_scan/sharded_amih "
                         "cells (host-mode ShardPlan shards)")
    ap.add_argument("--probe-backend", type=str, nargs="+",
                    default=["host", "device"],
                    choices=["host", "device"],
                    help="probing walks to time for the amih cells "
                         "(axis of the sweep)")
    ap.add_argument("--max-n", type=int, default=None,
                    help="largest DB size (default REPRO_BENCH_MAX_N or 1e6)")
    ap.add_argument("--nq", type=int, default=64, help="queries per cell")
    ap.add_argument("--p", type=int, nargs="+", default=[64, 128])
    ap.add_argument("--k", type=int, nargs="+", default=[1, 10, 100])
    ap.add_argument("--out", type=str, default=None,
                    help="write the JSON payload here instead of "
                         "BENCH_engine.json (used by scripts/bench_check)")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT.json",
                    help="capture ONE traced repetition (outside the "
                         "timed reps) as a Chrome trace at this path")
    return ap.parse_args(argv)


if __name__ == "__main__":
    a = _parse_args()
    run(max_n=a.max_n, nq=a.nq, batches=tuple(sorted(set(a.batch))),
        ps=tuple(a.p), ks=tuple(a.k), out_json=a.out,
        shards=tuple(sorted(set(a.shards))),
        probe_backends=tuple(dict.fromkeys(a.probe_backend)),
        trace_out=a.trace)
