"""Paper Fig. 6: percentage of queries whose search radius exceeds r-hat
(the Prop-2 closed-form region) — falls with n, grows with code length."""

from __future__ import annotations

import os

import numpy as np

from repro.core import AMIHIndex, AMIHStats

from .common import make_db, make_queries, write_csv


def run():
    max_n = int(os.environ.get("REPRO_BENCH_MAX_N", 1_000_000))
    rows = []
    for p in (32, 64, 128):
        for n in (10_000, 100_000, 1_000_000):
            if n > max_n:
                continue
            db_bits, db = make_db(n, p, seed=0)
            _, qs = make_queries(db_bits, 30, seed=1)
            idx = AMIHIndex.build(db, p)
            exceeded = 0
            radii = []
            for q in qs:
                st = AMIHStats()
                idx.knn(q, 10, stats=st)
                exceeded += int(st.exceeded_rhat)
                radii.append(st.max_radius)
            rows.append({
                "p": p, "n": n, "K": 10,
                "pct_exceeded_rhat": round(100.0 * exceeded / len(qs), 1),
                "avg_max_radius": round(float(np.mean(radii)), 2),
            })
            print(f"p={p} n={n:>8}: {rows[-1]['pct_exceeded_rhat']}% "
                  f"exceeded r-hat (avg radius {rows[-1]['avg_max_radius']})")
    path = write_csv("rhat_exceedance.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
