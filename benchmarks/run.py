"""Benchmark driver: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # quick tier
    REPRO_BENCH_MAX_N=10000000 python -m benchmarks.run  # big sweep

Artifacts land in artifacts/bench/*.csv; the mapping to paper figures is
documented in DESIGN.md §8 and EXPERIMENTS.md.
"""

from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    t0 = time.time()
    # keep the default tier CI-sized; export REPRO_BENCH_MAX_N to go big
    os.environ.setdefault("REPRO_BENCH_MAX_N", "200000")
    os.environ.setdefault("REPRO_BENCH_RECALL_N", "20000")
    from . import (
        bench_amih_vs_scan,
        bench_cost_model,
        bench_indexing,
        bench_kernels,
        bench_probings,
        bench_recall,
        bench_rhat,
        bench_roofline,
    )

    suites = [
        ("Fig3_probings_single_table", bench_probings.run),
        ("Fig5_Table2_amih_vs_scan", bench_amih_vs_scan.run),
        ("Fig6_rhat_exceedance", bench_rhat.run),
        ("Fig7_indexing_time", bench_indexing.run),
        ("Fig8_9_recall_vs_baselines", bench_recall.run),
        ("Eq14_cost_model", bench_cost_model.run),
        ("kernel_scan_throughput", bench_kernels.run),
        ("roofline_table", bench_roofline.run),
    ]
    failed = []
    for name, fn in suites:
        print(f"\n=== {name} {'=' * max(1, 60 - len(name))}")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s; "
          f"{len(suites) - len(failed)}/{len(suites)} suites passed")
    if failed:
        print("FAILED:", ", ".join(failed))
        sys.exit(1)


if __name__ == "__main__":
    main()
