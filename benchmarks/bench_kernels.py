"""Device-side scan kernel throughput (the linear-scan baseline / reranker
path) + roofline accounting for the Pallas hamming_scan kernel.

On CPU this measures the XLA reference path (interpret-mode Pallas is a
correctness tool, not a perf path); the roofline numbers are the TPU
projection: the kernel is HBM-bound at 16 B/code for p=128."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import make_db, make_queries, write_csv


def run():
    rows = []
    for p in (64, 128):
        n, B, k = 1_000_000, 8, 100
        db_bits, db = make_db(n, p, seed=0)
        _, qw = make_queries(db_bits, B, seed=1)
        dbj, qj = jnp.asarray(db), jnp.asarray(qw)
        fn = lambda: jax.block_until_ready(ops.scan_topk(qj, dbj, k))
        fn()  # compile
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            fn()
        dt = (time.perf_counter() - t0) / reps
        bytes_scanned = db.nbytes
        rows.append({
            "p": p, "n": n, "B": B, "k": k, "kind": "scan_topk",
            "cpu_ms": round(1e3 * dt, 1),
            "cpu_GBps": round(bytes_scanned / dt / 1e9, 2),
            "scanned_frac": 1.0,
            # TPU projection: one pass over the packed codes at HBM speed
            "tpu_roofline_ms": round(1e3 * bytes_scanned / 819e9, 3),
        })
        print(f"p={p}: scan_topk {rows[-1]['cpu_ms']}ms on CPU "
              f"({rows[-1]['cpu_GBps']} GB/s); TPU HBM roofline "
              f"{rows[-1]['tpu_roofline_ms']}ms")
        # block-max pruned exact scan (§Perf R2) at the 1NN serving point
        qj1 = qj[:1]
        fnp = lambda: jax.block_until_ready(
            ops.scan_topk_pruned(qj1, dbj, 1, blk=2048)
        )
        fnp()
        t0 = time.perf_counter()
        for _ in range(reps):
            _, _, frac = fnp()
        dtp = (time.perf_counter() - t0) / reps
        rows.append({
            "p": p, "n": n, "B": 1, "k": 1, "kind": "scan_topk_pruned",
            "cpu_ms": round(1e3 * dtp, 1),
            "cpu_GBps": round(bytes_scanned / dtp / 1e9, 2),
            "scanned_frac": round(float(frac), 4),
            "tpu_roofline_ms": round(
                1e3 * bytes_scanned * (1 + float(frac)) / 819e9, 3
            ),
        })
        print(f"p={p}: pruned 1NN scanned {float(frac):.2%} of blocks "
              f"({rows[-1]['cpu_ms']}ms CPU)")
    path = write_csv("kernel_scan_throughput.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
