"""Paper Figs. 8-9: recall/time/memory versus approximate baselines.

Scenario 1 (Fig. 8, binary space): AMIH (exact, recall 1.0) vs SP-CP /
MP-CP cross-polytope LSH applied to the binary codes.
Scenario 2 (Fig. 9, real space): approximate methods on the raw vectors vs
AMIH on AQBC-binarized codes (recall measured against the real-space truth).

KGraph/Annoy are third-party C++ systems — out of scope (recorded); the
LSH baselines are implemented in repro.core.lsh.
"""

from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core import aqbc, make_engine, pack_bits
from repro.core.lsh import CrossPolytopeLSH
from repro.data import clustered_features

from .common import timer, write_csv


def _index_memory_bytes(idx) -> int:
    b = idx.db_words.nbytes
    for t in idx.tables:
        b += t.sorted_vals.nbytes + t.sorted_ids.nbytes
    return b


def run():
    n = int(os.environ.get("REPRO_BENCH_RECALL_N", 50_000))
    dim, nq = 128, 50
    x = clustered_features(n + nq, dim=dim, n_clusters=128, seed=0)
    base, queries = x[:n], x[n:]
    xn = base / np.linalg.norm(base, axis=1, keepdims=True)
    rows = []

    for p in (64, 128):
        model = aqbc.learn(base[:20_000], code_bits=p, iters=10)
        db_bits = np.asarray(aqbc.encode(jnp.asarray(base), model.rotation))
        q_bits = np.asarray(aqbc.encode(jnp.asarray(queries), model.rotation))
        db_words, q_words = pack_bits(db_bits), pack_bits(q_bits)
        engine = make_engine("amih", db_words, p)

        # real-space ground truth (scenario 2)
        def truth_real(q):
            qn = q / np.linalg.norm(q)
            return int(np.argmax(xn @ qn))

        # binary-space ground truth (scenario 1) = linear scan over codes
        # --- AMIH (unified engine): exact in binary space; sweep K for
        # real-space recall, with a batch-size axis (the serving shape)
        for K in (1, 10, 100):
            for batch in (1, nq):
                t0 = time.perf_counter()
                all_ids = np.concatenate([
                    engine.knn_batch(q_words[lo : lo + batch], K)[0]
                    for lo in range(0, nq, batch)
                ])
                t_batch = time.perf_counter() - t0
                hit_real = 0
                for qi in range(nq):
                    ids = all_ids[qi]
                    qn = queries[qi] / np.linalg.norm(queries[qi])
                    best = ids[np.argmax(xn[ids] @ qn)] if len(ids) else -1
                    hit_real += int(best == truth_real(queries[qi]))
                rows.append({
                    "method": f"AMIH-{p}", "p": p, "param": K,
                    "batch": batch,
                    "recall_binary": 1.0,
                    "recall_real": round(hit_real / nq, 3),
                    "query_ms": round(1e3 * t_batch / nq, 3),
                    "index_MB": round(
                        _index_memory_bytes(engine.index) / 2**20, 1
                    ),
                })
                print(f"AMIH p={p} K={K} B={batch}: real-recall "
                      f"{rows[-1]['recall_real']} {rows[-1]['query_ms']}ms")

        # --- LSH on the real vectors (scenario 2 comparator)
        lsh = CrossPolytopeLSH.build(base, l=10, k=1, proj_dim=32, seed=0)
        for probes in (1, 4, 16):
            t, hit = [], 0
            for qi in range(nq):
                t0 = time.perf_counter()
                got = lsh.query(queries[qi], k_neighbors=1,
                                probes_per_table=probes)
                t.append(time.perf_counter() - t0)
                hit += int(len(got) and got[0] == truth_real(queries[qi]))
            mem = sum(v.nbytes for tab in lsh.tables for v in tab.values())
            rows.append({
                "method": "MP-CP" if probes > 1 else "SP-CP",
                "p": dim, "param": probes, "batch": 1,
                "recall_binary": "",
                "recall_real": round(hit / nq, 3),
                "query_ms": round(1e3 * float(np.median(t)), 3),
                "index_MB": round(mem / 2**20, 1),
            })
            print(f"CP-LSH probes={probes}: recall "
                  f"{rows[-1]['recall_real']} {rows[-1]['query_ms']}ms")
    path = write_csv("recall_vs_baselines.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
