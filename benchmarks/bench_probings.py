"""Paper Fig. 3: average #probings to solve angular KNN with a SINGLE hash
table — demonstrating why the single-table approach collapses for long
codes (probings exceed n), which motivates AMIH (§5)."""

from __future__ import annotations

import numpy as np

from repro.core import SearchStats, SingleTableIndex
from repro.core.probing import probing_sequence
from repro.core.tuples import tuple_count

from .common import make_db, make_queries, write_csv


def expected_probings_analytic(p: int, z: int, frac_needed: float) -> float:
    """Buckets that must be probed until ``frac_needed`` of the hypercube
    mass is covered (uniform-codes model) — the Fig. 3 growth curve."""
    covered = 0.0
    probes = 0.0
    total = 2.0 ** p
    for (a, b) in probing_sequence(p, z):
        cnt = tuple_count(p, z, a, b)
        probes += cnt
        covered += cnt
        if covered / total >= frac_needed:
            break
    return probes


def run():
    rows = []
    # measured: short codes where a single table is viable
    for p in (16, 20, 24):
        n = 100_000
        db_bits, db = make_db(n, p, seed=0, mode="uniform")
        _, qs = make_queries(db_bits, 15, seed=1)
        idx = SingleTableIndex.build(db, p)
        for K in (1, 10, 100):
            probes = []
            for q in qs:
                st = SearchStats()
                idx.knn(q, K, stats=st)
                probes.append(st.probes)
            rows.append({
                "p": p, "n": n, "K": K,
                "avg_probes": round(float(np.mean(probes)), 1),
                "probes_over_n": round(float(np.mean(probes)) / n, 4),
                "kind": "measured",
            })
            print(f"p={p} K={K}: avg probes {rows[-1]['avg_probes']} "
                  f"({rows[-1]['probes_over_n']} of n)")
    # analytic: the paper's point — for 64/128-bit codes the probing count
    # explodes past any realistic n (Fig. 3's near-exponential growth)
    for p in (32, 64, 128):
        z = p // 2
        for n in (10**6, 10**9):
            need = 100 / n  # fraction of hypercube holding K=100 items
            probes = expected_probings_analytic(p, z, need)
            rows.append({
                "p": p, "n": n, "K": 100,
                "avg_probes": f"{probes:.3e}",
                "probes_over_n": f"{probes / n:.3e}",
                "kind": "analytic",
            })
            print(f"p={p} n={n:.0e}: analytic probes {probes:.3e} "
                  f"({probes/n:.2e} of n)")
    path = write_csv("probings_single_table.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
