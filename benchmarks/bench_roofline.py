"""§Roofline deliverable: aggregate the dry-run artifacts into the
per-(arch x shape x mesh) roofline table with dominant-term analysis.

Reads artifacts/dryrun/*.json produced by ``python -m repro.launch.dryrun
--all --mesh both``. Does NOT lower anything itself (that is the dry-run's
job) — run the dry-run first."""

from __future__ import annotations

import json
import os

from .common import write_csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")

MITIGATION = {
    "compute": "raise arithmetic intensity: larger per-chip batch or fewer"
               " remat recomputes",
    "memory": "cut HBM traffic: fuse attention/softmax chains (Pallas),"
              " bf16 params/activations, int8 optimizer moments",
    "collective": "reshard to keep tokens local: EP all-to-all instead of"
                  " capacity scatter, overlap collectives with compute",
}


def run():
    if not os.path.isdir(DRYRUN_DIR):
        print(f"no dry-run artifacts at {DRYRUN_DIR}; run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return []
    rows = []
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, name)) as f:
            r = json.load(f)
        if r.get("status") == "skip":
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": "skip", "note": r["reason"][:80],
            })
            continue
        if r.get("status") != "ok":
            continue
        step = max(r.get("compute_s", 0), r.get("memory_s", 0),
                   r.get("collective_s", 0))
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok",
            "compute_s": round(r.get("compute_s", 0), 4),
            "memory_s": round(r.get("memory_s", 0), 4),
            "collective_s": round(r.get("collective_s", 0), 4),
            "dominant": r.get("dominant", ""),
            "step_s": round(step, 4),
            "model_flops": f"{r.get('model_flops', 0):.3e}",
            "hlo_flops": f"{r.get('hlo_total_flops', 0):.3e}",
            "useful_ratio": round(r.get("useful_ratio", 0), 4),
            "GiB_per_dev": round(r.get("bytes_per_device", 0) / 2**30, 2),
            "note": MITIGATION.get(r.get("dominant", ""), "")[:60],
        })
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"{len(ok)} compiled cells, "
          f"{sum(1 for r in rows if r['status'] == 'skip')} recorded skips")
    for r in ok:
        print(f"{r['mesh']:>6} {r['arch']:<18} {r['shape']:<12} "
              f"dom={r['dominant']:<10} step={r['step_s']:>9.3f}s "
              f"useful={r['useful_ratio']:.3f}")
    path = write_csv("roofline_table.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
