"""Assemble the EXPERIMENTS.md data tables from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.report_experiments > /tmp/tables.md

Reads artifacts/dryrun (baseline) and artifacts/dryrun_optimized; emits
markdown tables for §Dry-run and §Roofline.
"""

from __future__ import annotations

import json
import os
import sys


def load(d):
    out = {}
    if not os.path.isdir(d):
        return out
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                r = json.load(f)
            out[(r.get("mesh"), r.get("arch"), r.get("shape"))] = r
    return out


def step_s(r):
    return max(r.get("compute_s", 0), r.get("memory_s", 0),
               r.get("collective_s", 0))


def fused_step_s(r):
    """Recompute the fused-attention memory substitution from the stored
    scope breakdown (prefill only — the kernel is forward-only)."""
    m = r.get("memory_s", 0)
    scopes = r.get("hbm_bytes_by_scope") or {}
    if r.get("shape", "").startswith("prefill") and "flash_attn" in scopes:
        from repro.configs import get_config
        from repro.configs.profiles import optimized_overrides
        from repro.models.common import SHAPES
        from repro.roofline.model_flops import flash_io_bytes_per_device

        arch_id = r["arch"].replace("-", "_").replace(".", "_")
        try:
            cfg = get_config(arch_id)
            cfg = cfg.replace(**optimized_overrides(arch_id))
            io = flash_io_bytes_per_device(cfg, SHAPES[r["shape"]])
            if io > 0:
                m = m - scopes["flash_attn"] / 819e9 + io / 819e9
        except KeyError:
            pass
    return max(r.get("compute_s", 0), m, r.get("collective_s", 0))


def main():
    base = load("artifacts/dryrun")
    opt = load("artifacts/dryrun_optimized")

    print("### Roofline table — single-pod 16x16 (256 chips), per step\n")
    print("| arch | shape | dom | compute_s | memory_s | coll_s | "
          "step_s | opt step_s | gain | useful | GiB/dev | opt GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        mesh, arch, shape = key
        if mesh != "single":
            continue
        r = base[key]
        if r.get("status") == "skip":
            print(f"| {arch} | {shape} | SKIP ({r['reason'][:48]}...) "
                  f"| | | | | | | | |")
            continue
        if r.get("status") != "ok":
            continue
        o = opt.get(key, {})
        s_b = step_s(r)
        s_o = fused_step_s(o) if o.get("status") == "ok" else float("nan")
        gain = s_b / s_o if s_o and s_o == s_o else float("nan")
        print(
            f"| {arch} | {shape} | {r['dominant'][:4]} "
            f"| {r.get('compute_s', 0):.3f} | {r.get('memory_s', 0):.3f} "
            f"| {r.get('collective_s', 0):.3f} | {s_b:.3f} "
            f"| {s_o:.3f} | {gain:.1f}x "
            f"| {r.get('useful_ratio', 0):.3f} "
            f"| {r.get('bytes_per_device', 0)/2**30:.1f} "
            f"| {o.get('bytes_per_device', 0)/2**30:.1f} |"
        )

    print("\n### Multi-pod 2x16x16 (512 chips) — shardability proof\n")
    print("| arch | shape | status | dom | step_s | opt step_s |")
    print("|---|---|---|---|---|---|")
    for key in sorted(base):
        mesh, arch, shape = key
        if mesh != "multi":
            continue
        r = base[key]
        if r.get("status") == "skip":
            print(f"| {arch} | {shape} | SKIP | | | |")
            continue
        o = opt.get(key, {})
        s_o = fused_step_s(o) if o.get("status") == "ok" else float("nan")
        print(f"| {arch} | {shape} | ok | {r['dominant'][:4]} "
              f"| {step_s(r):.3f} | {s_o:.3f} |")

    n_ok_b = sum(1 for r in base.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in base.values() if r.get("status") == "skip")
    n_ok_o = sum(1 for r in opt.values() if r.get("status") == "ok")
    print(f"\nbaseline: {n_ok_b} compiled cells + {n_skip} recorded skips; "
          f"optimized: {n_ok_o} compiled cells", file=sys.stderr)


if __name__ == "__main__":
    main()
