"""Serving benchmark: pipelined vs sequential engines under the
streaming loop (throughput + p50/p99 step latency).

Every cell drives ``nq`` queries through ``repro.pipeline.stream_search``
— the actual serving loop (two-stage encode -> search pipeline, results
yielded per batch step) — ``batch`` queries per step, and times the whole
drain. Cells come in pairs:

  - shards=1: engine "amih", sequential vs pipelined
    (``overlap_verify=True``: tuple-step verify/probe overlap).
  - shards=S: engine "sharded_amih", sequential (PR 3's chained bound)
    vs pipelined (``probe_workers=S``: shard-parallel probing under the
    shared warm-started k-th-cosine bound, served by the PERSISTENT
    worker pool — forked once per engine, reused by every drain/repeat
    of the cell; ``pool``/``pool_forks`` on each row record that, and
    ``devices`` records how many distinct placement devices the shards
    landed on). The pool's adaptive stand-down gates apply
    (ShardedAMIHEngine.PARALLEL_MIN_*): on hosts without real cores,
    narrow batches, or tiny shards the pipelined engine runs the
    sequential chain — ``parallel_active`` on each row records whether
    the pool actually engaged, so a ~1.0x speedup with
    ``parallel_active: false`` reads as "host can't pay for the pool",
    not as a pipelining regression.

With ``--hosts H`` (H > 1) a third backend joins the sweep: the
cross-host ``cluster`` tier (repro.cluster) — a coordinator plus H
spawned localhost workers, each serving its host-partitioned slice of
the same S shards, merged over the TCP frame protocol with the bound
broadcast live. Cluster cells require S >= H (one shard per host at
minimum) and report ``mode="sequential"`` (the fan-out across hosts IS
the parallelism; there is no separate pipelined variant).
``speedup_vs_sequential`` on a cluster row is measured against the
single-host sequential sharded_amih cell at the same (probe_backend,
batch) — the "what did crossing host boundaries cost/buy" number. Every
row carries a ``hosts`` key (1 on single-host rows) so
``scripts/bench_check.py`` keys the cells apart; baselines written
before the axis existed default to hosts=1 and keep parsing.

Reported per cell: ms_per_query + qps over the best-of-REPEATS drain,
and p50/p99 over that drain's per-step latencies (enqueue -> step
completion, the number a serving SLO would track). ``speedup_vs_sequential``
on pipelined rows is the throughput ratio against the matching
sequential cell.

Results land in ``BENCH_engine.json`` under a top-level ``"serving"``
section (the engine rows stay untouched, old baselines without the
section still parse) plus artifacts/bench/serving.csv;
``scripts/bench_check.py`` gates the cells when the baseline has them.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
if __package__ in (None, ""):  # run as a script: fix up both import roots
    sys.path.insert(0, _HERE)
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    from common import make_db, make_queries, write_csv
else:
    from .common import make_db, make_queries, write_csv

from repro.core import make_engine
from repro.pipeline import stream_search

BENCH_JSON = os.path.join(_ROOT, "BENCH_engine.json")

REPEATS = 3  # best-of; host timing at sub-ms/step is noisy


def _engine_for(mode: str, db, p: int, S: int, probe_backend: str):
    """The cell's engine: amih at S=1, sharded_amih otherwise; the
    pipelined variant turns on the matching repro.pipeline path. On the
    device probing walk both pipeline knobs are stand-downs (no host
    loop to overlap or fork for), so pipelined device cells measure the
    gates doing their job."""
    if S == 1:
        return make_engine(
            "amih", db, p, query_cache_size=0,
            overlap_verify=(mode == "pipelined"),
            probe_backend=probe_backend,
        )
    return make_engine(
        "sharded_amih", db, p, num_shards=S,
        probe_workers=(S if mode == "pipelined" else None),
        probe_backend=probe_backend,
    )


def _drain(engine, qs, k: int, batch: int):
    """One full streaming drain; returns (wall seconds, step latencies)."""
    steps = [qs[lo : lo + batch] for lo in range(0, len(qs), batch)]
    lats = []
    t0 = time.perf_counter()
    for sr in stream_search(engine, steps, k):
        lats.append(sr.latency_ms)
    return time.perf_counter() - t0, lats


def _capture_trace(engine, qs, k: int, batch: int, out_path: str):
    """One traced streaming drain OUTSIDE the timed reps: the timed
    drains run with tracing disabled (a span site costs one attribute
    check), then this extra drain records the serving-stage, engine,
    AMIH and kernel-launch spans and writes a Perfetto-loadable Chrome
    trace — validated by reading it back."""
    from repro.obs import trace as _obs
    from repro.obs.export import load_chrome_trace, write_chrome_trace

    tracer = _obs.Tracer(enabled=True, host="bench")
    prev = _obs.set_tracer(tracer)
    try:
        _drain(engine, qs, k, batch)
    finally:
        _obs.set_tracer(prev)
    n_spans = write_chrome_trace(tracer, out_path)
    load_chrome_trace(out_path)   # raises unless Perfetto-loadable
    print(f"wrote {out_path} ({n_spans} spans, traced drain untimed)")


def run(max_n: int | None = None, nq: int = 64, ps=(64,), k: int = 10,
        batches=(1, 32), shards=(1, 8), out_json: str | None = None,
        sizes=None, csv_name: str = "serving.csv",
        probe_backends=("host", "device"), hosts=(1,),
        trace_out: str | None = None):
    max_n = max_n or int(os.environ.get("REPRO_BENCH_MAX_N", 100_000))
    if sizes is None:
        sizes = [n for n in (10_000, 100_000, 1_000_000) if n <= max_n]
    else:
        sizes = [n for n in sizes if n <= max_n]
    rows = []
    for p in ps:
        for n in sizes:
            db_bits, db = make_db(n, p, seed=0)
            _, qs = make_queries(db_bits, nq, seed=1)
            for S in shards:
                if S > n:
                    continue
                seq_ms = {}
                cells = [(pb, mode) for pb in probe_backends
                         for mode in ("sequential", "pipelined")]
                for pb, mode in cells:
                    engine = _engine_for(mode, db, p, S, pb)
                    if trace_out is not None:
                        # once, on the sweep's first cell: the trace
                        # shows the span taxonomy, not the perf numbers
                        _capture_trace(engine, qs, k, max(batches),
                                       trace_out)
                        trace_out = None
                    plan = getattr(engine, "plan", None)
                    n_dev = (
                        len({str(d) for d in plan.devices})
                        if plan is not None and plan.devices else 1
                    )
                    for batch in batches:
                        best_t, best_lats = float("inf"), []
                        for _ in range(REPEATS):
                            t, lats = _drain(engine, qs, k, batch)
                            if t < best_t:
                                best_t, best_lats = t, lats
                        ms_q = 1e3 * best_t / nq
                        # the device walk stands every pipeline knob
                        # down: nothing host-side left to overlap/fork
                        active = bool(
                            mode == "pipelined" and pb == "host" and (
                                S == 1 or engine._use_parallel(batch)
                            )
                        )
                        # persistent-pool accounting: the drain above
                        # reused one fork-once worker pool across every
                        # repeat (when the stand-down gates let it engage)
                        pool = getattr(engine, "_pool", None)
                        row = {
                            "backend": "amih" if S == 1 else "sharded_amih",
                            "mode": mode, "p": p, "n": n, "K": k,
                            "batch": batch, "shards": S, "queries": nq,
                            "probe_backend": pb, "hosts": 1,
                            "parallel_active": active,
                            "devices": n_dev,
                            "pool": (
                                "persistent" if pool is not None else ""
                            ),
                            "pool_forks": (
                                pool.forks if pool is not None else 0
                            ),
                            "total_s": round(best_t, 6),
                            "ms_per_query": round(ms_q, 4),
                            "qps": round(nq / max(best_t, 1e-9), 2),
                            "p50_ms": round(
                                float(np.percentile(best_lats, 50)), 4),
                            "p99_ms": round(
                                float(np.percentile(best_lats, 99)), 4),
                            "speedup_vs_sequential": "",
                        }
                        if mode == "sequential":
                            seq_ms[pb, batch] = ms_q
                        else:
                            row["speedup_vs_sequential"] = round(
                                seq_ms[pb, batch] / max(ms_q, 1e-9), 3
                            )
                        rows.append(row)
                        extra = (
                            f" ({row['speedup_vs_sequential']}x vs seq)"
                            if mode == "pipelined" else ""
                        )
                        print(
                            f"p={p} n={n:>9} S={S:>2} B={batch:>3} "
                            f"{row['backend']:>13}[{pb}]/{mode:<10} "
                            f"{ms_q:7.3f} ms/q  p50={row['p50_ms']:.2f} "
                            f"p99={row['p99_ms']:.2f}{extra}"
                        )
                    if hasattr(engine, "close"):
                        engine.close()   # release the persistent pool
                # cross-host cells: same S shards, partitioned over H
                # spawned localhost workers behind the frame protocol.
                # S >= H (host_partition needs a shard per host); the
                # single-host sequential cell above is the speedup
                # reference.
                for H in hosts:
                    if H <= 1 or S < H or S > n:
                        continue
                    for pb in probe_backends:
                        engine = make_engine(
                            "cluster", db, p, hosts=H, num_shards=S,
                            probe_backend=pb,
                        )
                        try:
                            for batch in batches:
                                best_t, best_lats = float("inf"), []
                                for _ in range(REPEATS):
                                    t, lats = _drain(engine, qs, k, batch)
                                    if t < best_t:
                                        best_t, best_lats = t, lats
                                ms_q = 1e3 * best_t / nq
                                row = {
                                    "backend": "cluster",
                                    "mode": "sequential", "p": p,
                                    "n": n, "K": k, "batch": batch,
                                    "shards": S, "queries": nq,
                                    "probe_backend": pb, "hosts": H,
                                    "parallel_active": False,
                                    "pool": "", "pool_forks": 0,
                                    "total_s": round(best_t, 6),
                                    "ms_per_query": round(ms_q, 4),
                                    "qps": round(
                                        nq / max(best_t, 1e-9), 2),
                                    "p50_ms": round(float(
                                        np.percentile(best_lats, 50)),
                                        4),
                                    "p99_ms": round(float(
                                        np.percentile(best_lats, 99)),
                                        4),
                                    "speedup_vs_sequential": round(
                                        seq_ms[pb, batch]
                                        / max(ms_q, 1e-9), 3
                                    ) if (pb, batch) in seq_ms else "",
                                }
                                rows.append(row)
                                extra = (
                                    f" ({row['speedup_vs_sequential']}"
                                    f"x vs 1-host seq)"
                                    if row["speedup_vs_sequential"]
                                    else ""
                                )
                                print(
                                    f"p={p} n={n:>9} S={S:>2} "
                                    f"B={batch:>3} "
                                    f"{'cluster':>13}[{pb}]/H={H:<7} "
                                    f"{ms_q:7.3f} ms/q  "
                                    f"p50={row['p50_ms']:.2f} "
                                    f"p99={row['p99_ms']:.2f}{extra}"
                                )
                        finally:
                            engine.close()
    path = write_csv(csv_name, rows)
    section = {
        "workload": {
            "sizes": sizes, "ps": list(ps), "k": k,
            "batches": list(batches), "shards": list(shards),
            "probe_backends": list(probe_backends),
            "hosts": list(hosts),
            "queries": nq,
            "codes": "synthetic clustered (AQBC-like)",
        },
        "rows": rows,
    }
    if out_json is None:
        # merge into the committed trajectory next to the engine rows
        payload = {"bench": "engine"}
        if os.path.exists(BENCH_JSON):
            with open(BENCH_JSON) as f:
                payload = json.load(f)
        payload["serving"] = section
        target = BENCH_JSON
    else:
        payload = {"bench": "serving", **section}
        target = out_json
    with open(target, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")
    print(f"wrote {target}")
    return rows


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--batch", type=int, nargs="+", default=[1, 32],
                    help="queries per serving step (axis of the sweep)")
    ap.add_argument("--shards", type=int, nargs="+", default=[1, 8],
                    help="shard counts (1 -> amih, >1 -> sharded_amih)")
    ap.add_argument("--max-n", type=int, default=None,
                    help="largest DB size (default REPRO_BENCH_MAX_N or 1e5)")
    ap.add_argument("--nq", type=int, default=64, help="queries per cell")
    ap.add_argument("--p", type=int, nargs="+", default=[64])
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--probe-backend", type=str, nargs="+",
                    default=["host", "device"],
                    choices=["host", "device"],
                    help="probing walks to time (axis of the sweep)")
    ap.add_argument("--hosts", type=int, nargs="+", default=[1],
                    help="cross-host cluster sizes to add to the sweep "
                         "(values > 1 spawn localhost worker fleets; "
                         "1 = single-host cells only)")
    ap.add_argument("--out", type=str, default=None,
                    help="write a standalone JSON payload here instead of "
                         "merging into BENCH_engine.json (bench_check)")
    ap.add_argument("--trace", type=str, default=None, metavar="OUT.json",
                    help="capture ONE traced streaming drain (outside "
                         "the timed reps) as a Chrome trace at this path")
    return ap.parse_args(argv)


if __name__ == "__main__":
    a = _parse_args()
    run(max_n=a.max_n, nq=a.nq, ps=tuple(a.p), k=a.k,
        batches=tuple(sorted(set(a.batch))),
        shards=tuple(sorted(set(a.shards))), out_json=a.out,
        probe_backends=tuple(dict.fromkeys(a.probe_backend)),
        hosts=tuple(sorted(set(a.hosts))), trace_out=a.trace)
