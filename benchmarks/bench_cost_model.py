"""Paper Eq. 14: measured candidate counts vs the n^{H((r1+r2)/p)} cost
model — validating the sublinear-cost claim that concludes §5.2."""

from __future__ import annotations

import math
import os

import numpy as np

from repro.core import AMIHIndex, AMIHStats

from .common import make_db, make_queries, write_csv


def binary_entropy(a: float) -> float:
    if a <= 0 or a >= 1:
        return 0.0
    return -a * math.log2(a) - (1 - a) * math.log2(1 - a)


def run():
    max_n = int(os.environ.get("REPRO_BENCH_MAX_N", 1_000_000))
    p, K = 64, 10
    rows = []
    for n in (10_000, 100_000, 1_000_000):
        if n > max_n:
            continue
        db_bits, db = make_db(n, p, seed=0, mode="uniform")
        _, qs = make_queries(db_bits, 25, seed=1)
        idx = AMIHIndex.build(db, p)
        probes, verified, radii = [], [], []
        for q in qs:
            st = AMIHStats()
            idx.knn(q, K, stats=st)
            probes.append(st.probes)
            verified.append(st.verified)
            radii.append(st.max_radius)
        r = float(np.mean(radii))
        pred = (p / max(1.0, math.log2(n))) * n ** binary_entropy(r / p)
        cost = float(np.mean(probes)) + float(np.mean(verified))
        rows.append({
            "n": n, "p": p, "K": K, "m": idx.m,
            "avg_radius": round(r, 2),
            "avg_probes": round(float(np.mean(probes)), 1),
            "avg_verified": round(float(np.mean(verified)), 1),
            "measured_cost": round(cost, 1),
            "eq14_prediction": round(pred, 1),
            "cost_over_n": round(cost / n, 5),
        })
        print(f"n={n:>8}: cost {cost:10.1f} vs Eq.14 {pred:10.1f} "
              f"(cost/n = {cost/n:.5f})")
    # the claim: cost/n falls as n grows (sublinearity)
    fracs = [r["cost_over_n"] for r in rows]
    assert all(a >= b for a, b in zip(fracs, fracs[1:])), fracs
    path = write_csv("cost_model_eq14.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
