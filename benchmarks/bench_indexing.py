"""Paper Fig. 7: AMIH indexing (build) time vs dataset size, 64/128-bit."""

from __future__ import annotations

import os
import time

import numpy as np

from repro.core import AMIHIndex

from .common import make_db, write_csv


def run():
    max_n = int(os.environ.get("REPRO_BENCH_MAX_N", 1_000_000))
    rows = []
    for p in (64, 128):
        for n in (10_000, 100_000, 1_000_000):
            if n > max_n:
                continue
            _, db = make_db(n, p, seed=0)
            t0 = time.perf_counter()
            idx = AMIHIndex.build(db, p)
            dt = time.perf_counter() - t0
            rows.append({
                "p": p, "n": n, "m_tables": idx.m,
                "build_s": round(dt, 3),
                "ns_per_item": round(1e9 * dt / n, 1),
            })
            print(f"p={p} n={n:>8}: build {dt:.3f}s "
                  f"({rows[-1]['ns_per_item']} ns/item, m={idx.m})")
    path = write_csv("indexing_time.csv", rows)
    print(f"wrote {path}")
    return rows


if __name__ == "__main__":
    run()
