"""AQBC binarization and cross-polytope LSH baseline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aqbc
from repro.core.lsh import CrossPolytopeLSH
from repro.data import clustered_features


def test_encode_projected_is_exact_argmax():
    """The vectorized encoder must equal brute force over all prefix sets."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32))
    bits = np.asarray(aqbc.encode_projected(v))
    for i in range(20):
        row = np.asarray(v[i])
        best, best_b = -np.inf, None
        order = np.argsort(-row)
        for t in range(1, 9):
            b = np.zeros(8)
            b[order[:t]] = 1
            score = (b @ row) / np.sqrt(t)
            if score > best:
                best, best_b = score, b
        assert np.array_equal(bits[i], best_b), i


def test_learn_objective_monotone_and_orthogonal():
    x = clustered_features(400, dim=32, seed=1)
    model = aqbc.learn(x, code_bits=16, iters=12)
    R = np.asarray(model.rotation)
    np.testing.assert_allclose(R.T @ R, np.eye(16), atol=1e-4)
    trace = np.asarray(model.objective_trace)
    # monotone non-decreasing up to float noise (alternating maximization)
    assert trace[-1] >= trace[0] - 1e-5
    assert np.all(np.diff(trace) > -1e-3)


def test_aqbc_preserves_neighborhoods():
    """Codes of angularly-near vectors should be closer (in angle) than
    codes of far vectors, on average — the point of angular quantization."""
    x = clustered_features(600, dim=64, n_clusters=8, seed=2, noise=0.05)
    model = aqbc.learn(x, code_bits=32, iters=10)
    bits = np.asarray(aqbc.encode(jnp.asarray(x), model.rotation)).astype(np.float64)
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    bn = bits / np.maximum(np.linalg.norm(bits, axis=1, keepdims=True), 1e-9)
    rng = np.random.default_rng(0)
    ii = rng.integers(0, 600, 400)
    jj = rng.integers(0, 600, 400)
    real = (xn[ii] * xn[jj]).sum(1)
    code = (bn[ii] * bn[jj]).sum(1)
    # Code sims are heavily quantized (clustered points share codes, so
    # only ~tens of distinct values over 400 pairs) — a rank correlation
    # collapses under those ties. Pearson on the raw sims is the
    # tie-robust version of the same claim, and must be clearly positive.
    rr = np.corrcoef(real, code)[0, 1]
    assert rr > 0.5, rr
    # And the ordering claim directly: angularly-near pairs get closer
    # codes than far pairs on average, with a real margin.
    near, far = real >= np.quantile(real, 0.75), real <= np.quantile(real, 0.25)
    assert code[near].mean() > code[far].mean() + 0.1, (
        code[near].mean(), code[far].mean()
    )


def test_lsh_recall_increases_with_probes():
    x = clustered_features(1500, dim=32, seed=3)
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    lsh = CrossPolytopeLSH.build(x, l=8, k=1, proj_dim=16, seed=0)
    rng = np.random.default_rng(1)
    qs = x[rng.integers(0, 1500, 40)] + 0.01 * rng.normal(size=(40, 32)).astype(np.float32)

    def recall(probes):
        hit = 0
        for q in qs:
            qn = q / np.linalg.norm(q)
            truth = int(np.argmax(xn @ qn))
            got = lsh.query(q, k_neighbors=1, probes_per_table=probes)
            hit += int(len(got) and got[0] == truth)
        return hit / len(qs)

    r1, r8 = recall(1), recall(8)
    assert r8 >= r1
    assert r8 > 0.5, (r1, r8)
