"""AdamW (+ int8 moments), schedules, gradient compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (
    OptimConfig,
    apply_error_feedback,
    apply_updates,
    dequantize_block_int8,
    init_state,
    lr_at,
    quantize_block_int8,
    state_specs,
)


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (8, 16)),
        "b": jnp.zeros((16,)),
        "deep": {"v": jax.random.normal(k2, (5,))},
    }


def test_lr_schedule_shape():
    cfg = OptimConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(110)]
    assert lrs[0] < lrs[5] < lrs[9]               # warmup rising
    assert abs(lrs[10] - 1.0) < 0.02              # peak
    assert lrs[50] < lrs[10]                      # decaying
    assert lrs[105] == pytest.approx(0.1, abs=1e-6)  # floor


def test_adamw_descends_quadratic():
    cfg = OptimConfig(peak_lr=0.05, warmup_steps=1, decay_steps=1000,
                      weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = init_state(cfg, params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = apply_updates(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_applied():
    cfg = OptimConfig(peak_lr=1e-3, clip_norm=1.0, warmup_steps=1)
    params = {"x": jnp.zeros((4,))}
    state = init_state(cfg, params)
    big = {"x": jnp.full((4,), 1e6)}
    _, _, m = apply_updates(cfg, params, big, state)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_quantized_moments_track_full_precision():
    key = jax.random.key(0)
    params_a = _toy_params(key)
    params_b = jax.tree.map(jnp.copy, params_a)
    cfg_f = OptimConfig(peak_lr=1e-2, warmup_steps=1, quantized_moments=False)
    cfg_q = OptimConfig(peak_lr=1e-2, warmup_steps=1, quantized_moments=True,
                        moment_block=32)
    sa, sb = init_state(cfg_f, params_a), init_state(cfg_q, params_b)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["deep"]["v"] ** 2)

    for _ in range(20):
        ga = jax.grad(loss)(params_a)
        gb = jax.grad(loss)(params_b)
        params_a, sa, _ = apply_updates(cfg_f, params_a, ga, sa)
        params_b, sb, _ = apply_updates(cfg_q, params_b, gb, sb)
    wa = np.asarray(params_a["w"])
    wb = np.asarray(params_b["w"])
    assert np.max(np.abs(wa - wb)) < 0.05 * (np.abs(wa).max() + 1e-6)


def test_state_specs_match_init():
    for quant in (False, True):
        cfg = OptimConfig(quantized_moments=quant, moment_block=32)
        params = _toy_params(jax.random.key(1))
        state = init_state(cfg, params)
        specs = state_specs(
            cfg,
            jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params),
        )
        flat_s = jax.tree.leaves(state)
        flat_t = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        )
        assert len(flat_s) == len(flat_t)
        for a, b in zip(flat_s, flat_t):
            assert a.shape == b.shape and a.dtype == b.dtype


# ------------------------------------------------------------- compression
@given(seed=st.integers(0, 2**31 - 1), block=st.sampled_from([16, 64, 256]))
@settings(max_examples=30, deadline=None)
def test_int8_quantization_error_bounded(seed, block):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 10)
    q, scale = quantize_block_int8(x, block)
    back = dequantize_block_int8(q, scale, x.shape)
    err = np.abs(np.asarray(back - x))
    # error per block bounded by scale/2 = max|x_block| / 254
    assert err.max() <= float(scale.max()) * 0.51 + 1e-7


def test_error_feedback_cancels_bias():
    """Sum of reconstructed grads + final residual == sum of true grads
    (telescoping identity of EF), so accumulated bias stays bounded."""
    rng = np.random.default_rng(0)
    res = jnp.zeros((256,))
    total_true = np.zeros((256,))
    total_recon = np.zeros((256,))
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        q, scale, res = apply_error_feedback(g, res, block=64)
        recon = dequantize_block_int8(q, scale, (256,))
        total_true += np.asarray(g)
        total_recon += np.asarray(recon)
    gap = np.abs(total_true - (total_recon + np.asarray(res)))
    assert gap.max() < 1e-3  # exact telescoping up to float add order
