"""The cross-host serving tier (repro.cluster): transport framing in
isolation (round-trips, partial reads, truncation, timeouts), the worker
frame loop in-process, coordinator failure semantics against stub
workers (request timeout -> degraded cluster), and the end-to-end
exactness contract over a REAL spawned localhost fleet — merged cluster
results bit-identical in sims to ``linear_scan_knn`` and bit-identical
in ids to single-host ``sharded_amih`` over the same plan, including
uneven N, K > per-host rows, and a worker SIGKILLed mid-stream (whose
tickets must FAIL promptly, never hang).

The spawned fleet is module-scoped (each worker is a fresh interpreter
importing jax — seconds per process), shared by every exactness test via
``workers=``; the kill test gets its own throwaway fleet.
"""

import json
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.cluster import (
    ClusterDegradedError,
    FrameError,
    LocalCluster,
    RequestTimeoutError,
    WorkerDiedError,
    pack_ragged,
    recv_frame,
    send_frame,
    unpack_ragged,
)
from repro.cluster.worker import WorkerServer, stats_from_wire, \
    stats_to_wire
from repro.core import AMIHStats, linear_scan_knn, make_engine, pack_bits
from repro.core.engine import EngineStats
from repro.core.linear_scan import sims_against_db, sims_for_ids
from repro.core.single_table import SearchStats
from repro.data import synthetic_binary_codes, synthetic_queries
from repro.shard import ShardPlan


def _check_exact(ids, sims, qs, db, k_eff):
    """The repo-wide exactness convention: sims bit-identical to the
    scan; ids distinct and really carrying those sims (tie ORDER inside
    one Hamming tuple is the only permitted difference vs the scan)."""
    B = qs.shape[0]
    assert ids.shape == (B, k_eff) and sims.shape == (B, k_eff)
    for i in range(B):
        _, sims_l = linear_scan_knn(qs[i], db, k_eff)
        np.testing.assert_array_equal(sims[i], sims_l)
        np.testing.assert_array_equal(
            sims_for_ids(qs[i], db, ids[i]), sims[i]
        )
        assert len(set(ids[i].tolist())) == k_eff


# ============================================================= transport
def _pair():
    a, b = socket.socketpair()
    return a, b


def test_frame_roundtrip_meta_and_arrays():
    a, b = _pair()
    try:
        arrays = {
            "q": np.arange(12, dtype=np.uint32).reshape(3, 4),
            "floor": np.array([-np.inf, 0.25], dtype=np.float64),
            "empty": np.empty(0, dtype=np.int64),
        }
        send_frame(a, "search", {"req": 7, "k": 10}, arrays)
        kind, meta, got = recv_frame(b)
        assert kind == "search" and meta["req"] == 7 and meta["k"] == 10
        assert set(got) == set(arrays)
        for name, arr in arrays.items():
            assert got[name].dtype == arr.dtype
            np.testing.assert_array_equal(got[name], arr)
        # a bare frame (no meta, no arrays) round-trips too
        send_frame(a, "ping")
        kind, meta, got = recv_frame(b)
        assert kind == "ping" and meta == {} and got == {}
    finally:
        a.close(), b.close()


def test_frame_rejects_non_wire_dtype_before_sending():
    a, b = _pair()
    try:
        with pytest.raises(ValueError, match="non-wire dtype"):
            send_frame(a, "x", arrays={
                "bad": np.zeros(2, dtype=np.float16)
            })
        # nothing hit the wire: the socket would block on recv
        b.setblocking(False)
        with pytest.raises(BlockingIOError):
            b.recv(1)
    finally:
        a.close(), b.close()


def test_frame_partial_reads_and_short_writes():
    """TCP delivers byte dribbles, not frames: a sender trickling one
    byte at a time must still produce one intact frame on the reader."""
    a, b = _pair()
    try:
        payload = {"ids": np.arange(1000, dtype=np.int64)}
        cap = []
        orig = a.sendall

        class Dribble:
            def sendall(self, data):
                cap.append(bytes(data))

        fake = Dribble()
        send_frame(fake, "result", {"req": 1}, payload)
        (frame,) = cap

        def trickle():
            for i in range(0, len(frame), 1):
                orig(frame[i : i + 1])

        t = threading.Thread(target=trickle, daemon=True)
        t.start()
        kind, meta, got = recv_frame(b)
        t.join()
        assert kind == "result" and meta["req"] == 1
        np.testing.assert_array_equal(got["ids"], payload["ids"])
    finally:
        a.close(), b.close()


def test_frame_truncation_and_bad_magic_raise_frame_error():
    a, b = _pair()
    cap = []

    class Cap:
        def sendall(self, data):
            cap.append(bytes(data))

    send_frame(Cap(), "result", {"req": 1},
               {"ids": np.arange(64, dtype=np.int64)})
    (frame,) = cap
    try:
        a.sendall(frame[: len(frame) // 2])
        a.close()   # EOF mid-frame
        with pytest.raises(FrameError, match="mid-frame"):
            recv_frame(b)
    finally:
        b.close()
    a, b = _pair()
    try:
        a.sendall(b"NOPE" + frame[4:])
        with pytest.raises(FrameError, match="magic"):
            recv_frame(b)
    finally:
        a.close(), b.close()


def test_frame_rejects_negative_declared_shape():
    """A negative dim makes np.prod negative, which would slip under the
    MAX_PAYLOAD guard and reach np.frombuffer as a bad count — the
    receiver must reject it as a FrameError up front."""
    a, b = _pair()
    try:
        hdr = json.dumps({
            "kind": "result",
            "arrays": [{"name": "z", "dtype": "int64",
                        "shape": [-1, 1 << 40]}],
        }).encode()
        a.sendall(b"AMRP" + struct.pack(">I", len(hdr)) + hdr)
        with pytest.raises(FrameError, match="negative dimension"):
            recv_frame(b)
    finally:
        a.close(), b.close()


def test_recv_frame_timeout_bounds_idle_wait():
    a, b = _pair()
    try:
        t0 = time.perf_counter()
        with pytest.raises((socket.timeout, TimeoutError)):
            recv_frame(b, timeout=0.2)
        assert time.perf_counter() - t0 < 5.0
        # the socket is reusable after the timeout (deadline cleared)
        send_frame(a, "pong", {"seq": 3})
        kind, meta, _ = recv_frame(b, timeout=5.0)
        assert kind == "pong" and meta["seq"] == 3
    finally:
        a.close(), b.close()


def test_pack_unpack_ragged_roundtrip_and_validation():
    planes = [
        np.array([3, 1, 4], dtype=np.int64),
        np.empty(0, dtype=np.int64),
        np.array([1, 5], dtype=np.int64),
    ]
    flat, lens = pack_ragged(planes, dtype=np.int64)
    assert flat.tolist() == [3, 1, 4, 1, 5]
    assert lens.tolist() == [3, 0, 2]
    back = unpack_ragged(flat, lens)
    assert [p.tolist() for p in back] == [p.tolist() for p in planes]
    flat2, lens2 = pack_ragged([], dtype=np.float64)
    assert flat2.shape == (0,) and lens2.shape == (0,)
    with pytest.raises(FrameError, match="lengths sum"):
        unpack_ragged(flat, np.array([3, 1, 2], dtype=np.int64))


def test_stats_wire_roundtrip_mixed_kinds():
    st = EngineStats(
        backend="sharded_amih", queries=2,
        per_query=[AMIHStats(probes=3, tuples_processed=7), SearchStats()],
        shards=2, per_shard=[{"shard": 0, "rows": 5}],
        cache_info={"hits": 1},
    )
    back = stats_from_wire(stats_to_wire(st))
    assert back.backend == st.backend and back.queries == 2
    assert isinstance(back.per_query[0], AMIHStats)
    assert isinstance(back.per_query[1], SearchStats)
    assert back.per_query[0].tuples_processed == 7
    assert back.per_shard == st.per_shard
    assert back.cache_info == st.cache_info


# ====================================================== worker, in-process
def test_worker_frame_loop_in_process():
    """One WorkerServer driven over raw frames: build -> ready, a bounded
    search returning exact global-id planes, bound frames published when
    queries fill k, and a live remote bound applied without error."""
    p, n, B, k = 64, 600, 4, 5
    db_bits = synthetic_binary_codes(n, p, seed=20)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=21))
    # the worker serves the SECOND half of a 2-host partition: its ids
    # must come back global with no coordinator-side fixup
    plan = ShardPlan.balanced(n, 4)
    sub = plan.host_partition(2)[1]
    srv = WorkerServer("127.0.0.1", 0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    sock = socket.create_connection(srv.addr, timeout=30)
    try:
        send_frame(sock, "build", {
            "host": 1, "p": p, "backend": "sharded_amih",
            "plan": sub.summary(), "cfg": {},
        }, {"db": db[sub.base : sub.base + sub.n]})
        kind, meta, _ = recv_frame(sock, timeout=60)
        assert kind == "ready"
        assert meta["host"] == 1 and meta["n"] == sub.n
        send_frame(sock, "search", {"req": 0, "k": k}, {
            "q": qs, "floor": np.full(B, -np.inf),
        })
        bounds, result = [], None
        while result is None:
            kind, meta, arrays = recv_frame(sock, timeout=60)
            if kind == "bound":
                assert meta["req"] == 0
                bounds.append((int(arrays["qi"][0]),
                               float(arrays["val"][0])))
                # echo it back: a live bound mid-search must be absorbed
                send_frame(sock, "bound", {"req": 0}, {
                    "qi": arrays["qi"].copy(), "val": arrays["val"].copy(),
                })
            elif kind == "result":
                result = (meta, arrays)
        meta, arrays = result
        ids = unpack_ragged(arrays["ids"], arrays["lens"])
        sims = unpack_ragged(arrays["sims"], arrays["lens"])
        slab = db[sub.base : sub.base + sub.n]
        for i in range(B):
            assert sims[i].shape[0] >= k        # full local fill
            _, sims_l = linear_scan_knn(qs[i], slab, k)
            np.testing.assert_array_equal(sims[i][:k], sims_l)
            assert (ids[i] >= sub.base).all()   # global ids
            np.testing.assert_array_equal(
                sims_for_ids(qs[i], db, ids[i]), sims[i]
            )
        # every query filled k local rows -> every query published a
        # bound at least once, and re-publishes only RAISE it (each
        # successive shard can tighten the local k-th)
        assert {qi for qi, _ in bounds} == set(range(B))
        last = {}
        for qi, val in bounds:
            assert val > last.get(qi, -np.inf)
            last[qi] = val
        for i in range(B):
            assert last[i] == sims[i][k - 1]    # final bound = local kth
        st = stats_from_wire(meta["stats"])
        assert st.queries == B and st.shards == sub.num_shards
    finally:
        sock.close()
        srv.close()
        t.join(timeout=10)


def test_worker_survives_malformed_frame_content():
    """A well-framed build whose CONTENT is garbage (missing meta keys)
    must tear down that connection only — the documented failure unit —
    and the server keeps accepting, never dying with the exception."""
    srv = WorkerServer("127.0.0.1", 0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        sock = socket.create_connection(srv.addr, timeout=10)
        try:
            send_frame(sock, "build", {"host": 0})   # no backend/plan/p
            with pytest.raises(FrameError):          # conn torn down
                recv_frame(sock, timeout=10)
        finally:
            sock.close()
        # the process survived: a fresh connection still gets service
        sock = socket.create_connection(srv.addr, timeout=10)
        try:
            send_frame(sock, "ping", {"seq": 9})
            kind, meta, _ = recv_frame(sock, timeout=10)
            assert kind == "pong" and meta["seq"] == 9
        finally:
            sock.close()
    finally:
        srv.close()
        t.join(timeout=10)


# ============================================= coordinator failure semantics
class _StubWorker:
    """Protocol-correct worker that never answers searches: replies
    ready/pong so the build succeeds and heartbeats stay green, then
    swallows every search frame — the pure request-timeout case."""

    def __init__(self):
        self._srv = socket.create_server(("127.0.0.1", 0))
        self.addr = self._srv.getsockname()[:2]
        self.searches = 0
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        try:
            conn, _ = self._srv.accept()
        except OSError:
            return
        try:
            while True:
                kind, meta, _ = recv_frame(conn)
                if kind == "build":
                    send_frame(conn, "ready", {
                        "host": meta.get("host", 0),
                        "n": meta["plan"]["n"],
                        "shards": meta["plan"]["num_shards"],
                    })
                elif kind == "ping":
                    send_frame(conn, "pong", {"seq": meta.get("seq", 0)})
                elif kind == "search":
                    self.searches += 1   # ...and never answer
                elif kind == "close":
                    return
        except (FrameError, OSError):
            pass
        finally:
            conn.close()

    def close(self):
        self._srv.close()
        self._t.join(timeout=5)


def test_heartbeat_clock_restarts_after_slow_build():
    """Regression: last_seen is stamped at socket-connect time, but a
    build (slab transfer + engine construction) can take minutes — the
    coordinator must restart the staleness clock at init, or the first
    heartbeat check marks every worker dead before any ping is sent."""
    from repro.cluster.coordinator import ClusterCoordinator, \
        _WorkerHandle

    a, b = _pair()
    stop = threading.Event()

    def ponger():
        try:
            while not stop.is_set():
                kind, meta, _ = recv_frame(b)
                if kind == "ping":
                    send_frame(b, "pong", {"seq": meta.get("seq", 0)})
        except (FrameError, OSError):
            pass

    t = threading.Thread(target=ponger, daemon=True)
    t.start()
    h = _WorkerHandle(0, ("127.0.0.1", 0), a)
    h.last_seen -= 60.0                # pretend build took a minute
    coord = ClusterCoordinator(
        [h], ShardPlan.balanced(10, 1), heartbeat=0.1
    )
    try:
        time.sleep(1.0)                # ~10 beats: any stale clock trips
        assert h.alive
    finally:
        stop.set()
        coord.close()
        t.join(timeout=5)


class _GarbageResultWorker:
    """Answers the build correctly, then replies to a search with a
    WELL-FRAMED result whose stats rows don't decode (unexpected field
    -> TypeError in stats_from_wire) — the escape path that bypasses
    FrameError/OSError in the coordinator's reader."""

    def __init__(self):
        self._srv = socket.create_server(("127.0.0.1", 0))
        self.addr = self._srv.getsockname()[:2]
        self._t = threading.Thread(target=self._loop, daemon=True)
        self._t.start()

    def _loop(self):
        try:
            conn, _ = self._srv.accept()
        except OSError:
            return
        try:
            while True:
                kind, meta, arrays = recv_frame(conn)
                if kind == "build":
                    send_frame(conn, "ready", {
                        "host": meta.get("host", 0),
                        "n": meta["plan"]["n"],
                        "shards": meta["plan"]["num_shards"],
                    })
                elif kind == "ping":
                    send_frame(conn, "pong", {"seq": meta.get("seq", 0)})
                elif kind == "search":
                    B = arrays["q"].shape[0]
                    send_frame(conn, "result", {
                        "req": meta["req"],
                        "stats": {"per_query": [
                            {"_kind": "AMIHStats", "no_such_counter": 1}
                        ]},
                    }, {
                        "ids": np.zeros(0, dtype=np.int64),
                        "sims": np.zeros(0, dtype=np.float64),
                        "lens": np.zeros(B, dtype=np.int64),
                    })
                elif kind == "close":
                    return
        except (FrameError, OSError):
            pass
        finally:
            conn.close()

    def close(self):
        self._srv.close()
        self._t.join(timeout=5)


def test_corrupt_result_fails_request_fast_not_timeout():
    """A result the reader can't decode must fail the in-flight request
    IMMEDIATELY via _mark_dead, not silently kill the reader thread and
    leave the request to sit out the full request_timeout."""
    p, n = 64, 200
    db = pack_bits(synthetic_binary_codes(n, p, seed=24))
    qs = pack_bits(synthetic_queries(
        synthetic_binary_codes(n, p, seed=24), 2, seed=25))
    stub = _GarbageResultWorker()
    try:
        eng = make_engine(
            "cluster", db, p, workers=[stub.addr],
            request_timeout=60.0, heartbeat=0.4,
        )
        try:
            t0 = time.perf_counter()
            with pytest.raises(WorkerDiedError):
                eng.knn_batch(qs, 3)
            # via the reader's death, NOT the 60 s request timeout
            assert time.perf_counter() - t0 < 20.0
        finally:
            eng.close()
    finally:
        stub.close()


def test_request_timeout_degrades_silent_worker():
    p, n = 64, 200
    db = pack_bits(synthetic_binary_codes(n, p, seed=22))
    qs = pack_bits(synthetic_queries(
        synthetic_binary_codes(n, p, seed=22), 2, seed=23))
    stub = _StubWorker()
    try:
        eng = make_engine(
            "cluster", db, p, workers=[stub.addr],
            request_timeout=1.5, heartbeat=0.4,
        )
        try:
            t0 = time.perf_counter()
            with pytest.raises(RequestTimeoutError, match="timed out"):
                eng.knn_batch(qs, 3)
            assert time.perf_counter() - t0 < 30.0   # bounded, no hang
            assert stub.searches == 1
            # the timed-out handle's socket is CLOSED (not just flagged
            # dead): the stub's serving loop sees EOF and exits, instead
            # of lingering until eng.close() with a parked reader
            stub._t.join(timeout=10.0)
            assert not stub._t.is_alive()
            # the silent worker is OUT: the cluster fails fast now
            # instead of re-timing-out every request
            with pytest.raises(ClusterDegradedError):
                eng.knn_batch(qs, 3)
        finally:
            eng.close()
    finally:
        stub.close()


# ===================================================== e2e: spawned fleet
HOSTS = 3


@pytest.fixture(scope="module")
def fleet():
    """One spawned 3-worker localhost fleet for every e2e test here
    (workers accept a new coordinator per engine, so engines can come
    and go while the processes live for the whole module)."""
    fl = LocalCluster(HOSTS)
    yield fl
    fl.close()


@pytest.fixture(scope="module")
def corpus():
    p, n = 64, 997                     # prime N: uneven shards everywhere
    db_bits = synthetic_binary_codes(n, p, seed=0)
    return p, pack_bits(db_bits), db_bits


@pytest.mark.parametrize("B", [1, 8, 64])
def test_cluster_exact_vs_scan_and_single_host(fleet, corpus, B):
    """The acceptance contract: merged cluster results carry exactly the
    scan's sims AND exactly the ids single-host sharded_amih produces
    over the same plan (the lexsort merge commutes with partitioning)."""
    p, db, db_bits = corpus
    k, S = 10, 5                       # 5 shards over 3 hosts: runs 2/2/1
    qs = pack_bits(synthetic_queries(db_bits, B, seed=B))
    eng = make_engine("cluster", db, p, workers=fleet.addresses,
                      num_shards=S)
    try:
        ids, sims, stats = eng.knn_batch(qs, k)
    finally:
        eng.close()
    _check_exact(ids, sims, qs, db, k)
    single = make_engine("sharded_amih", db, p, num_shards=S)
    ids_1, sims_1, _ = single.knn_batch(qs, k)
    np.testing.assert_array_equal(ids, ids_1)
    np.testing.assert_array_equal(sims, sims_1)
    # per-host attribution covers the whole fleet and all the rows
    assert len(stats.per_host) == HOSTS
    assert sum(h["rows"] for h in stats.per_host) == db.shape[0]
    assert sum(h["shards"] for h in stats.per_host) == S
    assert all(h["rpc_ms"] >= 0 for h in stats.per_host)
    assert stats.queries == B and len(stats.per_query) == B


def test_cluster_k_exceeds_per_host_rows(fleet):
    """K larger than any single host's slice: hosts return short planes
    (and stay silent on the bound channel), the union still covers k."""
    p, n, k = 64, 50, 40               # ~17 rows/host, k=40
    db_bits = synthetic_binary_codes(n, p, seed=2)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=3))
    eng = make_engine("cluster", db, p, workers=fleet.addresses,
                      num_shards=HOSTS)
    try:
        ids, sims, _ = eng.knn_batch(qs, k)
        _check_exact(ids, sims, qs, db, k)
        # k > n clamps to n (the union is the whole DB)
        ids, sims, _ = eng.knn_batch(qs, 99)
        _check_exact(ids, sims, qs, db, n)
    finally:
        eng.close()


def test_cluster_bound_broadcast_reaches_other_hosts(fleet, corpus):
    """The cross-host floor is not decorative: after a batch, the
    coordinator has rebroadcast raised bounds to peers (bound_frames
    move), and priming never breaks exactness (prime_bound on/off
    agree bit-identically)."""
    p, db, db_bits = corpus
    qs = pack_bits(synthetic_queries(db_bits, 8, seed=40))
    eng = make_engine("cluster", db, p, workers=fleet.addresses,
                      num_shards=6)
    try:
        ids_a, sims_a, stats = eng.knn_batch(qs, 10)
        assert sum(h["bound_frames"] for h in stats.per_host) > 0
    finally:
        eng.close()
    unprimed = make_engine("cluster", db, p, workers=fleet.addresses,
                           num_shards=6, prime_bound=False)
    try:
        ids_b, sims_b, _ = unprimed.knn_batch(qs, 10)
    finally:
        unprimed.close()
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sims_a, sims_b)


def test_cluster_exact_when_floor_equals_kth_with_tie_group(fleet):
    """Regression: exactly-tied probing tuples can round 1 ulp apart in
    float64, so a worker's strictly-below stop may fire mid-tie-group
    and drop rows AT the floor. With the primed floor equal to the true
    k-th (the sample covers the whole DB at this n) and two DB rows
    exactly at it, the merge must still produce the scan's sims — the
    coordinator keeps the bound-justifying sample rows in the pool."""
    p, n, k, seed = 128, 186, 6, 1994142471
    db_bits = synthetic_binary_codes(n, p, seed=seed)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 8, seed=seed + 1))
    eng = make_engine("cluster", db, p, workers=fleet.addresses,
                      num_shards=3)
    try:
        ids, sims, _ = eng.knn_batch(qs, k)
    finally:
        eng.close()
    _check_exact(ids, sims, qs, db, k)
    # query 6 is the tie witness: its k-th sim repeats at the floor
    scan_sims = np.sort(sims_against_db(qs[6], db))[::-1]
    assert scan_sims[k - 1] == scan_sims[k - 2] or \
        (scan_sims == scan_sims[k - 1]).sum() > 1


def test_killed_worker_fails_tickets_and_degrades_cluster():
    """A worker SIGKILLed mid-stream: the in-flight step's tickets FAIL
    with a ClusterError promptly (no hang), unanswered queries are
    re-queued, and the degraded cluster fast-fails afterwards."""
    from repro.cluster import ClusterError
    from repro.serve.retrieval import RetrievalConfig, RetrievalService

    p, n, B, k = 64, 1200, 12, 5
    db_bits = synthetic_binary_codes(n, p, seed=50)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=51))
    fl = LocalCluster(2)
    eng = None
    try:
        eng = make_engine("cluster", db, p, workers=fl.addresses,
                          num_shards=2, request_timeout=60.0)
        svc = RetrievalService(
            cfg=None, params=None,
            rcfg=RetrievalConfig(search_batch_size=4),  # 12 q -> 3 steps
        )
        svc.engine = eng
        # identity "encoder" over pre-packed codes, gated so steps after
        # the first cannot reach their search until the kill has landed
        # (otherwise the fast steps race the signal and all complete)
        gate = threading.Event()
        calls = [0]

        def encode(toks):
            if calls[0] > 0:
                assert gate.wait(timeout=30.0)
            calls[0] += 1
            return np.asarray(toks)

        svc.encode_query = encode
        tickets = [svc.submit(qs[i]) for i in range(B)]
        futures = [t.future for t in tickets]   # snapshot pre-requeue
        stream = svc.run_queued(k, stream=True)
        first = next(stream)                    # step 0 answered cleanly
        assert len(first.results) == 4
        fl.kill_worker(1)                       # SIGKILL mid-stream
        gate.set()                              # release steps 1, 2
        t0 = time.perf_counter()
        with pytest.raises(ClusterError):
            for _ in stream:
                pass
        assert time.perf_counter() - t0 < 30.0  # failed, didn't hang
        # step 0's tickets resolved; every later ticket's ORIGINAL
        # future fails with the step's ClusterError and the query is
        # back in the queue for a retry drain
        for f in futures[:4]:
            ids, sims = f.result(timeout=1)
            assert ids.shape == (k,)
        failed = [f for f in futures[4:]
                  if isinstance(f.exception(timeout=10), ClusterError)]
        assert len(failed) == B - 4
        assert svc.queue_depth() == B - 4
        # the cluster stays degraded: fail-fast, not retry-and-timeout
        with pytest.raises(ClusterDegradedError):
            eng.knn_batch(qs[:2], k)
    finally:
        if eng is not None:
            eng.close()
        fl.close()


def test_cluster_engine_spawns_and_owns_local_fleet():
    """The no-workers path: build spawns its own LocalCluster and close
    tears it down (the smoke/launcher shape, kept under test here)."""
    p, n = 64, 300
    db_bits = synthetic_binary_codes(n, p, seed=60)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 2, seed=61))
    eng = make_engine("cluster", db, p, hosts=2, num_shards=2)
    procs = list(eng._fleet.procs)
    try:
        ids, sims, _ = eng.knn_batch(qs, 3)
        _check_exact(ids, sims, qs, db, 3)
        assert all(pr.is_alive() for pr in procs)
    finally:
        eng.close()
    assert not any(pr.is_alive() for pr in procs)
