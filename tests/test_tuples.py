"""Unit + property tests for the Hamming-distance-tuple algebra (paper §3-4)."""

import math
from fractions import Fraction

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tuples import (
    all_valid_tuples,
    is_valid_tuple,
    rhat,
    sim_compare,
    sim_squared_fraction,
    sim_value,
    tuple_count,
)


def test_sim_matches_eq3_example():
    # q=(1,1,1,0,0,0), b=(0,1,0,1,1,1): tuple (2,3)  (paper Example 1)
    p, z = 6, 3
    r1, r2 = 2, 3
    # direct cosine: <q,b>=1, |q|=sqrt(3), |b|=sqrt(4)
    want = 1 / (math.sqrt(3) * math.sqrt(4))
    assert sim_value(p, z, r1, r2) == pytest.approx(want)


def test_sim_self_is_one():
    assert sim_value(64, 30, 0, 0) == pytest.approx(1.0)


def test_degenerate_zero_query():
    assert sim_value(8, 0, 0, 3) == 0.0


def test_degenerate_zero_code():
    # z - r1 + r2 == 0 means the code is all-zeros
    assert sim_value(8, 3, 3, 0) == 0.0


@given(
    p=st.integers(1, 64),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_sim_squared_consistent_with_float(p, data):
    z = data.draw(st.integers(0, p))
    r1 = data.draw(st.integers(0, z))
    r2 = data.draw(st.integers(0, p - z))
    frac = sim_squared_fraction(p, z, r1, r2)
    f = sim_value(p, z, r1, r2)
    assert math.isclose(float(frac), f * f, abs_tol=1e-12)


@given(p=st.integers(1, 48), data=st.data())
@settings(max_examples=200, deadline=None)
def test_sim_compare_total_order(p, data):
    z = data.draw(st.integers(0, p))
    tuples = all_valid_tuples(p, z)
    idx = st.integers(0, len(tuples) - 1)
    a = tuples[data.draw(idx)]
    b = tuples[data.draw(idx)]
    c = sim_compare(p, z, a, b)
    fa, fb = sim_value(p, z, *a), sim_value(p, z, *b)
    if fa > fb + 1e-12:
        assert c == 1
    elif fb > fa + 1e-12:
        assert c == -1
    # exact comparator must be antisymmetric
    assert sim_compare(p, z, b, a) == -c


def test_prop1_monotone_in_r01_at_fixed_radius():
    """Prop 1: at fixed Hamming distance r, sim grows with r_{0->1}."""
    p, z = 45, 32
    for r in range(1, 13):
        sims = [
            sim_value(p, z, r - b, b)
            for b in range(r + 1)
            if is_valid_tuple(p, z, r - b, b)
        ]
        assert all(sims[i] <= sims[i + 1] + 1e-15 for i in range(len(sims) - 1))


def test_prop2_ball_separation():
    """Prop 2 (t=1): while z > r(r+1), C(q,r) beats everything outside."""
    p = 64
    for z in (10, 32, 50):
        r_h = rhat(z)
        assert z > r_h * (r_h + 1) or r_h == 0
        assert z <= (r_h + 1) * (r_h + 2)
        # min sim inside ball at radius r_h vs max sim outside
        inside_min = sim_value(p, z, r_h, 0)
        outside_max = sim_value(p, z, 0, r_h + 1) if r_h + 1 <= p - z else 0.0
        assert inside_min >= outside_max - 1e-12


def test_tuple_count_eq4():
    p, z = 10, 4
    assert tuple_count(p, z, 1, 2) == math.comb(4, 1) * math.comb(6, 2)
    assert tuple_count(p, z, 5, 0) == 0  # invalid r1 > z
    total = sum(tuple_count(p, z, a, b) for a, b in all_valid_tuples(p, z))
    assert total == 2 ** p  # tuples partition the whole hypercube


@given(z=st.integers(0, 10_000))
@settings(max_examples=300, deadline=None)
def test_rhat_is_integer_root(z):
    r = rhat(z)
    assert r >= 0
    assert r * (r + 1) <= z  # inside the guarantee
    assert (r + 1) * (r + 2) > z
