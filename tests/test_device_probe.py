"""Device-resident probing walk (core.probe_device + kernels.device_probe).

The fused probe -> bucket-lookup -> verify launch must be bit-identical
to the host reference walk (ids AND sims) and exact vs linear scan (sims
up to in-tuple ties), across every entry point that can select it, in
ONE walk launch per batch: every z-group rides the same schedule-stack
row of one ``lax.while_loop`` (``probe_fused=False`` keeps the PR 6
one-launch-per-z-group shape as the parity oracle).
"""

import numpy as np
import pytest

from repro.core import AMIHIndex, AMIHStats, linear_scan_knn, pack_bits
from repro.core.engine import make_engine
from repro.core.linear_scan import sims_for_ids
from repro.core.probe_device import (
    build_device_csr,
    get_schedule,
    schedule_cache_clear,
    schedule_cache_info,
)
from repro.kernels import ops
from repro.obs.metrics import REGISTRY as _REG


def _make_data(n, p, B, seed=0, clustered=False):
    rng = np.random.default_rng(seed)
    if clustered:
        # queries a few flips away from db rows: small probing radii, so
        # the precompiled stream covers the walk without the scan fallback
        base = rng.integers(0, 2, size=(n, p)).astype(np.uint8)
        picks = rng.integers(0, n, size=B)
        q_bits = base[picks].copy()
        for i in range(B):
            flips = rng.choice(p, size=3, replace=False)
            q_bits[i, flips] ^= 1
        return pack_bits(base), pack_bits(q_bits)
    db_bits = rng.integers(0, 2, size=(n, p)).astype(np.uint8)
    q_bits = rng.integers(0, 2, size=(B, p)).astype(np.uint8)
    return pack_bits(db_bits), pack_bits(q_bits)


def _check_vs_scan(q, db, ids, sims, k):
    """Exactness up to in-tuple ties: the sim multiset matches linear
    scan (1-ulp tolerance — the scan factors sqrt(z)*sqrt(|x|) where the
    tuple path takes one sqrt of the product) and every returned id
    really carries the sim it came with."""
    B = ids.shape[0]
    for b in range(B):
        _, sims_l = linear_scan_knn(q[b], db, k)
        np.testing.assert_allclose(sims[b], sims_l, atol=1e-9)
        np.testing.assert_allclose(
            sims_for_ids(q[b], db, ids[b].astype(np.int64)), sims[b],
            atol=1e-9,
        )


def _pair(db, p, **kw):
    host = AMIHIndex.build(db, p, probe_backend="host", **kw)
    dev = AMIHIndex.build(db, p, probe_backend="device", **kw)
    return host, dev


# ------------------------------------------------------------- exactness
@pytest.mark.parametrize(
    "p,B,n,k",
    [(32, 1, 300, 5), (32, 8, 300, 5), (64, 8, 500, 10),
     (64, 64, 500, 10), (128, 8, 300, 7)],
)
def test_device_bit_identical_to_host_and_scan(p, B, n, k):
    db, q = _make_data(n, p, B, seed=p + B)
    host, dev = _pair(db, p)
    ih, sh = host.knn_batch(q, k)
    id_, sd = dev.knn_batch(q, k)
    np.testing.assert_array_equal(ih, id_)
    np.testing.assert_array_equal(sh, sd)
    _check_vs_scan(q, db, id_, sd, k)


def test_zero_norm_queries():
    p, n, k = 64, 400, 6
    db, q = _make_data(n, p, 8, seed=3)
    q[0] = 0                      # zero query: Hamming-order fallback
    q[3] = 0
    host, dev = _pair(db, p)
    ih, sh = host.knn_batch(q, k)
    id_, sd = dev.knn_batch(q, k)
    np.testing.assert_array_equal(ih, id_)
    np.testing.assert_array_equal(sh, sd)
    _check_vs_scan(q, db, id_, sd, k)


def test_k_exceeds_bucket_yields():
    # k = n forces the walk past every bucket the early tuples yield
    p, n = 32, 120
    db, q = _make_data(n, p, 4, seed=11)
    host, dev = _pair(db, p)
    ih, sh = host.knn_batch(q, n)
    id_, sd = dev.knn_batch(q, n)
    np.testing.assert_array_equal(ih, id_)
    np.testing.assert_array_equal(sh, sd)
    _check_vs_scan(q, db, id_, sd, n)


def test_truncated_stream_falls_back_to_scan():
    p, n, k = 64, 400, 5
    db, q = _make_data(n, p, 8, seed=5)
    host = AMIHIndex.build(db, p, probe_backend="host")
    dev = AMIHIndex.build(db, p, probe_backend="device",
                          probe_stream_cap=64)
    before = _REG.value("launches.device_probe_scan")
    stats = [AMIHStats() for _ in range(q.shape[0])]
    ih, sh = host.knn_batch(q, k)
    id_, sd = dev.knn_batch(q, k, stats=stats)
    np.testing.assert_array_equal(sh, sd)
    _check_vs_scan(q, db, id_, sd, k)
    assert _REG.value("launches.device_probe_scan") > before
    assert any(st.fell_back_to_scan for st in stats)


def test_bounded_path_matches_host():
    p, n, k = 64, 500, 8
    db, q = _make_data(n, p, 16, seed=21)
    host, dev = _pair(db, p)
    for bound in (-np.inf, 0.4, 1.01):
        bounds = np.full(q.shape[0], bound)
        rh = host.knn_batch_bounded(q, k, stop_below=bounds)
        rd = dev.knn_batch_bounded(q, k, stop_below=bounds)
        for (hi, hs), (di, ds) in zip(rh, rd):
            np.testing.assert_array_equal(hi, di)
            np.testing.assert_array_equal(hs, ds)


# -------------------------------------------------------- launch economy
def test_one_walk_launch_per_batch():
    p, n, k = 64, 2000, 5
    db, q = _make_data(n, p, 32, seed=9, clustered=True)
    dev = AMIHIndex.build(db, p, probe_backend="device")
    groups = len(np.unique(np.bitwise_count(q).sum(axis=1)))
    assert groups > 1             # the fusion must actually fuse something
    walk0 = _REG.value("launches.device_probe")
    scan0 = _REG.value("launches.device_probe_scan")
    dev.knn_batch(q, k)
    assert _REG.value("launches.device_probe") - walk0 == 1
    # the cross-group scan fallback fires at most ONCE for the whole
    # batch (covering only bailed queries): O(1) launches per batch total
    assert _REG.value("launches.device_probe_scan") - scan0 <= 1
    # the PR 6 per-z-group shape survives behind probe_fused=False
    grouped = AMIHIndex.build(db, p, probe_backend="device",
                              probe_fused=False)
    walk0 = _REG.value("launches.device_probe")
    grouped.knn_batch(q, k)
    assert _REG.value("launches.device_probe") - walk0 == groups


@pytest.mark.parametrize("p,B", [(32, 1), (32, 8), (64, 8), (64, 64),
                                 (128, 8)])
def test_fused_batch_parity_and_single_launch(p, B):
    """Mixed-z batches: the fused walk is ONE launch per batch and
    bit-identical (ids AND sims) to both the host walk and the PR 6
    per-z-group device path."""
    n, k = 600, 7
    db, q = _make_data(n, p, B, seed=p + 2 * B)
    host = AMIHIndex.build(db, p, probe_backend="host")
    fused = AMIHIndex.build(db, p, probe_backend="device")
    grouped = AMIHIndex.build(db, p, probe_backend="device",
                              probe_fused=False)
    walk0 = _REG.value("launches.device_probe")
    scan0 = _REG.value("launches.device_probe_scan")
    if_, sf = fused.knn_batch(q, k)
    assert _REG.value("launches.device_probe") - walk0 == 1
    assert _REG.value("launches.device_probe_scan") - scan0 <= 1
    ih, sh = host.knn_batch(q, k)
    ig, sg = grouped.knn_batch(q, k)
    np.testing.assert_array_equal(ih, if_)
    np.testing.assert_array_equal(sh, sf)
    np.testing.assert_array_equal(ig, if_)
    np.testing.assert_array_equal(sg, sf)
    _check_vs_scan(q, db, if_, sf, k)


def test_batched_trace_counts_bounded():
    """Varying z-histograms across batches must NOT retrace the fused
    kernels: the schedule stack pads its group count and stream length
    to power-of-two buckets, so once a set of z values is resident, any
    mix of them traces nothing new."""
    from repro.kernels import device_probe

    p, n, k = 64, 800, 5
    db, _ = _make_data(n, p, 1, seed=23)
    dev = AMIHIndex.build(db, p, probe_backend="device")
    rng = np.random.default_rng(29)
    support = [28, 30, 32, 34, 36]

    def batch_with_zs(zs):
        bits = np.zeros((len(zs), p), dtype=np.uint8)
        for i, z in enumerate(zs):
            bits[i, rng.choice(p, size=z, replace=False)] = 1
        return pack_bits(bits)

    # warmup: every z of the support enters the stack; this call pays
    # the trace (and any stack growth / commit)
    dev.knn_batch(batch_with_zs(support + support[:3]), k)
    before = dict(device_probe.TRACE_COUNTS)
    for seed in range(5):
        r = np.random.default_rng(100 + seed)
        # a different histogram over the SAME support each batch
        zs = r.choice(support, size=8, p=np.roll(
            [0.4, 0.3, 0.15, 0.1, 0.05], seed
        ))
        dev.knn_batch(batch_with_zs(zs), k)
    after = dict(device_probe.TRACE_COUNTS)
    assert after["device_probe_walk_batched"] == \
        before["device_probe_walk_batched"]
    # the scan fallback pads the BAILED subset to a power-of-two bucket,
    # so at most log2(B) distinct shapes can ever trace
    assert after["device_probe_scan_multi"] - \
        before["device_probe_scan_multi"] <= 3


def test_schedule_cache_shared_across_indexes():
    schedule_cache_clear()
    p = 32
    db1, q = _make_data(200, p, 4, seed=1)
    db2, _ = _make_data(300, p, 4, seed=2)
    a = AMIHIndex.build(db1, p, probe_backend="device")
    b = AMIHIndex.build(db2, p, probe_backend="device")
    a.knn_batch(q, 3)
    entries_after_first = schedule_cache_info()[0]
    b.knn_batch(q, 3)  # same (p, m, widths, z) keys: no new entries
    assert schedule_cache_info()[0] == entries_after_first
    widths = tuple(int(w) for w in a.device_csr["widths"])
    sched = get_schedule(p, a.m, widths, int(
        np.bitwise_count(q[0]).sum()), a.probe_stream_cap)
    assert sched.p == p and sched.s_len > 0


def test_csr_rejects_oversized_substrings():
    # one 64-bit table would need a 2^64-slot offsets array
    db, _ = _make_data(100, 64, 1, seed=4)
    idx = AMIHIndex.build(db, 64, m=1)
    with pytest.raises(ValueError, match="substring"):
        build_device_csr(idx)


# ------------------------------------------------------------ entry points
def test_engine_entry_points():
    p, n, B, k = 64, 600, 16, 7
    db, q = _make_data(n, p, B, seed=7)
    ih, sh, _ = make_engine(
        "amih", db, p, m=4, probe_backend="host").knn_batch(q, k)
    id_, sd, _ = make_engine(
        "amih", db, p, m=4, probe_backend="device").knn_batch(q, k)
    np.testing.assert_array_equal(ih, id_)
    np.testing.assert_array_equal(sh, sd)
    # pipelined engine: overlap_verify is a no-op on the device path
    ip, sp, _ = make_engine(
        "amih", db, p, m=4, probe_backend="device", overlap_verify=True,
    ).knn_batch(q, k)
    np.testing.assert_array_equal(ip, id_)
    np.testing.assert_array_equal(sp, sd)


def test_sharded_entry_point_records_backend_and_stands_down():
    p, n, B, k = 64, 600, 16, 7
    db, q = _make_data(n, p, B, seed=13)
    eng_h = make_engine("sharded_amih", db, p, num_shards=3, m=4,
                        probe_backend="host")
    eng_d = make_engine("sharded_amih", db, p, num_shards=3, m=4,
                        probe_backend="device")
    ih, sh, st_h = eng_h.knn_batch(q, k)
    id_, sd, st_d = eng_d.knn_batch(q, k)
    np.testing.assert_array_equal(ih, id_)
    np.testing.assert_array_equal(sh, sd)
    assert all(ps["probe_backend"] == "device" for ps in st_d.per_shard)
    assert all(ps["probe_backend"] == "host" for ps in st_h.per_shard)
    # no host probing loop left: the worker pool never engages
    eng_d.probe_workers = 8
    assert not eng_d._use_parallel(64)


def test_shard_pool_collapses_to_inline_for_device_indexes():
    from repro.pipeline.shardpool import PersistentShardPool, SharedBound

    p, n, B, k = 64, 600, 8, 5
    db, q = _make_data(n, p, B, seed=17)
    eng = make_engine("sharded_amih", db, p, num_shards=3, m=4,
                      probe_backend="device")
    pool = PersistentShardPool(eng.indexes, AMIHStats, max_workers=4,
                               mode="process")
    try:
        assert len(pool.groups) == 1      # stand-down gate: inline path
        out = pool.probe(q, k, SharedBound(B, k))
        assert pool.forks == 0
        assert set(out) == {s for s, _ in eng.indexes}
    finally:
        pool.close()


def test_stats_populated_on_device_path():
    p, n, k = 64, 500, 5
    db, q = _make_data(n, p, 8, seed=19)
    dev = AMIHIndex.build(db, p, probe_backend="device")
    stats = [AMIHStats() for _ in range(q.shape[0])]
    dev.knn_batch(q, k, stats=stats)
    for st in stats:
        assert st.probes > 0
        assert st.verified > 0
        assert st.tuples_processed > 0
