"""HLO parser validation: on scan-free modules, XLA's own cost_analysis is
correct — the structural parser must agree on FLOPs; with scans, the parser
must scale by trip count while cost_analysis does not."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.jax_compat import cost_analysis_dict as _xla_cost_analysis
from repro.roofline import analyze, model_flops, parse_hlo_costs
from repro.roofline.hlo_parse import _parse_op_line, _shape_bytes


# ------------------------------------------------------------- line parser
def test_parse_op_line_simple():
    op = _parse_op_line(
        "  %dot.1 = f32[16,1024,2048]{2,1,0} dot(%a, %b), "
        "lhs_batch_dims={0}, lhs_contracting_dims={2}, rhs_batch_dims={0}, "
        "rhs_contracting_dims={1}"
    )
    assert op.kind == "dot"
    assert op.out_shapes == [("f32", (16, 1024, 2048))]
    assert op.operand_names == ["a", "b"]


def test_parse_op_line_tuple_output():
    op = _parse_op_line(
        "  ROOT %t = (s32[], f32[4,8]{1,0}) tuple(%x, %y)"
    )
    assert op.kind == "tuple"
    assert op.out_shapes == [("s32", ()), ("f32", (4, 8))]


def test_shape_bytes():
    assert _shape_bytes("bf16", (10, 10)) == 200
    assert _shape_bytes("f32", ()) == 4
    assert _shape_bytes("pred", (8,)) == 8


# ----------------------------------------------- agreement with XLA (no scan)
def test_parser_matches_cost_analysis_scanfree():
    def f(a, b, c):
        return jnp.tanh(a @ b) @ c

    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    c = jnp.zeros((512, 64), jnp.float32)
    compiled = jax.jit(f).lower(a, b, c).compile()
    ca = _xla_cost_analysis(compiled)
    costs = parse_hlo_costs(compiled.as_text())
    want_flops = 2 * 128 * 256 * 512 + 2 * 128 * 512 * 64
    assert costs.flops == pytest.approx(want_flops, rel=0.01)
    assert ca["flops"] == pytest.approx(want_flops, rel=0.05)


def test_parser_scales_scan_bodies_by_trip_count():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, None, length=17)
        return h

    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = parse_hlo_costs(compiled.as_text())
    want = 17 * 2 * 64 * 64 * 64
    assert costs.flops == pytest.approx(want, rel=0.01)
    assert 17 in costs.trip_counts
    # XLA's own counter misses the scaling (this is WHY the parser exists)
    ca = _xla_cost_analysis(compiled)
    assert ca["flops"] < want / 2


def test_parser_nested_scans():
    def f(x, w):
        def inner(h, _):
            return jnp.tanh(h @ w), None

        def outer(h, _):
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None

        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    x = jnp.zeros((32, 32), jnp.float32)
    w = jnp.zeros((32, 32), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    costs = parse_hlo_costs(compiled.as_text())
    want = 5 * 3 * 2 * 32 * 32 * 32
    assert costs.flops == pytest.approx(want, rel=0.02)


def test_parser_counts_collectives():
    import subprocess, sys, textwrap

    # collectives need >1 device: subprocess with fake devices
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.roofline import parse_hlo_costs

        mesh = jax.make_mesh((8,), ("d",))
        def f(x):
            return jax.lax.psum(x, "d")
        from repro.jax_compat import shard_map
        fn = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
        x = jax.ShapeDtypeStruct((800, 4), jnp.float32)
        compiled = jax.jit(fn).lower(x).compile()
        costs = parse_hlo_costs(compiled.as_text())
        total = costs.total_collective_bytes
        # per-device operand: (100, 4) f32 = 1600 B
        assert total >= 1600, costs.collective_bytes
        assert "all-reduce" in costs.collective_bytes, costs.collective_bytes
        print("OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


# --------------------------------------------------------------- model flops
def test_model_flops_conventions():
    from repro.configs import get_config
    from repro.models.common import SHAPES

    cfg = get_config("llama3_8b")
    N = cfg.param_count()
    tr = model_flops(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6.0 * N * 256 * 4096)
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    assert pf == pytest.approx(2.0 * N * 32 * 32768)
    dec = model_flops(cfg, SHAPES["decode_32k"])
    assert dec > 2.0 * N * 128  # includes the KV-cache attention term

    moe = get_config("kimi_k2_1t_a32b")
    assert moe.active_param_count() < 0.1 * moe.param_count()
    assert model_flops(moe, SHAPES["train_4k"]) == pytest.approx(
        6.0 * moe.active_param_count() * 256 * 4096
    )


def test_analyze_dominant_term():
    from repro.configs import get_config
    from repro.models.common import SHAPES
    from repro.roofline.hlo_parse import HloCosts

    cfg = get_config("llama3_8b")
    costs = HloCosts(flops=1e12, hbm_bytes=1e13, collective_bytes={"all-reduce": 1e9})
    rep = analyze(
        cfg, SHAPES["train_4k"], "single", 256, "", 1e9, costs=costs
    )
    assert rep.dominant == "memory"
    assert rep.memory_s == pytest.approx(1e13 / 819e9)
    assert rep.compute_s == pytest.approx(1e12 / 197e12)
    assert rep.collective_s == pytest.approx(1e9 / 50e9)
    assert rep.step_s == rep.memory_s
    assert rep.fits
