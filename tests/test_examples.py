"""Examples smoke: every runnable example executes headless end to end
(small DB via REPRO_EXAMPLE_N) and reports its success line. Guards the
docs' quickstart snippets — the examples are what README/docs point
users at first."""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(name: str, n: int, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(_ROOT, "src")
        + (os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    )
    env["REPRO_EXAMPLE_N"] = str(n)
    out = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", name)],
        capture_output=True, text=True, cwd=_ROOT, timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, (
        f"{name} failed\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    )
    return out.stdout


def test_quickstart_runs_headless():
    out = _run_example("quickstart.py", n=3000)
    assert "all queries exact" in out
    assert "sims bit-identical" in out


def test_distributed_search_runs_headless():
    # n divisible by the example's 8 shards; the example pins 8 fake
    # devices itself and checks the sharded merge against linear scan
    out = _run_example("distributed_search.py", n=4096)
    assert "devices: 8" in out
    assert "exact" in out
