"""Bit packing, substring extraction, and bucket enumeration tests."""

import math

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.enumeration import tuple_bucket_values
from repro.core.packing import (
    codes_to_ints,
    extract_substring,
    hamming_tuples,
    ints_to_codes,
    n_words,
    pack_bits,
    popcount,
    substring_spans,
    unpack_bits,
)


@given(
    n=st.integers(1, 20),
    p=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=80, deadline=None)
def test_pack_unpack_roundtrip(n, p, seed):
    rng = np.random.default_rng(seed)
    bits = (rng.random((n, p)) < 0.5).astype(np.uint8)
    words = pack_bits(bits)
    assert words.shape == (n, n_words(p))
    assert np.array_equal(unpack_bits(words, p), bits)
    assert np.array_equal(popcount(words), bits.sum(axis=1))


@given(p=st.integers(1, 128), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=80, deadline=None)
def test_hamming_tuples_match_definition(p, seed):
    rng = np.random.default_rng(seed)
    q = (rng.random(p) < 0.5).astype(np.uint8)
    db = (rng.random((50, p)) < 0.5).astype(np.uint8)
    r10, r01 = hamming_tuples(pack_bits(q), pack_bits(db))
    want10 = ((q[None, :] == 1) & (db == 0)).sum(axis=1)
    want01 = ((q[None, :] == 0) & (db == 1)).sum(axis=1)
    assert np.array_equal(r10, want10)
    assert np.array_equal(r01, want01)


@given(
    p=st.integers(2, 160),
    m=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=80, deadline=None)
def test_extract_substring_matches_bits(p, m, seed):
    m = min(m, p)
    rng = np.random.default_rng(seed)
    bits = (rng.random((8, p)) < 0.5).astype(np.uint8)
    words = pack_bits(bits)
    for lo, hi in substring_spans(p, m):
        if hi - lo > 64:
            continue
        vals = extract_substring(words, lo, hi)
        for row in range(8):
            want = 0
            for j in range(lo, hi):
                want |= int(bits[row, j]) << (j - lo)
            assert int(vals[row]) == want


def test_substring_spans_cover_disjoint():
    spans = substring_spans(70, 3)
    assert spans == [(0, 24), (24, 47), (47, 70)]


def test_codes_to_ints_roundtrip(rng):
    bits = (rng.random((30, 64)) < 0.5).astype(np.uint8)
    words = pack_bits(bits)
    vals = codes_to_ints(words, 64)
    back = ints_to_codes(vals, 64)
    assert np.array_equal(back, words)


@given(
    width=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    a=st.integers(0, 4),
    b=st.integers(0, 4),
)
@settings(max_examples=100, deadline=None)
def test_tuple_bucket_values_exact(width, seed, a, b):
    """Every enumerated bucket lies at exactly tuple (a,b); count = Eq. 4."""
    rng = np.random.default_rng(seed)
    q = int(rng.integers(0, 2**width))
    z = q.bit_count()
    vals = tuple_bucket_values(q, width, z, a, b)
    if not (a <= z and b <= width - z):
        assert vals.size == 0
        return
    assert vals.size == math.comb(z, a) * math.comb(width - z, b)
    for v in vals[: min(len(vals), 50)]:
        v = int(v)
        r10 = (q & ~v).bit_count()
        r01 = (~q & v & ((1 << width) - 1)).bit_count()
        assert (r10, r01) == (a, b)
    assert len(set(vals.tolist())) == vals.size  # no duplicates


def test_enumeration_cap():
    import pytest

    with pytest.raises(ValueError):
        tuple_bucket_values(0b1111111100000000, 16, 8, 4, 4, cap=10)
