"""Property tests for the probing-sequence generator (paper RQ1, Props 1-3)."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.probing import (
    closed_form_prefix,
    first_anchor,
    probing_sequence,
    second_anchor,
)
from repro.core.tuples import all_valid_tuples, rhat, sim_value


@given(p=st.integers(1, 40), data=st.data())
@settings(max_examples=120, deadline=None)
def test_sequence_is_permutation_of_all_valid_tuples(p, data):
    z = data.draw(st.integers(0, p))
    seq = list(probing_sequence(p, z))
    assert sorted(seq) == sorted(all_valid_tuples(p, z))


@given(p=st.integers(1, 40), data=st.data())
@settings(max_examples=120, deadline=None)
def test_sequence_sim_nonincreasing(p, data):
    """Proposition 3: emitted sims never increase."""
    z = data.draw(st.integers(0, p))
    sims = [sim_value(p, z, *t) for t in probing_sequence(p, z)]
    for a, b in zip(sims, sims[1:]):
        assert a >= b - 1e-12


@given(p=st.integers(2, 64), data=st.data())
@settings(max_examples=100, deadline=None)
def test_closed_form_prefix_agrees(p, data):
    """The Prop-2 closed form is a prefix of the general algorithm's order
    (up to exact ties, which both orders break by (radius, r1))."""
    z = data.draw(st.integers(1, p))
    prefix = closed_form_prefix(p, z)
    general = []
    gen = probing_sequence(p, z)
    for _ in range(len(prefix)):
        general.append(next(gen))
    assert prefix == general


def test_limit_caps_output():
    out = list(probing_sequence(32, 12, limit=7))
    assert len(out) == 7
    assert out[0] == (0, 0)


def test_anchors_match_paper_example2():
    # paper Example 2: z=10, p=32, v=(1,4): first anchor (0,6), second (2,3)
    assert first_anchor(32, 10, 1, 4) == (0, 6)
    assert second_anchor(32, 10, 1, 4) == (2, 3)


def test_first_anchor_clamps_to_valid_range():
    # when x+y+1 exceeds p-z, the first anchor shifts ones into r1
    p, z = 8, 6  # p - z = 2
    assert first_anchor(p, z, 0, 2) == (1, 2)  # c = max(0, 3-2) = 1


def test_zero_query_hamming_order():
    # z == 0: cosine undefined; falls back to Hamming (ascending r2)
    seq = list(probing_sequence(6, 0))
    assert seq == [(0, r) for r in range(7)]


@given(p=st.integers(1, 28), data=st.data())
@settings(max_examples=60, deadline=None)
def test_no_duplicates(p, data):
    z = data.draw(st.integers(0, p))
    seq = list(probing_sequence(p, z))
    assert len(seq) == len(set(seq))
