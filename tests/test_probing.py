"""Property tests for the probing-sequence generator (paper RQ1, Props 1-3)."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.probing import (
    closed_form_prefix,
    first_anchor,
    probing_cache_clear,
    probing_cache_info,
    probing_prefix,
    probing_sequence,
    second_anchor,
    shared_probing_iter,
)
from repro.core.tuples import (
    all_valid_tuples,
    rhat,
    sim_squared_fraction,
    sim_value,
)


@given(p=st.integers(1, 40), data=st.data())
@settings(max_examples=120, deadline=None)
def test_sequence_is_permutation_of_all_valid_tuples(p, data):
    z = data.draw(st.integers(0, p))
    seq = list(probing_sequence(p, z))
    assert sorted(seq) == sorted(all_valid_tuples(p, z))


@given(p=st.integers(1, 40), data=st.data())
@settings(max_examples=120, deadline=None)
def test_sequence_sim_nonincreasing(p, data):
    """Proposition 3: emitted sims never increase."""
    z = data.draw(st.integers(0, p))
    sims = [sim_value(p, z, *t) for t in probing_sequence(p, z)]
    for a, b in zip(sims, sims[1:]):
        assert a >= b - 1e-12


@given(p=st.integers(2, 64), data=st.data())
@settings(max_examples=100, deadline=None)
def test_closed_form_prefix_agrees(p, data):
    """The Prop-2 closed form is a prefix of the general algorithm's order
    (up to exact ties, which both orders break by (radius, r1))."""
    z = data.draw(st.integers(1, p))
    prefix = closed_form_prefix(p, z)
    general = []
    gen = probing_sequence(p, z)
    for _ in range(len(prefix)):
        general.append(next(gen))
    assert prefix == general


def test_limit_caps_output():
    out = list(probing_sequence(32, 12, limit=7))
    assert len(out) == 7
    assert out[0] == (0, 0)


def test_anchors_match_paper_example2():
    # paper Example 2: z=10, p=32, v=(1,4): first anchor (0,6), second (2,3)
    assert first_anchor(32, 10, 1, 4) == (0, 6)
    assert second_anchor(32, 10, 1, 4) == (2, 3)


def test_first_anchor_clamps_to_valid_range():
    # when x+y+1 exceeds p-z, the first anchor shifts ones into r1
    p, z = 8, 6  # p - z = 2
    assert first_anchor(p, z, 0, 2) == (1, 2)  # c = max(0, 3-2) = 1


def test_zero_query_hamming_order():
    # z == 0: cosine undefined; falls back to Hamming (ascending r2)
    seq = list(probing_sequence(6, 0))
    assert seq == [(0, r) for r in range(7)]


@given(p=st.integers(1, 28), data=st.data())
@settings(max_examples=60, deadline=None)
def test_no_duplicates(p, data):
    z = data.draw(st.integers(0, p))
    seq = list(probing_sequence(p, z))
    assert len(seq) == len(set(seq))


def _brute_force_eq5_order(p, z):
    """Every valid tuple sorted by the paper's Eq. (5) similarity, in
    exact rational arithmetic, with the generator's deterministic
    tie-break (Hamming distance, then r1). sim >= 0 on the valid domain
    (r1 <= z), so sim^2 sorts identically to sim."""
    return sorted(
        all_valid_tuples(p, z),
        key=lambda t: (
            -sim_squared_fraction(p, z, *t), t[0] + t[1], t[0],
        ),
    )


@given(p=st.integers(1, 40), data=st.data())
@settings(max_examples=100, deadline=None)
def test_sequence_matches_brute_force_eq5_sort(p, data):
    """The incremental anchor-driven walk (heap + Defs. 5a/5b) emits the
    exact order a brute-force Eq. (5) sort of ALL valid tuples gives —
    not just the same multiset of sims."""
    z = data.draw(st.integers(1, p))
    assert list(probing_sequence(p, z)) == _brute_force_eq5_order(p, z)


@given(p=st.integers(2, 48), data=st.data())
@settings(max_examples=60, deadline=None)
def test_closed_form_prefix_matches_brute_force(p, data):
    """Prop. 2's closed form is the head of the brute-force Eq. (5)
    sort — the device schedule builder leans on both."""
    z = data.draw(st.integers(1, p))
    prefix = closed_form_prefix(p, z)
    assert prefix == _brute_force_eq5_order(p, z)[: len(prefix)]


# ----------------------------------------------------------- shared cache
def test_probing_prefix_matches_generator():
    probing_cache_clear()
    p, z = 32, 11
    want = list(probing_sequence(p, z, limit=50))
    got = probing_prefix(p, z, 50)
    assert got[:50] == want
    # a longer ask extends the same entry, never rebuilds it
    longer = probing_prefix(p, z, 200)
    assert longer[:50] == want
    entries, total = probing_cache_info()
    assert entries == 1 and total >= 200


def test_probing_prefix_clamps_to_sequence_length():
    probing_cache_clear()
    p, z = 6, 2
    full = list(probing_sequence(p, z))
    got = probing_prefix(p, z, 10_000)
    assert got == full  # (z+1)(p-z+1) tuples, no padding past the end


def test_shared_probing_iter_replays_and_extends():
    probing_cache_clear()
    p, z = 40, 13
    it1 = shared_probing_iter(p, z)
    head = [next(it1) for _ in range(30)]
    # a second consumer replays the materialized prefix bit-for-bit and
    # keeps extending past it; interleaving the two stays consistent
    it2 = shared_probing_iter(p, z)
    assert [next(it2) for _ in range(30)] == head
    assert [next(it1) for _ in range(20)] == [next(it2) for _ in range(20)]
    assert head + [next(it2) for _ in range(0)] == list(
        probing_sequence(p, z, limit=30)
    )


def test_probing_cache_clear_resets():
    probing_cache_clear()
    probing_prefix(24, 7, 40)
    assert probing_cache_info()[0] == 1
    probing_cache_clear()
    assert probing_cache_info() == (0, 0)
