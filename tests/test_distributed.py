"""Distribution tests (8 fake CPU devices in subprocesses):

- sharded angular scan == single-device scan (the pod-scale search path)
- pjit train step on a 2x4 mesh == single-device train step
- int8-compressed DP train step converges and approximates exact mean
- elastic checkpoint restore across different device counts
"""

import subprocess
import sys
import textwrap

import pytest


def _run(code: str, devices: int = 8) -> str:
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """)
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, cwd="/root/repo", timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_scan_matches_single_device():
    _run("""
        from repro.core.distributed import sharded_scan_topk
        from repro.core import pack_bits, linear_scan_knn
        from repro.launch.mesh import make_mesh

        rng = np.random.default_rng(0)
        p, n, k, B = 64, 4096, 10, 4
        db_bits = (rng.random((n, p)) < 0.5).astype(np.uint8)
        q_bits = (rng.random((B, p)) < 0.5).astype(np.uint8)
        db = jnp.asarray(pack_bits(db_bits))
        q = jnp.asarray(pack_bits(q_bits))

        mesh = make_mesh((4, 2), ("data", "model"))
        sims, ids = sharded_scan_topk(mesh, q, db, k, chunk=256)
        sims, ids = np.asarray(sims), np.asarray(ids)
        for b in range(B):
            ids_l, sims_l = linear_scan_knn(pack_bits(q_bits[b]), pack_bits(db_bits), k)
            np.testing.assert_allclose(np.sort(sims[b])[::-1], sims_l, atol=1e-6)
        print("OK")
    """)


def test_pjit_train_step_matches_single_device():
    _run("""
        from repro.configs import get_tiny
        from repro.optim import OptimConfig
        from repro.train.step import make_train_step, TrainConfig
        from repro.launch.mesh import make_mesh
        from repro.data import DataConfig, TokenPipeline

        cfg = get_tiny("llama3_8b").replace(compute_dtype="float32")
        ocfg = OptimConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=20)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
        batch = {k: jnp.asarray(v) for k, v in
                 TokenPipeline(dcfg).global_batch_at(0).items()}

        # single device
        b1 = make_train_step(cfg, ocfg, TrainConfig())
        p1, s1 = b1["init"](jax.random.key(0))
        p1n, s1n, m1 = b1["step"](p1, s1, batch)

        # 2x4 mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        b2 = make_train_step(cfg, ocfg, TrainConfig(), mesh=mesh, log=[])
        p2, s2 = b2["init"](jax.random.key(0))
        p2 = jax.device_put(p2, b2["in_shardings"][0])
        s2 = jax.device_put(s2, b2["in_shardings"][1])
        p2n, s2n, m2 = b2["step"](p2, s2, batch)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1, m2)
        la, lb = jax.tree.leaves(p1n), jax.tree.leaves(p2n)
        for a, b in zip(la, lb):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-5, rtol=1e-4)
        print("OK")
    """)


def test_compressed_dp_step_tracks_exact():
    _run("""
        from repro.configs import get_tiny
        from repro.optim import OptimConfig, zeros_like_residuals
        from repro.train.step import (make_train_step, TrainConfig,
                                      make_dp_compressed_train_step)
        from repro.launch.mesh import make_mesh
        from repro.data import DataConfig, TokenPipeline

        cfg = get_tiny("llama3_8b").replace(compute_dtype="float32")
        ocfg = OptimConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
        pipe = TokenPipeline(dcfg)

        b_exact = make_train_step(cfg, ocfg, TrainConfig())
        pe, se = b_exact["init"](jax.random.key(0))

        mesh = make_mesh((8,), ("data",))
        step_c = make_dp_compressed_train_step(cfg, ocfg, mesh)
        pc, sc = b_exact["init"](jax.random.key(0))
        res = zeros_like_residuals(pc)

        losses_e, losses_c = [], []
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in pipe.global_batch_at(i).items()}
            pe, se, me = b_exact["step"](pe, se, batch)
            pc, sc, res, mc = step_c(pc, sc, res, batch)
            losses_e.append(float(me["loss"]))
            losses_c.append(float(mc["loss"]))
        # compressed training must track exact within a small margin
        assert losses_c[-1] < losses_c[0], losses_c
        assert abs(losses_c[-1] - losses_e[-1]) < 0.05 * losses_e[-1], (
            losses_e[-1], losses_c[-1])
        print("OK")
    """)


def test_elastic_checkpoint_restore_across_device_counts():
    # save on 8 devices...
    _run("""
        import tempfile
        from repro.configs import get_tiny
        from repro.optim import OptimConfig
        from repro.train.step import make_train_step, TrainConfig
        from repro.launch.mesh import make_mesh
        from repro.checkpoint import save

        cfg = get_tiny("llama3_8b").replace(compute_dtype="float32")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        b = make_train_step(cfg, OptimConfig(), TrainConfig(), mesh=mesh)
        p, s = b["init"](jax.random.key(0))
        p = jax.device_put(p, b["in_shardings"][0])
        save("/tmp/elastic_ckpt", 5, {"params": p})
        print("OK")
    """, devices=8)
    # ...restore on 2 devices with a different mesh, run a step
    _run("""
        from repro.configs import get_tiny
        from repro.optim import OptimConfig, init_state
        from repro.train.step import make_train_step, TrainConfig
        from repro.checkpoint import restore
        from repro.data import DataConfig, TokenPipeline

        cfg = get_tiny("llama3_8b").replace(compute_dtype="float32")
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        b = make_train_step(cfg, OptimConfig(), TrainConfig(), mesh=mesh)
        tree, _ = restore("/tmp/elastic_ckpt", {"params": b["param_specs"]})
        params = jax.device_put(tree["params"], b["in_shardings"][0])
        opt = jax.device_put(init_state(OptimConfig(), params),
                             b["in_shardings"][1])
        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
        batch = {k: jnp.asarray(v) for k, v in
                 TokenPipeline(dcfg).global_batch_at(0).items()}
        p2, o2, m = b["step"](params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK")
    """, devices=2)


def test_dryrun_entrypoint_one_cell():
    """The assignment's dry-run command path works end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "gemma_2b", "--shape", "decode_32k", "--mesh", "multi",
         "--out", "/tmp/dryrun_pytest"],
        capture_output=True, text=True, cwd="/root/repo",
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        timeout=560,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert '"status": "ok"' in out.stdout
