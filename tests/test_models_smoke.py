"""Per-architecture smoke tests (assignment-mandated): a REDUCED config of
the same family runs one forward/train/decode step on CPU with shape +
finiteness asserts. Full configs are touched only by the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_tiny
from repro.models import Model, SHAPES, input_specs, shape_applicable

ASSIGNED = {
    # name -> (layers, d_model, heads, kv, d_ff, vocab)
    "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
    "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
    "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
    "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
    "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
    "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
    "granite_34b": (88, 6144, 48, 1, 24576, 49152),
    "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
    "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
    "mamba2_1_3b": (48, 2048, 1, 1, 0, 50280),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v


def test_extra_config_fields():
    assert get_config("kimi_k2_1t_a32b").n_experts == 384
    assert get_config("kimi_k2_1t_a32b").experts_per_token == 8
    assert get_config("arctic_480b").n_experts == 128
    assert get_config("arctic_480b").experts_per_token == 2
    assert get_config("arctic_480b").moe_dense_residual_ff > 0
    assert get_config("hymba_1_5b").ssm_state == 16
    assert get_config("mamba2_1_3b").ssm_state == 128
    assert get_config("whisper_tiny").n_encoder_layers == 4
    assert get_config("gemma_2b").head_dim == 256


def _batch_for(cfg, B, S, rng):
    batch = {
        "tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32
        )
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = 0.01 * jnp.ones(
            (B, cfg.vision_tokens, cfg.d_model), cfg.cdtype()
        )
    if cfg.family == "encdec":
        batch["enc_frames"] = 0.01 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), cfg.cdtype()
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_tiny(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch_for(cfg, 2, 32, rng)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # one SGD step must change the loss (gradients flow end to end)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch, rng):
    """prefill + one decode step == teacher-forced forward (f32, no drops)."""
    cfg = get_tiny(arch).replace(compute_dtype="float32")
    if cfg.is_moe:  # capacity-induced drops differ by token count
        cfg = cfg.replace(
            capacity_factor=float(cfg.n_experts) / cfg.experts_per_token
        )
    model = Model(cfg)
    params = model.init_params(jax.random.key(1))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S + 1)), jnp.int32)
    batch = _batch_for(cfg, B, S, rng)
    batch["tokens"] = toks[:, :S]
    full_batch = dict(batch, tokens=toks)
    logits_full, _ = model.forward(params, full_batch)
    logits_pf, cache = model.prefill(params, batch)
    extra = cfg.vision_tokens if cfg.family == "vlm" else 0
    tmpl = model.init_cache(B, S + extra + 8)
    cache = jax.tree.map(
        lambda c, t: jnp.pad(
            c, [(0, ts - cs) for cs, ts in zip(c.shape, t.shape)]
        ),
        cache,
        tmpl,
    )
    logits_dec, _ = model.decode_step(
        params, cache, toks[:, S : S + 1], jnp.int32(S + extra)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, S]),
        atol=2e-4, rtol=1e-4,
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_match_init(arch):
    cfg = get_tiny(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    specs = model.param_specs()
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert p.shape == s.shape and p.dtype == s.dtype


def test_shape_applicability_rules():
    long = SHAPES["long_500k"]
    runnable = [a for a in ARCH_IDS if shape_applicable(get_config(a), long)[0]]
    assert sorted(runnable) == ["hymba_1_5b", "mamba2_1_3b"]
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for sname, shape in SHAPES.items():
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)


def test_moe_dropping_and_aux(rng):
    from repro.models.moe import moe_block
    import jax.numpy as jnp

    T, D, E, k = 64, 16, 8, 2
    key = jax.random.key(0)
    params = {
        "router": jax.random.normal(jax.random.key(1), (D, E)),
        "w_up": jax.random.normal(jax.random.key(2), (E, D, 32)) * 0.1,
        "w_gate": jax.random.normal(jax.random.key(3), (E, D, 32)) * 0.1,
        "w_down": jax.random.normal(jax.random.key(4), (E, 32, D)) * 0.1,
    }
    x = jax.random.normal(key, (T, D))
    out, aux = moe_block(
        x, params, top_k=k, capacity_factor=1.0, activation="swiglu"
    )
    assert out.shape == x.shape
    assert 0.0 <= float(aux["dropped_fraction"]) <= 1.0
    assert float(aux["load_balance_loss"]) >= 1.0 - 1e-3  # >= 1 at optimum
