"""Shim over ``hypothesis``: real library when installed, else a small
deterministic fallback so the suite collects and runs without the optional
dependency.

The fallback implements exactly the API surface this repo's tests use:

  - ``@given(**kwargs)`` with keyword strategies
  - ``@settings(max_examples=..., deadline=...)`` (stacked under ``given``)
  - ``st.integers(lo, hi)``, ``st.sampled_from(seq)``, ``st.data()`` with
    ``data.draw(strategy)``

Draws are deterministic per (test name, example index), so failures are
reproducible; the drawn values are attached to the assertion message.
``REPRO_MAX_EXAMPLES`` caps example counts for quick local runs.

Install the real thing with the ``test`` extra (see pyproject.toml) to get
shrinking and the full strategy library.
"""

from __future__ import annotations

import os
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    class _Strategy:
        def example(self, rng):  # pragma: no cover - interface
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return self.options[int(rng.integers(0, len(self.options)))]

    class _DataStrategy(_Strategy):
        def example(self, rng):
            return _DataObject(rng)

    class _DataObject:
        """Interactive draws inside the test body (st.data())."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.example(self._rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _StrategiesModule()

    def settings(max_examples: int = 100, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            inner = fn
            max_examples = getattr(inner, "_compat_max_examples", 100)
            cap = os.environ.get("REPRO_MAX_EXAMPLES")
            if cap:
                max_examples = min(max_examples, int(cap))
            base_seed = zlib.crc32(
                getattr(inner, "__qualname__", inner.__name__).encode()
            )

            def wrapper(*args, **kwargs):
                for i in range(max_examples):
                    rng = np.random.default_rng([base_seed, i])
                    drawn = {
                        name: strat.example(rng)
                        for name, strat in strategies.items()
                    }
                    try:
                        inner(*args, **kwargs, **drawn)
                    except Exception as e:  # annotate the failing example
                        shown = {
                            k: v
                            for k, v in drawn.items()
                            if not isinstance(v, _DataObject)
                        }
                        raise AssertionError(
                            f"falsifying example #{i}: {shown}"
                        ) from e

            wrapper.__name__ = inner.__name__
            wrapper.__qualname__ = getattr(
                inner, "__qualname__", inner.__name__
            )
            wrapper.__doc__ = inner.__doc__
            wrapper.__module__ = inner.__module__
            return wrapper

        return deco
