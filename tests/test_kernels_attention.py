"""Flash-attention Pallas kernel vs the pure-JAX online-softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import _blocked_attention_impl


def _mk(rng, B, Sq, Sk, Hq, Hkv, D, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,D",
    [
        (1, 128, 128, 4, 4, 32),     # MHA square
        (2, 128, 256, 8, 2, 64),     # GQA, kv longer
        (1, 100, 100, 4, 1, 32),     # MQA, non-multiple seq (padding)
        (2, 64, 192, 6, 3, 16),      # odd head count
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(rng, B, Sq, Sk, Hq, Hkv, D, causal):
    q, k, v = _mk(rng, B, Sq, Sk, Hq, Hkv, D)
    got = flash_attention(
        q, k, v, causal=causal, q_blk=64, kv_blk=64, interpret=True
    )
    want = _blocked_attention_impl(
        q, k, v, causal=causal, q_chunk=32, kv_chunk=32
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(rng, window):
    B, S, H, D = 1, 160, 4, 32
    q, k, v = _mk(rng, B, S, S, H, H, D)
    got = flash_attention(
        q, k, v, causal=True, window=window, q_blk=64, kv_blk=64,
        interpret=True,
    )
    want = _blocked_attention_impl(
        q, k, v, causal=True, window=window, q_chunk=32, kv_chunk=32
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_flash_bf16_io(rng):
    B, S, H, D = 1, 128, 4, 32
    q, k, v = _mk(rng, B, S, S, H, H, D, dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, q_blk=64, kv_blk=64,
                          interpret=True)
    assert got.dtype == jnp.bfloat16
    want = _blocked_attention_impl(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        causal=True, q_chunk=64, kv_chunk=64,
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=3e-2
    )


@pytest.mark.parametrize("cache_len", [1, 37, 100, 160])
@pytest.mark.parametrize("window", [0, 24])
def test_flash_decode_matches_oracle(rng, cache_len, window):
    """Flash-DECODE: dynamic valid_len + window over a partially-filled
    KV cache must match the pure-JAX decode oracle."""
    from repro.models.layers import _decode_attention_impl, decode_attention

    B, S, Hq, Hkv, D = 2, 160, 8, 2, 32
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    got = decode_attention(
        q, k, v, jnp.int32(cache_len), window=window, use_kernel=True
    )
    want = _decode_attention_impl(
        q, k, v, jnp.int32(cache_len), window=window
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5
    )


def test_flash_block_shape_sweep(rng):
    """Block sizes must never change the result (pure tiling)."""
    B, S, Hq, Hkv, D = 1, 192, 4, 2, 32
    q, k, v = _mk(rng, B, S, S, Hq, Hkv, D)
    ref = flash_attention(q, k, v, causal=True, q_blk=192, kv_blk=192,
                          interpret=True)
    for q_blk, kv_blk in [(32, 64), (64, 32), (96, 192), (192, 48)]:
        got = flash_attention(q, k, v, causal=True, q_blk=q_blk,
                              kv_blk=kv_blk, interpret=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5
        )
