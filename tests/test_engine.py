"""The unified SearchEngine contract: every backend's ``knn_batch`` is
exact — bit-identical sims to per-query ``linear_scan_knn`` — across batch
sizes, code lengths, degenerate queries, and the fell-back-to-scan path;
stats objects aggregate per query."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    AMIHIndex,
    AMIHStats,
    EngineStats,
    available_backends,
    linear_scan_knn,
    make_engine,
    pack_bits,
)
from repro.core.linear_scan import sims_against_db
from repro.data import synthetic_binary_codes, synthetic_queries


def _backends_for(p):
    # "cluster" registers process-globally once any test imports
    # repro.cluster; it spawns a worker fleet per engine, which is the
    # wrong granularity for a per-example sweep — its exactness sweep
    # (incl. this module's invariants) lives in tests/test_cluster.py
    return [
        b for b in available_backends()
        if b != "cluster" and (b != "single_table" or p <= 64)
    ]


def _check_batch_exact(ids, sims, qs, db, k_eff):
    """Exactness up to ties: sims rows bit-identical to linear scan, and
    every returned id carries its true sim."""
    B = qs.shape[0]
    assert ids.shape == (B, k_eff) and sims.shape == (B, k_eff)
    for i in range(B):
        _, sims_l = linear_scan_knn(qs[i], db, k_eff)
        np.testing.assert_array_equal(sims[i], sims_l)
        all_sims = sims_against_db(qs[i], db)
        np.testing.assert_array_equal(all_sims[ids[i]], sims[i])


def test_registry_and_unknown_backend():
    assert {"amih", "linear_scan", "single_table"} <= set(available_backends())
    db = pack_bits(np.zeros((4, 16), np.uint8))
    with pytest.raises(ValueError, match="unknown search backend"):
        make_engine("nope", db, 16)


@given(
    p=st.sampled_from([32, 64, 128]),
    B=st.sampled_from([1, 8, 64]),
    n=st.integers(20, 300),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_knn_batch_exact_all_backends(p, B, n, k, seed):
    db_bits = synthetic_binary_codes(n, p, seed=seed)
    q_bits = synthetic_queries(db_bits, B, seed=seed + 1)
    db, qs = pack_bits(db_bits), pack_bits(q_bits)
    k_eff = min(k, n)
    for backend in _backends_for(p):
        eng = make_engine(backend, db, p)
        ids, sims, stats = eng.knn_batch(qs, k)
        _check_batch_exact(ids, sims, qs, db, k_eff)
        assert isinstance(stats, EngineStats)
        assert stats.backend == backend and stats.queries == B
        assert len(stats.per_query) == B


def test_linear_scan_backend_bit_identical_ids():
    p, n, B, k = 64, 250, 16, 9
    db_bits = synthetic_binary_codes(n, p, seed=5)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=6))
    db = pack_bits(db_bits)
    eng = make_engine("linear_scan", db, p)
    ids, sims, _ = eng.knn_batch(qs, k)
    for i in range(B):
        ids_l, sims_l = linear_scan_knn(qs[i], db, k)
        np.testing.assert_array_equal(ids[i], ids_l)
        np.testing.assert_array_equal(sims[i], sims_l)


def test_zero_norm_queries_in_batch():
    p, n = 64, 120
    db_bits = synthetic_binary_codes(n, p, seed=7)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=8))
    qs[1] = 0  # zero-norm query amid normal ones
    db = pack_bits(db_bits)
    for backend in _backends_for(p):
        eng = make_engine(backend, db, p)
        ids, sims, _ = eng.knn_batch(qs, 5)
        _check_batch_exact(ids, sims, qs, db, 5)
        assert np.all(sims[1] == 0.0)


def test_single_query_1d_promotes_to_batch():
    p, n = 32, 60
    db_bits = synthetic_binary_codes(n, p, seed=9)
    q = pack_bits(synthetic_queries(db_bits, 1, seed=10)[0])
    db = pack_bits(db_bits)
    for backend in _backends_for(p):
        ids, sims, stats = make_engine(backend, db, p).knn_batch(q, 3)
        assert ids.shape == (1, 3) and stats.queries == 1


def test_k_larger_than_n_clamps():
    p, n = 32, 15
    db_bits = synthetic_binary_codes(n, p, seed=11)
    qs = pack_bits(synthetic_queries(db_bits, 3, seed=12))
    db = pack_bits(db_bits)
    for backend in _backends_for(p):
        ids, sims, _ = make_engine(backend, db, p).knn_batch(qs, 99)
        assert ids.shape == (3, n)
        _check_batch_exact(ids, sims, qs, db, n)


def test_amih_fell_back_to_scan_path_is_exact():
    # m=1 on wide sparse codes forces huge per-table enumerations; a tiny
    # cap triggers the degrade-to-verification guard. Still exact.
    p, n = 64, 80
    rng = np.random.default_rng(13)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    qs = pack_bits((rng.random((4, p)) < 0.5).astype(np.uint8))
    eng = make_engine("amih", db, p, m=1, enumeration_cap=10)
    ids, sims, stats = eng.knn_batch(qs, 10)
    _check_batch_exact(ids, sims, qs, db, 10)
    assert stats.total("fell_back_to_scan") == 4
    assert all(s.fell_back_to_scan for s in stats.per_query)


def test_single_table_fell_back_to_scan_path_is_exact():
    # Sparse occupancy at p=64: bucket enumeration blows past the cap and
    # the engine degrades the query to an exact linear scan.
    p, n = 64, 100
    rng = np.random.default_rng(14)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    qs = pack_bits((rng.random((3, p)) < 0.5).astype(np.uint8))
    eng = make_engine("single_table", db, p)
    ids, sims, stats = eng.knn_batch(qs, 8)
    _check_batch_exact(ids, sims, qs, db, 8)
    assert stats.total("fell_back_to_scan") >= 1


def test_amih_pallas_verification_matches_numpy():
    p, n, B, k = 96, 150, 6, 7
    db_bits = synthetic_binary_codes(n, p, seed=15)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=16))
    db = pack_bits(db_bits)
    eng_np = make_engine("amih", db, p, verify_backend="numpy")
    eng_pl = make_engine("amih", db, p, verify_backend="pallas")
    ids_n, sims_n, st_n = eng_np.knn_batch(qs, k)
    ids_p, sims_p, st_p = eng_pl.knn_batch(qs, k)
    np.testing.assert_array_equal(ids_n, ids_p)
    np.testing.assert_array_equal(sims_n, sims_p)
    # identical probing work either way — only the verifier differs
    assert st_n.aggregate() == st_p.aggregate()


def test_amih_stats_aggregate_per_query():
    p, n, B = 64, 400, 12
    db_bits = synthetic_binary_codes(n, p, seed=17)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=18))
    db = pack_bits(db_bits)
    eng = make_engine("amih", db, p)
    _, _, stats = eng.knn_batch(qs, 10)
    assert all(isinstance(s, AMIHStats) for s in stats.per_query)
    agg = stats.aggregate()
    for counter in ("probes", "retrieved", "verified", "tuples_processed"):
        assert agg[counter] == sum(
            getattr(s, counter) for s in stats.per_query
        )
    assert agg["probes"] > 0 and agg["verified"] > 0
    # batched counters match the per-query algorithm exactly
    for i in range(B):
        st = AMIHStats()
        eng.index.knn(qs[i], 10, stats=st)
        assert st == stats.per_query[i]


def test_batch_matches_per_query_amih_ids():
    p, n, B, k = 128, 350, 24, 6
    db_bits = synthetic_binary_codes(n, p, seed=19)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=20))
    db = pack_bits(db_bits)
    idx = AMIHIndex.build(db, p)
    ids_b, sims_b = idx.knn_batch(qs, k)
    for i in range(B):
        ids_1, sims_1 = idx.knn(qs[i], k)
        np.testing.assert_array_equal(ids_b[i], ids_1)
        np.testing.assert_array_equal(sims_b[i], sims_1)


def test_bad_query_shape_raises():
    p = 64
    db = pack_bits(np.zeros((10, p), np.uint8))
    eng = make_engine("amih", db, p)
    with pytest.raises(ValueError, match="packed words"):
        eng.knn_batch(np.zeros((4, 7), np.uint32), 3)


@given(
    p=st.sampled_from([32, 64, 128]),
    B=st.sampled_from([1, 8, 64]),
    n=st.integers(20, 300),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_linear_scan_pallas_compute_backend_exact(p, B, n, k, seed):
    """compute_backend="pallas" (device scan_topk preselect + float64 host
    rerank) stays bit-identical to linear_scan_knn, up to in-tuple ties."""
    db_bits = synthetic_binary_codes(n, p, seed=seed)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=seed + 1))
    db = pack_bits(db_bits)
    eng = make_engine("linear_scan", db, p, compute_backend="pallas")
    ids, sims, stats = eng.knn_batch(qs, k)
    _check_batch_exact(ids, sims, qs, db, min(k, n))
    assert stats.backend == "linear_scan" and stats.queries == B
    # ids within a row must be unique (no candidate fetched twice)
    for i in range(B):
        assert len(set(ids[i].tolist())) == ids.shape[1]


def test_linear_scan_unknown_compute_backend_raises():
    db = pack_bits(np.zeros((4, 32), np.uint8))
    with pytest.raises(ValueError, match="compute_backend"):
        make_engine("linear_scan", db, 32, compute_backend="cuda")


def test_linear_scan_pallas_uploads_db_once():
    p, n = 64, 150
    db_bits = synthetic_binary_codes(n, p, seed=30)
    qs = pack_bits(synthetic_queries(db_bits, 6, seed=31))
    db = pack_bits(db_bits)
    eng = make_engine("linear_scan", db, p, compute_backend="pallas")
    assert eng._db_dev is None  # lazy: upload on first query
    eng.knn_batch(qs, 4)
    dev0 = eng._db_dev
    assert dev0 is not None
    eng.knn_batch(qs, 7)
    assert eng._db_dev is dev0


def test_amih_query_cache_hits_and_exactness():
    """Repeated query codes are served from the engine's LRU without
    probing; results and per-query counters are identical to a cold run."""
    p, n, B, k = 64, 400, 8, 10
    db_bits = synthetic_binary_codes(n, p, seed=40)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=41))
    db = pack_bits(db_bits)
    eng = make_engine("amih", db, p)
    ids1, sims1, st1 = eng.knn_batch(qs, k)
    assert st1.cache_hits == 0 and eng.cache_hits == 0
    ids2, sims2, st2 = eng.knn_batch(qs, k)
    assert st2.cache_hits == B and eng.cache_hits == B
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(sims1, sims2)
    # replayed stats equal the computed ones, per query
    assert [s for s in st1.per_query] == [s for s in st2.per_query]
    # a different k is a different cache entry (misses once, then hits)
    _, _, st3 = eng.knn_batch(qs, k + 1)
    assert st3.cache_hits == 0
    _, _, st4 = eng.knn_batch(qs, k + 1)
    assert st4.cache_hits == B


def test_amih_query_cache_dedups_within_batch():
    p, n = 64, 200
    db_bits = synthetic_binary_codes(n, p, seed=42)
    qs = pack_bits(synthetic_queries(db_bits, 2, seed=43))
    batch = np.concatenate([qs, qs[0:1], qs[1:2]])   # rows 2,3 duplicate 0,1
    db = pack_bits(db_bits)
    eng = make_engine("amih", db, p)
    ids, sims, stats = eng.knn_batch(batch, 5)
    np.testing.assert_array_equal(ids[2], ids[0])
    np.testing.assert_array_equal(sims[3], sims[1])
    assert stats.per_query[2] == stats.per_query[0]
    # results identical to an uncached engine
    eng0 = make_engine("amih", db, p, query_cache_size=0)
    ids0, sims0, st0 = eng0.knn_batch(batch, 5)
    np.testing.assert_array_equal(ids, ids0)
    np.testing.assert_array_equal(sims, sims0)
    assert eng0.cache_hits == 0
    _, _, st0b = eng0.knn_batch(batch, 5)
    assert st0b.cache_hits == 0                     # disabled stays cold


def test_amih_query_cache_lru_bound():
    p, n = 64, 150
    db_bits = synthetic_binary_codes(n, p, seed=44)
    qs = pack_bits(synthetic_queries(db_bits, 6, seed=45))
    db = pack_bits(db_bits)
    eng = make_engine("amih", db, p, query_cache_size=4)
    eng.knn_batch(qs, 3)                            # 6 misses -> 2 evicted
    assert len(eng._query_cache) == 4
    _, _, stats = eng.knn_batch(qs, 3)
    # the two oldest rows were evicted, the four newest hit
    assert stats.cache_hits == 4


def test_amih_enumeration_cap_default_scales_with_n():
    """AMIH's default cap matches SingleTableEngine's max(8n, 16384)
    instead of a hardcoded constant."""
    p = 64
    for n in (10, 3000, 50_000):
        db = pack_bits(np.zeros((n, p), np.uint8))
        amih = make_engine("amih", db, p)
        single = make_engine("single_table", db, p)
        assert amih.enumeration_cap == max(8 * n, 1 << 14)
        assert amih.enumeration_cap == single.enumeration_cap
    # explicit values still win
    db = pack_bits(np.zeros((100, p), np.uint8))
    assert make_engine("amih", db, p, enumeration_cap=7).enumeration_cap == 7
