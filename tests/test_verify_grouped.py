"""The batched verification pipeline (one launch per z-group and tuple
step): grouped-Pallas keys == per-query NumPy tuples across ragged
candidate blocks straddling the power-of-two padding buckets, the jit
cache stays bounded under varied shapes, and AMIH's launch counters match
the one-launch-per-(z-group, tuple-step) contract."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import AMIHIndex, make_engine, pack_bits
from repro.core.packing import hamming_tuples
from repro.data import synthetic_binary_codes, synthetic_queries
from repro.kernels import ops
from repro.obs.metrics import REGISTRY as _REG


def _random_workload(rng, B, C, p, n=64):
    db = pack_bits((rng.random((n, p)) < 0.4).astype(np.uint8))
    qs = pack_bits((rng.random((B, p)) < 0.4).astype(np.uint8))
    idx = rng.integers(0, n, size=(B, C)).astype(np.int32)
    lengths = rng.integers(0, C + 1, size=B).astype(np.int32)
    lengths[rng.integers(0, B)] = C  # at least one full row
    return db, qs, idx, lengths


# C values straddling every padding-bucket edge the op can hit at test
# sizes: below the minimum bucket (8), and around 8/16/32/64/128 (the
# default kernel block), plus a >1-block shape.
_C_EDGES = [1, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129]


@given(
    B=st.sampled_from([1, 8, 64]),
    ci=st.integers(0, len(_C_EDGES) - 1),
    p=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=24, deadline=None)
def test_grouped_pallas_matches_per_query_numpy(B, ci, p, seed):
    """keys[b, c] == r10 * (p+1) + r01 from host popcounts for c <
    lengths[b]; -1 (masked padding) beyond — for every ragged shape."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    C = _C_EDGES[ci]
    db, qs, idx, lengths = _random_workload(rng, B, C, p)
    keys = ops.verify_tuples_grouped_op(
        qs, jnp.asarray(db), idx, lengths, p=p, use_pallas=True
    )
    assert keys.shape == (B, C) and keys.dtype == np.int32
    for b in range(B):
        length = int(lengths[b])
        r10, r01 = hamming_tuples(qs[b], db[idx[b, :length]])
        np.testing.assert_array_equal(
            keys[b, :length], r10 * (p + 1) + r01
        )
        assert np.all(keys[b, length:] == -1)


def test_grouped_ref_path_matches_pallas():
    import jax.numpy as jnp

    rng = np.random.default_rng(3)
    db, qs, idx, lengths = _random_workload(rng, 8, 33, 96)
    k_pl = ops.verify_tuples_grouped_op(
        qs, jnp.asarray(db), idx, lengths, p=96, use_pallas=True
    )
    k_ref = ops.verify_tuples_grouped_op(
        qs, jnp.asarray(db), idx, lengths, p=96, use_pallas=False
    )
    np.testing.assert_array_equal(k_pl, k_ref)


def test_empty_candidate_matrix():
    import jax.numpy as jnp

    db = pack_bits(np.zeros((4, 32), np.uint8))
    keys = ops.verify_tuples_grouped_op(
        pack_bits(np.zeros((3, 32), np.uint8)),
        jnp.asarray(db),
        np.zeros((3, 0), np.int32),
        np.zeros(3, np.int32),
        p=32,
    )
    assert keys.shape == (3, 0)


def test_jit_cache_stays_bounded_across_varied_shapes():
    """100 calls with 100 distinct ragged (B, C) shapes must coalesce
    into the power-of-two padding buckets: the kernel trace count grows
    by at most log2-many entries, not one per shape."""
    import jax.numpy as jnp

    # the package re-exports the kernel *function* under this name (which
    # shadows the submodule attribute), so resolve the module itself for
    # its trace counters
    import importlib

    vt = importlib.import_module("repro.kernels.verify_tuples")

    rng = np.random.default_rng(11)
    p = 64
    db = pack_bits((rng.random((256, p)) < 0.5).astype(np.uint8))
    db_dev = jnp.asarray(db)
    before = vt.TRACE_COUNTS["verify_tuples_grouped"]
    shapes = [(1 + (i % 13), 1 + 2 * i) for i in range(100)]
    assert len(set(shapes)) == 100
    for B, C in shapes:
        qs = pack_bits((rng.random((B, p)) < 0.5).astype(np.uint8))
        idx = rng.integers(0, 256, size=(B, C)).astype(np.int32)
        lengths = np.full(B, C, np.int32)
        ops.verify_tuples_grouped_op(
            qs, db_dev, idx, lengths, p=p, use_pallas=True
        )
    traces = vt.TRACE_COUNTS["verify_tuples_grouped"] - before
    # B buckets {1,2,4,8,16} x C buckets {8,16,32,64,128,256} at most
    assert traces <= 30, traces


def test_amih_one_launch_per_z_group_and_tuple_step():
    """The launch counter contract: batched AMIH verification dispatches
    once per (z-group, tuple-step) with fresh candidates — identical
    launch counts for the numpy and pallas backends, both ≤ what
    query-at-a-time probing would have issued."""
    p, n, B, k = 64, 300, 16, 8
    db_bits = synthetic_binary_codes(n, p, seed=21)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=22))
    db = pack_bits(db_bits)

    eng_np = make_engine("amih", db, p, verify_backend="numpy")
    eng_pl = make_engine("amih", db, p, verify_backend="pallas")
    before = _REG.value("launches.verify_grouped")
    ids_n, sims_n, _ = eng_np.knn_batch(qs, k)
    ids_p, sims_p, _ = eng_pl.knn_batch(qs, k)
    np.testing.assert_array_equal(sims_n, sims_p)

    # device dispatches == the index's own accounting
    assert (
        _REG.value("launches.verify_grouped") - before
        == eng_pl.index.verify_launches
    )
    # grouped == grouped, whatever the backend
    assert eng_pl.index.verify_launches == eng_np.index.verify_launches

    # per-query probing would launch once per (query, step): the grouped
    # batch must not exceed it, and with shared-z queries it must win
    per_query = 0
    for i in range(B):
        idx1 = AMIHIndex.build(db, p, verify_backend="numpy")
        idx1.knn(qs[i], k)
        per_query += idx1.verify_launches
    assert eng_pl.index.verify_launches <= per_query
    zs = {int(z) for z in np.bitwise_count(qs).sum(axis=1)}
    if len(zs) < B:  # at least one shared z-group
        assert eng_pl.index.verify_launches < per_query


def test_amih_device_residency_uploaded_once():
    p, n = 64, 200
    db_bits = synthetic_binary_codes(n, p, seed=23)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=24))
    db = pack_bits(db_bits)
    idx = AMIHIndex.build(db, p, verify_backend="pallas")
    dev0 = idx._db_dev
    assert dev0 is not None  # uploaded eagerly at build
    idx.knn_batch(qs, 5)
    idx.knn_batch(qs, 3)
    assert idx.db_dev is dev0  # never re-shipped


def test_oversized_step_chunks_instead_of_exploding():
    """A fell-back-to-scan z-group (every block is the whole DB) must
    split across launches once the padded gather exceeds the element
    budget — same results, more dispatches, bounded peak memory."""
    from repro.core import linear_scan_knn

    p, n, B = 64, 512, 4
    rng = np.random.default_rng(25)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    qbits = (rng.random(p) < 0.5).astype(np.uint8)
    # same popcount for every query -> one z-group
    qs = pack_bits(np.stack([rng.permutation(qbits) for _ in range(B)]))

    results = []
    launches = []
    for budget in (1 << 24, 256):
        eng = make_engine("amih", db, p, m=1, enumeration_cap=10,
                          verify_backend="pallas")
        eng.index.verify_elem_budget = budget
        ids, sims, stats = eng.knn_batch(qs, 6)
        assert stats.total("fell_back_to_scan") == B
        results.append(sims)
        launches.append(eng.index.verify_launches)
    np.testing.assert_array_equal(results[0], results[1])
    assert launches[1] > launches[0]  # chunked into more dispatches
    for i in range(B):
        _, sims_l = linear_scan_knn(qs[i], db, 6)
        np.testing.assert_array_equal(results[0][i], sims_l)
