import numpy as np
import pytest

# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see the real single CPU device. Multi-device tests
# spawn subprocesses that set the flag before importing jax (see
# tests/test_distributed.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
