"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracle
(interpret mode on CPU; the same pallas_call lowers natively on TPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.linear_scan import sims_against_db
from repro.core.packing import pack_bits
from repro.kernels import ops, ref


def _random_codes(rng, n, p):
    return pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))


# ------------------------------------------------------------ oracle tests
def test_popcount32_exact(rng):
    v = rng.integers(0, 2**32, size=(64,), dtype=np.uint32)
    got = np.asarray(ref.popcount32(jnp.asarray(v)))
    want = np.bitwise_count(v)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("p", [8, 24, 32, 64, 128, 200])
def test_scores_ref_matches_numpy_eq3(rng, p):
    B, N = 4, 100
    q = _random_codes(rng, B, p)
    db = _random_codes(rng, N, p)
    z = np.bitwise_count(q).sum(axis=1)
    got = np.asarray(ref.scores_ref(jnp.asarray(q), jnp.asarray(db), jnp.asarray(z)))
    for b in range(B):
        want = sims_against_db(q[b], db)
        np.testing.assert_allclose(got[b], want, atol=1e-6)


# ---------------------------------------------------- pallas kernel sweeps
@pytest.mark.parametrize("p", [16, 32, 64, 128, 256])
@pytest.mark.parametrize("shape", [(1, 100), (5, 1030), (9, 2048)])
def test_hamming_scan_kernel_sweep(rng, p, shape):
    B, N = shape
    q = jnp.asarray(_random_codes(rng, B, p))
    db = jnp.asarray(_random_codes(rng, N, p))
    got = np.asarray(ops.scan_scores(q, db, use_pallas=True))
    want = np.asarray(ops.scan_scores(q, db, use_pallas=False))
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("p", [32, 64, 128])
@pytest.mark.parametrize("n", [64, 1000, 3000])
def test_verify_tuples_kernel_sweep(rng, p, n):
    q = jnp.asarray(_random_codes(rng, 1, p)[0])
    cand = jnp.asarray(_random_codes(rng, n, p))
    r10p, r01p = ops.verify_tuples_op(q, cand, use_pallas=True)
    r10r, r01r = ops.verify_tuples_op(q, cand, use_pallas=False)
    # integer outputs: exact equality, not allclose
    assert np.array_equal(np.asarray(r10p), np.asarray(r10r))
    assert np.array_equal(np.asarray(r01p), np.asarray(r01r))


def test_kernel_degenerate_zero_query(rng):
    p = 64
    q = jnp.zeros((1, 2), jnp.uint32)
    db = jnp.asarray(_random_codes(rng, 256, p))
    got = np.asarray(ops.scan_scores(q, db, use_pallas=True))
    assert np.all(got == 0.0)  # zero query -> sim defined as 0


def test_kernel_zero_codes_in_db(rng):
    p = 32
    q = jnp.asarray(_random_codes(rng, 1, p))
    db_bits = (np.random.default_rng(0).random((128, p)) < 0.5).astype(np.uint8)
    db_bits[7] = 0  # plant an all-zero code
    db = jnp.asarray(pack_bits(db_bits))
    got = np.asarray(ops.scan_scores(q, db, use_pallas=True))
    assert got[0, 7] == 0.0


# ------------------------------------------------------------ streaming topk
@pytest.mark.parametrize("chunk", [64, 1000, 1 << 14])
@pytest.mark.parametrize("k", [1, 10, 100])
def test_scan_topk_streaming_exact(rng, chunk, k):
    p, B, N = 64, 3, 2500
    q = jnp.asarray(_random_codes(rng, B, p))
    db = jnp.asarray(_random_codes(rng, N, p))
    sims, ids = ops.scan_topk(q, db, k, chunk=chunk)
    full = np.asarray(ops.scan_scores(q, db, use_pallas=False))
    for b in range(B):
        want = np.sort(full[b])[::-1][: min(k, N)]
        np.testing.assert_allclose(
            np.sort(np.asarray(sims[b]))[::-1], want, atol=1e-6
        )
        # ids must be consistent with their sims
        np.testing.assert_allclose(
            full[b][np.asarray(ids[b])], np.asarray(sims[b]), atol=1e-6
        )


# ------------------------------------------------- block-max pruned scan
@pytest.mark.parametrize("use_pallas", [False, True])
@pytest.mark.parametrize("mode", ["clustered", "uniform"])
def test_scan_topk_pruned_exact(rng, use_pallas, mode):
    from repro.data import synthetic_binary_codes, synthetic_queries

    # pruning needs n_blocks >> k: 128 blocks, k=5
    p, B, N, k = 64, 4, 16384, 5
    db_bits = synthetic_binary_codes(N, p, seed=3, mode=mode)
    q_bits = synthetic_queries(db_bits, B, seed=4)
    q = jnp.asarray(pack_bits(q_bits))
    db = jnp.asarray(pack_bits(db_bits))
    sims_p, ids_p, frac = ops.scan_topk_pruned(
        q, db, k, blk=128, use_pallas=use_pallas
    )
    sims_f, ids_f = ops.scan_topk(q, db, k, chunk=512)
    np.testing.assert_allclose(
        np.sort(np.asarray(sims_p), axis=1),
        np.sort(np.asarray(sims_f), axis=1),
        atol=1e-6,
    )
    assert 0.0 < float(frac) <= 1.0
    if mode == "clustered":  # pruning must actually bite on clustered data
        assert float(frac) < 0.5, float(frac)


def test_blockmax_kernel_matches_ref(rng):
    from repro.kernels.blockmax_scan import blockmax_scores

    p, B, N, blk = 96, 3, 2048, 256
    q = jnp.asarray(_random_codes(rng, B, p))
    db = jnp.asarray(_random_codes(rng, N, p))
    z = jnp.asarray(np.bitwise_count(np.asarray(q)).sum(axis=1), jnp.int32)
    got = np.asarray(blockmax_scores(q, z, db, blk_n=blk, interpret=True))
    full = np.asarray(ops.scan_scores(q, db, use_pallas=False))
    want = full.reshape(B, N // blk, blk).max(axis=-1)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_scan_topk_k_ge_n(rng):
    p, B, N = 32, 2, 37
    q = jnp.asarray(_random_codes(rng, B, p))
    db = jnp.asarray(_random_codes(rng, N, p))
    sims, ids = ops.scan_topk(q, db, 50, chunk=16)
    assert sims.shape == (B, N)
    assert set(np.asarray(ids[0]).tolist()) == set(range(N))
