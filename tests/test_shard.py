"""The sharded search subsystem (repro.shard): ShardPlan layout contract,
host- and mesh-mode engine exactness vs ``linear_scan_knn`` (uneven N,
K > per-shard rows, B in {1, 8, 64}), cross-shard early termination,
per-shard EngineStats, and the Optional-annotation regression of the old
``core.distributed`` module (multi-device cases run in subprocesses with
8 fake CPU devices, the tests/test_distributed.py pattern)."""

import json
import subprocess
import sys
import textwrap
import typing

import numpy as np
import pytest

from repro.core import linear_scan_knn, make_engine, pack_bits
from repro.core.linear_scan import sims_against_db
from repro.data import synthetic_binary_codes, synthetic_queries
from repro.shard import ShardPlan


def _run(code: str, devices: int = 8) -> str:
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """)
    out = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, cwd="/root/repo", timeout=560,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _check_exact(ids, sims, qs, db, k_eff):
    """Sharded results == per-query linear scan, up to in-tuple ties."""
    B = qs.shape[0]
    assert ids.shape == (B, k_eff) and sims.shape == (B, k_eff)
    for i in range(B):
        _, sims_l = linear_scan_knn(qs[i], db, k_eff)
        np.testing.assert_array_equal(sims[i], sims_l)
        all_sims = sims_against_db(qs[i], db)
        np.testing.assert_array_equal(all_sims[ids[i]], sims[i])
        assert len(set(ids[i].tolist())) == k_eff  # shards are disjoint


# --------------------------------------------------------------- ShardPlan
def test_plan_balanced_remainder():
    plan = ShardPlan.balanced(10, 8)
    assert plan.counts == (2, 2, 1, 1, 1, 1, 1, 1)   # differ by <= 1
    assert plan.starts == (0, 2, 4, 5, 6, 7, 8, 9)
    assert plan.rows_padded == 2
    assert plan.num_shards == 8
    # slices tile [0, n) exactly
    rows = np.concatenate(
        [np.arange(plan.n)[plan.shard_slice(s)] for s in range(8)]
    )
    np.testing.assert_array_equal(rows, np.arange(10))
    assert plan.global_ids(3, np.arange(plan.counts[3])).tolist() == [5]


def test_plan_summary_roundtrip_is_json():
    plan = ShardPlan.balanced(1001, 7, axis_names=("pod", "data"))
    wire = json.dumps(plan.summary())          # serializable by contract
    assert ShardPlan.from_summary(json.loads(wire)) == plan
    s = plan.summary()
    assert s["num_shards"] == 7 and s["rows_padded"] == 143


def test_plan_padded_layout_masks_remainder():
    db = 1 + np.arange(10 * 3, dtype=np.uint32).reshape(10, 3)
    plan = ShardPlan.balanced(10, 4)           # counts (3, 3, 2, 2)
    padded = plan.padded_layout(db)
    assert padded.shape == (12, 3)
    for s in range(4):
        lo = s * plan.rows_padded
        np.testing.assert_array_equal(
            padded[lo : lo + plan.counts[s]], db[plan.shard_slice(s)]
        )
    # the two remainder slots (shards 2 and 3) are zero codes
    assert not padded[2 * 3 + 2].any() and not padded[3 * 3 + 2].any()


def test_plan_validation():
    with pytest.raises(ValueError, match="num_shards"):
        ShardPlan.balanced(10, 0)
    with pytest.raises(ValueError, match="counts sum"):
        ShardPlan(n=5, starts=(0, 2), counts=(2, 2))
    with pytest.raises(ValueError, match="base"):
        ShardPlan(n=4, starts=(3, 5), counts=(2, 2))   # base defaults to 0


def test_plan_host_partition_global_ids_and_local_slices():
    """host_partition: contiguous shard runs differing by <= 1 shard,
    GLOBAL starts with per-host base, local shard_slice, and sub-plan
    summaries that round-trip base over the wire."""
    plan = ShardPlan.balanced(103, 8, axis_names=("pod",))
    subs = plan.host_partition(3)
    assert [s.num_shards for s in subs] == [3, 3, 2]   # differ by <= 1
    assert sum(s.n for s in subs) == plan.n
    # contiguous coverage: each host's base is where the previous ended
    assert subs[0].base == 0
    for prev, cur in zip(subs, subs[1:]):
        assert cur.base == prev.base + prev.n
    covered = []
    for sub in subs:
        assert sub.axis_names == plan.axis_names
        assert sub.devices == ()                       # placement dropped
        for s in range(sub.num_shards):
            # starts are GLOBAL: global_ids needs no per-host fixup
            lo = sub.starts[s]
            np.testing.assert_array_equal(
                sub.global_ids(s, np.arange(sub.counts[s])),
                np.arange(lo, lo + sub.counts[s]),
            )
            # shard_slice is LOCAL to the host's row slab
            sl = sub.shard_slice(s)
            assert sl.start == lo - sub.base
            covered.extend(range(lo, lo + sub.counts[s]))
    assert covered == list(range(plan.n))              # exact tiling
    # wire round-trip keeps base (the "base" key appears iff nonzero)
    for sub in subs:
        wire = json.loads(json.dumps(sub.summary()))
        assert ("base" in wire) == (sub.base != 0)
        assert ShardPlan.from_summary(wire) == sub
    # degenerate and invalid host counts
    assert plan.host_partition(1) == [plan]
    assert plan.host_partition(8)[7].num_shards == 1
    with pytest.raises(ValueError, match="num_hosts"):
        plan.host_partition(0)
    with pytest.raises(ValueError, match="at least one shard"):
        plan.host_partition(9)


# ------------------------------------------- host-mode engines (1 device)
@pytest.mark.parametrize("backend", ["sharded_scan", "sharded_amih"])
@pytest.mark.parametrize("B", [1, 8, 64])
def test_sharded_exact_uneven_n(backend, B):
    p, n, k, S = 64, 997, 10, 8                # N not divisible by shards
    db_bits = synthetic_binary_codes(n, p, seed=0)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=1))
    eng = make_engine(backend, db, p, num_shards=S)
    ids, sims, stats = eng.knn_batch(qs, k)
    _check_exact(ids, sims, qs, db, k)
    assert stats.backend == backend and stats.queries == B
    assert stats.shards == S and len(stats.per_shard) == S
    assert sum(d["rows"] for d in stats.per_shard) == n


@pytest.mark.parametrize("backend", ["sharded_scan", "sharded_amih"])
def test_sharded_k_exceeds_shard_rows(backend):
    # K > every shard's row count: each shard must surface its whole slice
    p, n, k, S = 64, 50, 40, 8                 # ~6 rows/shard, k=40
    db_bits = synthetic_binary_codes(n, p, seed=2)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=3))
    eng = make_engine(backend, db, p, num_shards=S)
    ids, sims, _ = eng.knn_batch(qs, k)
    _check_exact(ids, sims, qs, db, k)
    # k > n clamps too
    ids, sims, _ = eng.knn_batch(qs, 99)
    _check_exact(ids, sims, qs, db, n)


def test_sharded_more_shards_than_rows():
    p, n = 64, 5
    db_bits = synthetic_binary_codes(n, p, seed=4)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 2, seed=5))
    for backend in ("sharded_scan", "sharded_amih"):
        ids, sims, _ = make_engine(backend, db, p, num_shards=8).knn_batch(
            qs, 3
        )
        _check_exact(ids, sims, qs, db, 3)


def test_sharded_amih_early_termination_bounds_global_kth():
    """Later shards stop probing once the pooled k-th cosine bounds them:
    their tuples_processed collapses vs an unbounded per-shard run, and
    per_shard counts the early-stopped queries."""
    p, n, B, k, S = 64, 2000, 8, 5, 8
    db_bits = synthetic_binary_codes(n, p, seed=6)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=7))
    eng = make_engine("sharded_amih", db, p, num_shards=S)
    ids, sims, stats = eng.knn_batch(qs, k)
    _check_exact(ids, sims, qs, db, k)
    assert any(d["early_stopped"] > 0 for d in stats.per_shard[1:])
    # an unbounded run of the last shard does strictly more tuple work
    _, last_index = eng.indexes[-1]
    bounded_tuples = stats.per_shard[-1]["tuples_processed"]
    from repro.core import AMIHStats

    free_stats = [AMIHStats() for _ in range(B)]
    last_index.knn_batch(qs, k, stats=free_stats)
    unbounded_tuples = sum(s.tuples_processed for s in free_stats)
    assert bounded_tuples < unbounded_tuples


def test_sharded_amih_ids_are_global():
    p, n, S = 64, 300, 4
    db_bits = synthetic_binary_codes(n, p, seed=8)
    db = pack_bits(db_bits)
    eng = make_engine("sharded_amih", db, p, num_shards=S)
    for s, index in eng.indexes:
        assert index.id_offset == eng.plan.starts[s]
    # a query equal to a code in the LAST shard must return its global id
    target = n - 3
    q = db[target : target + 1]
    ids, sims, _ = eng.knn_batch(q, 1)
    assert ids[0, 0] == target
    assert sims[0, 0] == sims_against_db(q[0], db)[target]


def test_sharded_scan_per_shard_candidate_counters():
    p, n, S = 64, 640, 4
    db_bits = synthetic_binary_codes(n, p, seed=9)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 8, seed=10))
    eng = make_engine("sharded_scan", db, p, num_shards=S)
    _, _, stats = eng.knn_batch(qs, 7)
    assert [d["shard"] for d in stats.per_shard] == list(range(S))
    assert all(d["launches"] == 1 for d in stats.per_shard)
    assert sum(d["candidates"] for d in stats.per_shard) > 0
    assert eng.shard_launches == S
    eng.knn_batch(qs, 7)
    assert eng.shard_launches == 2 * S


def test_plan_knob_passes_through_make_engine():
    import jax

    p, n = 64, 100
    db_bits = synthetic_binary_codes(n, p, seed=11)
    db = pack_bits(db_bits)
    plan = ShardPlan.balanced(n, 3)
    eng = make_engine("sharded_scan", db, p, plan=plan)
    # layout passes through untouched; an UNPLACED caller plan (e.g. a
    # from_summary restore) is placed on the local devices like every
    # other path, so it never silently reverts to the device-0 funnel
    assert eng.plan == plan and eng.plan.counts == plan.counts
    assert len(eng.plan.devices) == plan.num_shards
    assert eng.plan.devices[0] == jax.devices()[0]
    # an already-placed caller plan is trusted as-is (identity kept)
    placed = plan.place(jax.devices())
    assert make_engine("sharded_scan", db, p, plan=placed).plan is placed
    with pytest.raises(ValueError, match="plan covers"):
        make_engine("sharded_scan", db, p, plan=ShardPlan.balanced(n + 1, 3))


# --------------------------------------------------------- device placement
def test_plan_place_round_robin_and_validation():
    plan = ShardPlan.balanced(10, 4)
    assert plan.devices == () and plan.device_for(0) is None
    placed = plan.place(["d0", "d1", "d2"])      # fewer devices than shards
    assert placed.devices == ("d0", "d1", "d2", "d0")
    assert placed.device_for(3) == "d0"
    assert placed.counts == plan.counts          # layout untouched
    assert placed == plan                        # devices excluded from eq
    assert placed.place(None).devices == ()      # clearing
    wide = plan.place(["a", "b", "c", "d", "e"])  # extra devices idle
    assert wide.devices == ("a", "b", "c", "d")
    # summaries carry the placement as strings, and round-trip unplaced
    # — an EXPLICIT drop now: warning by default, error under strict=
    s = placed.summary()
    assert s["devices"] == ["d0", "d1", "d2", "d0"]
    with pytest.warns(UserWarning, match="drops device placements"):
        restored = ShardPlan.from_summary(json.loads(json.dumps(s)))
    assert restored.devices == () and restored == plan
    with pytest.raises(ValueError, match="drops device placements"):
        ShardPlan.from_summary(s, strict=True)
    with pytest.raises(ValueError, match="devices maps"):
        ShardPlan(n=10, starts=placed.starts, counts=placed.counts,
                  devices=("d0",))


def test_host_engines_record_placement_single_device():
    """On a 1-device host every shard lands on that device — recorded in
    the plan and in each per_shard stats dict."""
    import jax

    p, n, S = 64, 400, 4
    db_bits = synthetic_binary_codes(n, p, seed=30)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=31))
    dev = str(jax.devices()[0])
    for backend in ("sharded_scan", "sharded_amih"):
        eng = make_engine(backend, db, p, num_shards=S)
        assert [str(d) for d in eng.plan.devices] == [dev] * S
        _, _, stats = eng.knn_batch(qs, 5)
        assert [d["device"] for d in stats.per_shard] == [dev] * S


def test_sharded_amih_verify_runs_on_assigned_devices_mesh():
    """The tentpole contract on 8 fake devices: each shard's db_dev is
    committed to its plan device, grouped-verify launches split across
    the devices (per-device launch counters move, the default-device
    counter does not), and results stay exact."""
    _run("""
        from repro.core import make_engine, linear_scan_knn, pack_bits
        from repro.data import synthetic_binary_codes, synthetic_queries
        from repro.kernels import ops
        from repro.launch.mesh import make_mesh

        p, n, B, k = 64, 1499, 8, 7
        db_bits = synthetic_binary_codes(n, p, seed=0)
        db = pack_bits(db_bits)
        qs = pack_bits(synthetic_queries(db_bits, B, seed=1))
        mesh = make_mesh((4, 2), ("data", "model"))
        eng = make_engine("sharded_amih", db, p, mesh=mesh,
                          verify_backend="pallas")
        assert eng.plan.num_shards == 8
        assert len({str(d) for d in eng.plan.devices}) == 8
        for s, ix in eng.indexes:
            (got,) = ix.db_dev.devices()
            assert got == eng.plan.device_for(s), (s, got)
        before = dict(ops.LAUNCH_COUNTS_BY_DEVICE)
        ids, sims, st = eng.knn_batch(qs, k)
        for i in range(B):
            _, sims_l = linear_scan_knn(qs[i], db, k)
            np.testing.assert_array_equal(sims[i], sims_l)
        delta = {d: c - before.get(d, 0)
                 for d, c in ops.LAUNCH_COUNTS_BY_DEVICE.items()}
        active = {d for d, c in delta.items() if c > 0}
        assert len(active) == 8 and "default" not in active, delta
        # stats record the placement and the per-shard launch counts
        # measured where the verifies ran
        for d in st.per_shard:
            assert d["device"].startswith("TFRT_CPU_")
            assert delta[d["device"]] >= d["launches"] > 0
        # one jit instance per device
        assert len(ops.device_jit_cache_info()) >= 8
        print("OK")
    """)


def test_sharded_amih_uneven_device_counts_mesh():
    """Placement stays exact when shards != devices: an explicit device
    list wraps round-robin (8 shards, 3 devices) and leaves extras idle
    (5 shards, 8 devices)."""
    _run("""
        from repro.core import make_engine, linear_scan_knn, pack_bits
        from repro.data import synthetic_binary_codes, synthetic_queries

        p, n, B, k = 64, 997, 4, 9
        db_bits = synthetic_binary_codes(n, p, seed=2)
        db = pack_bits(db_bits)
        qs = pack_bits(synthetic_queries(db_bits, B, seed=3))
        devs = jax.devices()
        few = make_engine("sharded_amih", db, p, num_shards=8,
                          devices=devs[:3], verify_backend="pallas")
        assert [str(d) for d in few.plan.devices] == \\
            [str(devs[s % 3]) for s in range(8)]
        many = make_engine("sharded_amih", db, p, num_shards=5,
                           devices=devs, verify_backend="pallas")
        assert [str(d) for d in many.plan.devices] == \\
            [str(d) for d in devs[:5]]
        for eng in (few, many):
            ids, sims, _ = eng.knn_batch(qs, k)
            for i in range(B):
                _, sims_l = linear_scan_knn(qs[i], db, k)
                np.testing.assert_array_equal(sims[i], sims_l)
        print("OK")
    """)


def test_sharded_amih_fused_one_launch_per_device():
    """PR 7 tentpole on 8 fake devices: 16 shards, 2 per device, fuse
    into ONE walk launch per device per batch (each device's two shards
    stacked into a super index), per-device launch counters move by
    exactly the fused dispatches, stats attribute the shared launch to
    the group's lead shard only, and results stay exact."""
    _run("""
        from repro.core import make_engine, linear_scan_knn, pack_bits
        from repro.data import synthetic_binary_codes, synthetic_queries
        from repro.kernels import ops
        from repro.obs.metrics import REGISTRY as _REG

        p, n, B, k = 64, 4000, 16, 5
        db_bits = synthetic_binary_codes(n, p, seed=4)
        db = pack_bits(db_bits)
        qs = pack_bits(synthetic_queries(db_bits, B, seed=5))
        eng = make_engine("sharded_amih", db, p, num_shards=16,
                          probe_backend="device")
        assert len({str(d) for d in eng.plan.devices}) == 8
        before = dict(ops.LAUNCH_COUNTS_BY_DEVICE)
        walk0 = _REG.value("launches.device_probe")
        ids, sims, st = eng.knn_batch(qs, k)
        # ONE fused walk launch per device, not one per shard
        assert _REG.value("launches.device_probe") - walk0 == 8
        delta = {d: c - before.get(d, 0)
                 for d, c in ops.LAUNCH_COUNTS_BY_DEVICE.items()}
        active = {d for d, c in delta.items() if c > 0}
        assert len(active) == 8 and "default" not in active, delta
        # walk (+ at most one scan-fallback) per device
        assert all(1 <= delta[d] <= 2 for d in active), delta
        # S6 attribution: every shard reports the shared per-device
        # launch id; only the lead shard of each device group carries
        # the launch count, so the sum equals real dispatches
        lids = [d["launch_id"] for d in st.per_shard]
        assert len(set(lids)) == 8 and len(lids) == 16
        assert all(d["fused_shards"] == 2 for d in st.per_shard)
        leads = [d for d in st.per_shard if d["launches"] > 0]
        assert len(leads) == 8
        assert sum(d["launches"] for d in st.per_shard) == \\
            sum(delta[d] for d in active)
        for i in range(B):
            _, sims_l = linear_scan_knn(qs[i], db, k)
            np.testing.assert_array_equal(sims[i], sims_l)
        # second batch: super indexes cached, still 8 walk launches
        walk0 = _REG.value("launches.device_probe")
        ids2, sims2, _ = eng.knn_batch(qs, k)
        assert _REG.value("launches.device_probe") - walk0 == 8
        np.testing.assert_array_equal(ids2, ids)
        print("OK")
    """)


# ------------------------------------------------- deprecated shim
def test_core_distributed_shim_warns_and_reexports():
    """core.distributed is a DeprecationWarning shim now; its re-exports
    must keep resolving for old imports."""
    import importlib
    import warnings

    import repro.core.distributed as legacy

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = importlib.reload(legacy)
    assert any(
        issubclass(w.category, DeprecationWarning)
        and "repro.shard" in str(w.message)
        for w in caught
    )
    from repro.shard import ShardPlan as new_plan

    assert legacy.ShardPlan is new_plan
    for name in ("make_retrieval_step", "sharded_scan_candidates",
                 "sharded_scan_topk"):
        assert callable(getattr(legacy, name))


# ------------------------------------------------- annotation regression
def test_distributed_annotations_resolve():
    """Regression: ``shard_axes: Optional[...]`` used to reference an
    un-imported Optional (hidden by ``from __future__ import
    annotations`` until something resolved the hints)."""
    from repro.core import distributed as legacy
    from repro.shard import distributed as shard_dist

    for fn in (
        shard_dist.sharded_scan_topk,
        shard_dist.make_retrieval_step,
        legacy.sharded_scan_topk,            # the shim re-export
    ):
        hints = typing.get_type_hints(fn)
        assert "shard_axes" in hints


# ---------------------------------------------- mesh mode (8 fake devices)
def test_sharded_engines_match_linear_scan_on_mesh():
    _run("""
        from repro.core import make_engine, linear_scan_knn, pack_bits
        from repro.data import synthetic_binary_codes, synthetic_queries
        from repro.launch.mesh import make_mesh, make_search_mesh

        p, n, k = 64, 4093, 25               # prime N: uneven everywhere
        db_bits = synthetic_binary_codes(n, p, seed=0)
        db = pack_bits(db_bits)
        mesh = make_mesh((4, 2), ("data", "model"))
        eng = make_engine("sharded_scan", db, p, mesh=mesh, chunk=256)
        assert eng.plan.num_shards == 8
        amih = make_engine("sharded_amih", db, p, mesh=mesh)
        for B in (1, 8, 64):
            qs = pack_bits(synthetic_queries(db_bits, B, seed=B))
            for e in (eng, amih):
                ids, sims, stats = e.knn_batch(qs, k)
                assert stats.shards == 8
                for i in range(B):
                    ids_l, sims_l = linear_scan_knn(qs[i], db, k)
                    np.testing.assert_array_equal(sims[i], sims_l)

        # K > per-shard rows (512 rows/shard, K pool spans shards)
        small = pack_bits(db_bits[:40])
        eng_s = make_engine("sharded_scan", small, p, mesh=mesh, chunk=8)
        qs = pack_bits(synthetic_queries(db_bits, 4, seed=99))
        ids, sims, _ = eng_s.knn_batch(qs, 30)
        for i in range(4):
            _, sims_l = linear_scan_knn(qs[i], small, 30)
            np.testing.assert_array_equal(sims[i], sims_l)

        # the 1-D search mesh helper spans all fake devices
        smesh = make_search_mesh()
        eng_m = make_engine("sharded_scan", db, p, mesh=smesh, chunk=256)
        assert eng_m.plan.num_shards == 8
        ids, sims, _ = eng_m.knn_batch(qs[:2], 10)
        for i in range(2):
            _, sims_l = linear_scan_knn(qs[i], db, 10)
            np.testing.assert_array_equal(sims[i], sims_l)
        print("OK")
    """)
