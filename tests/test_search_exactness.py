"""The paper's headline property: AMIH / single-table search is EXACT —
identical to linear scan for the angular KNN problem (up to ties)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    AMIHIndex,
    AMIHStats,
    SearchStats,
    SingleTableIndex,
    linear_scan_knn,
    pack_bits,
)
from repro.core.linear_scan import sims_against_db
from repro.data import synthetic_binary_codes, synthetic_queries


def _check_knn_equal(ids, sims, ids_l, sims_l, q_words, db_words):
    """Equality up to ties: sims must match exactly as multisets."""
    np.testing.assert_allclose(
        np.asarray(sims), np.asarray(sims_l), atol=1e-9
    )
    # every returned id must actually have the sim it was returned with
    all_sims = sims_against_db(q_words, db_words)
    np.testing.assert_allclose(all_sims[ids], sims, atol=1e-9)


@given(
    p=st.sampled_from([16, 24, 32, 48, 64, 96, 128]),
    n=st.integers(10, 400),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
    mode=st.sampled_from(["uniform", "clustered"]),
)
@settings(max_examples=60, deadline=None)
def test_amih_equals_linear_scan(p, n, k, seed, mode):
    db_bits = synthetic_binary_codes(n, p, seed=seed, mode=mode)
    q_bits = synthetic_queries(db_bits, 1, seed=seed + 1)[0]
    db = pack_bits(db_bits)
    q = pack_bits(q_bits)
    idx = AMIHIndex.build(db, p)
    stats = AMIHStats()
    ids, sims = idx.knn(q, k, stats=stats)
    ids_l, sims_l = linear_scan_knn(q, db, k)
    _check_knn_equal(ids, sims, ids_l, sims_l, q, db)


@given(
    m=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_amih_exact_for_any_table_count(m, seed):
    p, n, k = 48, 200, 10
    db_bits = synthetic_binary_codes(n, p, seed=seed)
    q = pack_bits(synthetic_queries(db_bits, 1, seed=seed + 9)[0])
    db = pack_bits(db_bits)
    idx = AMIHIndex.build(db, p, m=m)
    ids, sims = idx.knn(q, k)
    _, sims_l = linear_scan_knn(q, db, k)
    np.testing.assert_allclose(sims, sims_l, atol=1e-9)


def test_amih_extreme_queries():
    p, n = 64, 500
    rng = np.random.default_rng(3)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    idx = AMIHIndex.build(db, p)
    for q_bits in (np.zeros(p, np.uint8), np.ones(p, np.uint8)):
        q = pack_bits(q_bits)
        ids, sims = idx.knn(q, 5)
        _, sims_l = linear_scan_knn(q, db, 5)
        np.testing.assert_allclose(sims, sims_l, atol=1e-9)


def test_amih_k_larger_than_n():
    p, n = 32, 20
    rng = np.random.default_rng(4)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    q = pack_bits((rng.random(p) < 0.5).astype(np.uint8))
    idx = AMIHIndex.build(db, p)
    ids, sims = idx.knn(q, 100)
    assert len(ids) == n
    _, sims_l = linear_scan_knn(q, db, 100)
    np.testing.assert_allclose(sims, sims_l, atol=1e-9)


def test_amih_with_duplicate_codes():
    p = 24
    rng = np.random.default_rng(5)
    base = (rng.random((10, p)) < 0.5).astype(np.uint8)
    db_bits = np.repeat(base, 7, axis=0)  # each code 7 times
    db = pack_bits(db_bits)
    q = pack_bits(base[0])
    idx = AMIHIndex.build(db, p)
    ids, sims = idx.knn(q, 7)
    assert np.all(sims == sims[0]) and sims[0] == pytest.approx(1.0)


@given(
    seed=st.integers(0, 2**31 - 1),
    r1=st.integers(0, 6),
    r2=st.integers(0, 6),
)
@settings(max_examples=30, deadline=None)
def test_r1r2_near_neighbor_problem(seed, r1, r2):
    """Definition 4: search_radius returns exactly the codes with
    componentwise tuple <= (r1, r2)."""
    p, n = 32, 300
    db_bits = synthetic_binary_codes(n, p, seed=seed, flip_prob=0.15)
    q_bits = synthetic_queries(db_bits, 1, seed=seed + 7)[0]
    db, q = pack_bits(db_bits), pack_bits(q_bits)
    idx = AMIHIndex.build(db, p, m=3)
    got = idx.search_radius(q, r1, r2)
    from repro.core.packing import hamming_tuples

    e1, e2 = hamming_tuples(q, db)
    want = np.flatnonzero((e1 <= r1) & (e2 <= r2))
    assert np.array_equal(got, want)


def test_single_table_exact():
    p, n, k = 16, 300, 8
    rng = np.random.default_rng(11)
    db = pack_bits((rng.random((n, p)) < 0.5).astype(np.uint8))
    st_idx = SingleTableIndex.build(db, p)
    for i in range(10):
        q = pack_bits((rng.random(p) < 0.5).astype(np.uint8))
        stats = SearchStats()
        ids, sims = st_idx.knn(q, k, stats=stats)
        _, sims_l = linear_scan_knn(q, db, k)
        np.testing.assert_allclose(sims, sims_l, atol=1e-9)
        assert stats.probes > 0


def test_amih_stats_accounting():
    p, n = 64, 1000
    db_bits = synthetic_binary_codes(n, p, seed=0)
    q = pack_bits(synthetic_queries(db_bits, 1, seed=1)[0])
    idx = AMIHIndex.build(pack_bits(db_bits), p)
    stats = AMIHStats()
    idx.knn(q, 10, stats=stats)
    assert stats.probes > 0
    assert stats.verified <= n          # dedup: never verify twice
    assert stats.tuples_processed >= 1
    # sublinearity on clustered data: probes far below brute-force buckets
    assert stats.probes < 10 * n
